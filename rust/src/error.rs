//! Unified error type for the easyfl platform.
//!
//! Hand-rolled `Display`/`Error` impls keep the crate dependency-free
//! (the offline registry ships no `thiserror`).

use std::fmt;

/// All failure modes surfaced by the public API.
#[derive(Debug)]
pub enum Error {
    /// Configuration was syntactically valid but semantically wrong.
    Config(String),

    /// An AOT artifact (HLO text / meta / init params) is missing or bad.
    Artifact(String),

    /// The XLA/PJRT runtime rejected a compile or execute call.
    Runtime(String),

    /// A dataset/model/server/client registration problem.
    Registry(String),

    /// Remote-communication failure (framing, protocol, transport).
    Comm(String),

    /// Deployment-manager failure (spawn, supervise, teardown).
    Deploy(String),

    /// Tracking-store failure (persistence, query).
    Tracking(String),

    /// JSON parse/serialize failure.
    Json(String),

    /// A wire payload failed its integrity check (content-hash
    /// mismatch on a codec-encoded update).
    Integrity(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Registry(m) => write!(f, "registry error: {m}"),
            Error::Comm(m) => write!(f, "comm error: {m}"),
            Error::Deploy(m) => write!(f, "deploy error: {m}"),
            Error::Tracking(m) => write!(f, "tracking error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Integrity(m) => write!(f, "integrity error: {m}"),
            // Transparent: IO errors read best undecorated.
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Platform-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Registry("y".into()).to_string(), "registry error: y");
        assert_eq!(
            Error::Integrity("z".into()).to_string(),
            "integrity error: z"
        );
        let io = Error::from(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        assert!(io.to_string().contains("gone"));
    }
}
