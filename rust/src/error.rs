//! Unified error type for the easyfl platform.

use thiserror::Error;

/// All failure modes surfaced by the public API.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration was syntactically valid but semantically wrong.
    #[error("config error: {0}")]
    Config(String),

    /// An AOT artifact (HLO text / meta / init params) is missing or bad.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The XLA/PJRT runtime rejected a compile or execute call.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// A dataset/model/server/client registration problem.
    #[error("registry error: {0}")]
    Registry(String),

    /// Remote-communication failure (framing, protocol, transport).
    #[error("comm error: {0}")]
    Comm(String),

    /// Deployment-manager failure (spawn, supervise, teardown).
    #[error("deploy error: {0}")]
    Deploy(String),

    /// Tracking-store failure (persistence, query).
    #[error("tracking error: {0}")]
    Tracking(String),

    /// JSON parse/serialize failure.
    #[error("json error: {0}")]
    Json(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Platform-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
