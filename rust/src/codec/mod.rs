//! Compressed update transport: registry-selectable wire codecs.
//!
//! At federation scale the wire format is the scaling bottleneck: a dense
//! f32 upload ships `P·4` bytes per reporter per round. This module makes
//! compression a swappable stage, the same low-code way aggregators and
//! topologies are selected — `cfg.codec = Some("top_k_i8(0.05)".into())`
//! turns every client upload into a quantized sparse delta:
//!
//! | codec              | payload per kept coordinate | typical ratio |
//! |--------------------|-----------------------------|---------------|
//! | `identity`         | — (dense passthrough)       | 1×            |
//! | `top_k(frac)`      | u32 index + f32 value       | ~P/(2k)       |
//! | `top_k_f16(frac)`  | u32 index + f16 value       | ~P/(1.5k)     |
//! | `top_k_i8(frac)`   | u32 index + i8 value (+ one f32 scale per 256-value chunk) | ~P/(1.25k) |
//!
//! A codec encodes the *delta* against the distributed global parameters
//! (the same contract as [`Update::SparseTernary`]), keeping the
//! `k = ⌈frac·P⌉` largest-magnitude coordinates, and stamps a FNV-1a
//! content hash over the full payload so receivers can verify integrity
//! — a tampered payload surfaces as a typed [`Error::Integrity`], never
//! as silent divergence. The streaming aggregation plane folds encoded
//! updates index-wise without dense materialization (see
//! [`crate::aggregate::fold_delta_update`]), and SimNet charges the
//! encoded byte size for uplink delay and communication accounting.

use std::sync::Arc;

use crate::coordinator::ClientFlowFactory;
use crate::error::{Error, Result};
use crate::flow::{ClientFlow, ModelPayload, TrainStats, TrainTask, Update};
use crate::model::ParamVec;
use crate::registry::{spec_head, spec_inner, ComponentRegistry};
use crate::runtime::Engine;

/// Kept values per i8 quantization chunk: one f32 scale amortized over
/// this many quantized values (1.5% size overhead, per-chunk dynamic
/// range instead of one global scale).
const I8_CHUNK: usize = 256;

/// Fixed per-update framing: dense length (u32) + kept count (u32) +
/// content hash (u64).
const HEADER_BYTES: usize = 16;

/// Default kept-coordinate fraction when a spec carries no argument
/// (matches the STC default sparsity).
const DEFAULT_FRAC: f64 = 0.01;

// ------------------------------------------------------------ hashing

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Minimal FNV-1a 64-bit hasher (dependency-free, stable across
/// platforms — the hash is a wire artifact, not an in-process one).
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Fnv64 {
        Fnv64(FNV_OFFSET)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

// ----------------------------------------------------- f16 conversion

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (no `half` crate;
/// the offline registry ships no dependencies).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // Inf / NaN (keep a NaN payload bit so NaN stays NaN).
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e16 = exp - 112; // rebias 127 → 15
    if e16 >= 31 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // underflow → ±0
        }
        // Subnormal: restore the implicit bit, shift out with
        // round-to-nearest-even.
        let m = mant | 0x0080_0000;
        let shift = (14 - e16) as u32;
        let rem = m & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut out = m >> shift;
        if rem > half || (rem == half && out & 1 == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    // Normal: drop 13 mantissa bits with round-to-nearest-even.
    let mut e16 = e16 as u32;
    let mut m16 = mant >> 13;
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && m16 & 1 == 1) {
        m16 += 1;
        if m16 == 0x400 {
            m16 = 0;
            e16 += 1;
            if e16 >= 31 {
                return sign | 0x7c00;
            }
        }
    }
    sign | ((e16 as u16) << 10) | m16 as u16
}

/// IEEE 754 binary16 bits → f32 (exact; every f16 is representable).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: renormalize into the f32 exponent range.
            let mut e = 113u32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

// ------------------------------------------------------ encoded update

/// Which wire codec produced an [`EncodedUpdate`] (hashed into the
/// content hash so a payload cannot be reinterpreted under another
/// codec).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// Dense passthrough — never appears inside an `EncodedUpdate`
    /// (identity encodes straight to [`Update::Dense`]).
    Identity,
    /// Top-k sparse delta, full f32 values.
    TopK,
    /// Top-k sparse delta, f16-quantized values.
    TopKF16,
    /// Top-k sparse delta, i8-quantized values with per-chunk f32 scale.
    TopKI8,
}

impl CodecKind {
    pub(crate) fn tag(self) -> u8 {
        match self {
            CodecKind::Identity => 0,
            CodecKind::TopK => 1,
            CodecKind::TopKF16 => 2,
            CodecKind::TopKI8 => 3,
        }
    }

    /// Inverse of [`CodecKind::tag`] for wire decoding
    /// ([`crate::comm::protocol`] ships encoded updates by tag).
    pub(crate) fn from_tag(tag: u8) -> Option<CodecKind> {
        match tag {
            0 => Some(CodecKind::Identity),
            1 => Some(CodecKind::TopK),
            2 => Some(CodecKind::TopKF16),
            3 => Some(CodecKind::TopKI8),
            _ => None,
        }
    }

    fn head(self) -> &'static str {
        match self {
            CodecKind::Identity => "identity",
            CodecKind::TopK => "top_k",
            CodecKind::TopKF16 => "top_k_f16",
            CodecKind::TopKI8 => "top_k_i8",
        }
    }

    /// Payload bytes per kept coordinate (index + value), excluding
    /// chunk scales and framing.
    fn bytes_per_coord(self) -> usize {
        match self {
            CodecKind::Identity => 4,
            CodecKind::TopK => 8,
            CodecKind::TopKF16 => 6,
            CodecKind::TopKI8 => 5,
        }
    }
}

/// Quantized kept values of an encoded update, one entry per index.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantizedValues {
    /// Full-precision values (`top_k`).
    F32(Vec<f32>),
    /// binary16 bit patterns (`top_k_f16`).
    F16(Vec<u16>),
    /// i8 quanta with one f32 scale per [`I8_CHUNK`] values
    /// (`top_k_i8`): `value = quanta · scale`.
    I8 { quanta: Vec<i8>, scales: Vec<f32> },
}

impl QuantizedValues {
    fn len(&self) -> usize {
        match self {
            QuantizedValues::F32(v) => v.len(),
            QuantizedValues::F16(v) => v.len(),
            QuantizedValues::I8 { quanta, .. } => quanta.len(),
        }
    }

    /// Dequantized value at ordinal `i` (caller guarantees `i < len`).
    fn get(&self, i: usize) -> f32 {
        match self {
            QuantizedValues::F32(v) => v[i],
            QuantizedValues::F16(v) => f16_bits_to_f32(v[i]),
            QuantizedValues::I8 { quanta, scales } => {
                quanta[i] as f32 * scales[i / I8_CHUNK]
            }
        }
    }
}

/// One codec-compressed client upload: a sparse delta against the
/// distributed global parameters, integrity-stamped with a FNV-1a
/// content hash over the full payload.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedUpdate {
    /// Codec that produced the payload.
    pub kind: CodecKind,
    /// Dense parameter count P the delta applies to.
    pub len: usize,
    /// Kept coordinate indices, strictly ascending.
    pub indices: Vec<u32>,
    /// Quantized delta values, one per index.
    pub values: QuantizedValues,
    /// Exact serialized wire size in bytes (framing + indices + values
    /// + chunk scales) — what SimNet charges and `comm_bytes` counts.
    pub encoded_len: usize,
    /// FNV-1a 64 hash over (kind, len, indices, values, scales).
    pub content_hash: u64,
}

impl EncodedUpdate {
    /// Recompute the content hash from the payload.
    fn compute_hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write(&[self.kind.tag()]);
        h.write(&(self.len as u64).to_le_bytes());
        h.write(&(self.indices.len() as u64).to_le_bytes());
        for &i in &self.indices {
            h.write(&i.to_le_bytes());
        }
        match &self.values {
            QuantizedValues::F32(v) => {
                for x in v {
                    h.write(&x.to_le_bytes());
                }
            }
            QuantizedValues::F16(v) => {
                for x in v {
                    h.write(&x.to_le_bytes());
                }
            }
            QuantizedValues::I8 { quanta, scales } => {
                for &q in quanta {
                    h.write(&(q as u8).to_le_bytes());
                }
                for s in scales {
                    h.write(&s.to_le_bytes());
                }
            }
        }
        h.finish()
    }

    /// Verify the stamped content hash against the payload: the
    /// integrity gate every receiver runs before folding. A mismatch is
    /// the typed [`Error::Integrity`] — a tampered or corrupted upload
    /// must never silently enter the reduction.
    pub fn verify(&self) -> Result<()> {
        let got = self.compute_hash();
        if got != self.content_hash {
            return Err(Error::Integrity(format!(
                "codec {}: content hash mismatch (stamped {:#018x}, \
                 computed {got:#018x})",
                self.kind.head(),
                self.content_hash
            )));
        }
        Ok(())
    }

    /// Structural validation against a P-length model (arity, index
    /// range, chunk-scale count) — the same malformed-not-panicking
    /// contract as the sparse-ternary path.
    fn validate(&self, p: usize) -> Result<()> {
        if self.len != p {
            return Err(Error::Runtime(format!(
                "encoded update of len {} != P {p}",
                self.len
            )));
        }
        if self.values.len() != self.indices.len() {
            return Err(Error::Runtime(format!(
                "encoded update has {} values for {} indices",
                self.values.len(),
                self.indices.len()
            )));
        }
        if let QuantizedValues::I8 { quanta, scales } = &self.values {
            if scales.len() != quanta.len().div_ceil(I8_CHUNK) {
                return Err(Error::Runtime(format!(
                    "encoded update has {} chunk scales for {} quanta",
                    scales.len(),
                    quanta.len()
                )));
            }
        }
        for &idx in &self.indices {
            if idx as usize >= p {
                return Err(Error::Runtime(format!(
                    "encoded index {idx} out of range (P = {p})"
                )));
            }
        }
        Ok(())
    }

    /// Verify + validate, then fold `weight · delta` into the f64
    /// accumulator index-wise — the streaming decode. Indices at or past
    /// `active_limit` are skipped (slice-masked aggregation folds only
    /// the backbone prefix), mirroring the sparse-ternary fold. The
    /// caller accounts the `weight · global` base at finish, exactly as
    /// for [`Update::SparseTernary`].
    pub(crate) fn fold_into(
        &self,
        acc: &mut [f64],
        p: usize,
        weight: f64,
        active_limit: usize,
    ) -> Result<()> {
        self.verify()?;
        self.validate(p)?;
        for (i, &idx) in self.indices.iter().enumerate() {
            let idx = idx as usize;
            if idx < active_limit {
                acc[idx] += weight * self.values.get(i) as f64;
            }
        }
        Ok(())
    }

    /// Verify + validate, then reconstruct the dense parameter vector
    /// `global + delta` (rank-based aggregators and tests; the streaming
    /// path uses [`EncodedUpdate::fold_into`] instead).
    pub fn to_dense(&self, global: &ParamVec) -> Result<ParamVec> {
        self.verify()?;
        self.validate(global.len())?;
        let mut out = global.clone();
        for (i, &idx) in self.indices.iter().enumerate() {
            out[idx as usize] += self.values.get(i);
        }
        Ok(out)
    }

    /// Verify + validate, then the delta's L2 norm (norm-clip screening
    /// without dense materialization).
    pub fn delta_l2(&self, p: usize) -> Result<f64> {
        self.verify()?;
        self.validate(p)?;
        let mut sum = 0.0f64;
        for i in 0..self.indices.len() {
            let v = self.values.get(i) as f64;
            sum += v * v;
        }
        Ok(sum.sqrt())
    }
}

// ------------------------------------------------------------- codecs

/// The compression stage as a pluggable component: encodes a client's
/// new parameters into a wire [`Update`] (a delta against the
/// distributed global), and predicts its encoded wire size for SimNet's
/// deterministic cost accounting.
pub trait UpdateCodec: Send + Sync {
    /// Registered head name (`"top_k_i8"`).
    fn name(&self) -> &'static str;

    /// Full spec including parameters (`"top_k_i8(0.05)"`).
    fn spec(&self) -> String;

    /// Encode `new_params` as a wire update: the delta vs `global`,
    /// compressed and integrity-stamped. Identity returns
    /// [`Update::Dense`] unchanged.
    fn encode(&self, new_params: ParamVec, global: &ParamVec) -> Result<Update>;

    /// Deterministic encoded wire size for a model whose dense upload is
    /// `dense_bytes` — what SimNet charges per uplink without flowing
    /// real updates. Must agree with `encode`'s `encoded_len` when
    /// `dense_bytes = P·4`; identity returns `dense_bytes` exactly, so
    /// codec-unset and identity runs cost the same bytes bit-for-bit.
    fn wire_bytes_for(&self, dense_bytes: usize) -> usize;
}

/// The built-in codec family: identity passthrough or top-k sparse
/// delta with optional value quantization.
#[derive(Debug, Clone, Copy)]
pub struct SparseCodec {
    kind: CodecKind,
    /// Kept-coordinate fraction in (0, 1].
    frac: f64,
}

impl SparseCodec {
    /// Kept coordinates for a P-parameter model: `⌈frac·P⌉`, at least 1.
    fn k_for(&self, p: usize) -> usize {
        ((p as f64 * self.frac).ceil() as usize).clamp(1, p.max(1))
    }
}

impl UpdateCodec for SparseCodec {
    fn name(&self) -> &'static str {
        self.kind.head()
    }

    fn spec(&self) -> String {
        match self.kind {
            CodecKind::Identity => "identity".into(),
            _ => format!("{}({})", self.kind.head(), self.frac),
        }
    }

    fn encode(&self, new_params: ParamVec, global: &ParamVec) -> Result<Update> {
        if self.kind == CodecKind::Identity {
            return Ok(Update::Dense(new_params));
        }
        let p = global.len();
        if new_params.len() != p {
            return Err(Error::Runtime(format!(
                "codec {}: params of len {} != P {p}",
                self.kind.head(),
                new_params.len()
            )));
        }
        // Delta vs the distributed global, largest magnitudes kept —
        // the same selection STC performs, but value-preserving.
        let mut deltas: Vec<(u32, f32)> = new_params
            .iter()
            .zip(global.iter())
            .enumerate()
            .map(|(i, (n, g))| (i as u32, n - g))
            .collect();
        if deltas.iter().any(|(_, d)| !d.is_finite()) {
            return Err(Error::Runtime(format!(
                "codec {}: non-finite delta refused (diverged update?)",
                self.kind.head()
            )));
        }
        let k = self.k_for(p);
        if k < p {
            deltas.select_nth_unstable_by(k - 1, |a, b| {
                b.1.abs().partial_cmp(&a.1.abs()).unwrap()
            });
            deltas.truncate(k);
        }
        // Ascending indices: cache-friendly folds, deterministic hash.
        deltas.sort_unstable_by_key(|(i, _)| *i);
        let indices: Vec<u32> = deltas.iter().map(|(i, _)| *i).collect();
        let values = match self.kind {
            CodecKind::TopK => {
                QuantizedValues::F32(deltas.iter().map(|(_, d)| *d).collect())
            }
            CodecKind::TopKF16 => QuantizedValues::F16(
                deltas.iter().map(|(_, d)| f32_to_f16_bits(*d)).collect(),
            ),
            CodecKind::TopKI8 => {
                let mut quanta = Vec::with_capacity(k);
                let mut scales = Vec::with_capacity(k.div_ceil(I8_CHUNK));
                for chunk in deltas.chunks(I8_CHUNK) {
                    let max_abs = chunk
                        .iter()
                        .map(|(_, d)| d.abs())
                        .fold(0.0f32, f32::max);
                    let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
                    scales.push(scale);
                    for (_, d) in chunk {
                        let q = if scale > 0.0 {
                            (d / scale).round().clamp(-127.0, 127.0) as i8
                        } else {
                            0
                        };
                        quanta.push(q);
                    }
                }
                QuantizedValues::I8 { quanta, scales }
            }
            CodecKind::Identity => unreachable!("identity returned above"),
        };
        let encoded_len = HEADER_BYTES
            + k * self.kind.bytes_per_coord()
            + match self.kind {
                CodecKind::TopKI8 => k.div_ceil(I8_CHUNK) * 4,
                _ => 0,
            };
        let mut enc = EncodedUpdate {
            kind: self.kind,
            len: p,
            indices,
            values,
            encoded_len,
            content_hash: 0,
        };
        enc.content_hash = enc.compute_hash();
        Ok(Update::Encoded(enc))
    }

    fn wire_bytes_for(&self, dense_bytes: usize) -> usize {
        if self.kind == CodecKind::Identity {
            return dense_bytes;
        }
        let p = (dense_bytes / 4).max(1);
        let k = self.k_for(p);
        HEADER_BYTES
            + k * self.kind.bytes_per_coord()
            + match self.kind {
                CodecKind::TopKI8 => k.div_ceil(I8_CHUNK) * 4,
                _ => 0,
            }
    }
}

/// Parse a codec spec (`"identity"`, `"top_k(0.05)"`, `"top_k_i8"`)
/// into a live codec. Fraction defaults to 0.01 when absent; must be in
/// (0, 1].
pub fn parse(spec: &str) -> Result<Arc<dyn UpdateCodec>> {
    let head = spec_head(spec);
    let kind = match head.as_str() {
        "identity" => CodecKind::Identity,
        "top_k" => CodecKind::TopK,
        "top_k_f16" => CodecKind::TopKF16,
        "top_k_i8" => CodecKind::TopKI8,
        other => {
            return Err(Error::Config(format!("unknown codec {other:?}")));
        }
    };
    if kind == CodecKind::Identity {
        if spec_inner(spec).is_some() {
            return Err(Error::Config(
                "codec \"identity\" takes no argument".into(),
            ));
        }
        return Ok(Arc::new(SparseCodec { kind, frac: 1.0 }));
    }
    let frac = match spec_inner(spec) {
        Some(arg) => arg.parse::<f64>().map_err(|_| {
            Error::Config(format!("bad codec fraction {arg:?} in {spec:?}"))
        })?,
        None => DEFAULT_FRAC,
    };
    if !(frac > 0.0 && frac <= 1.0) {
        return Err(Error::Config(format!(
            "codec fraction must be in (0,1], got {frac}"
        )));
    }
    Ok(Arc::new(SparseCodec { kind, frac }))
}

/// Install the built-in codecs into a registry (called by
/// [`ComponentRegistry::with_builtins`]).
pub(crate) fn register_builtins(reg: &mut ComponentRegistry) {
    for name in ["identity", "top_k", "top_k_f16", "top_k_i8"] {
        reg.register_codec(name, Arc::new(parse));
    }
}

// ------------------------------------------------- client-flow wiring

/// Wraps any algorithm's client flow, replacing its compression stage
/// with a registered codec — `Config.codec` composes with every
/// algorithm without per-algorithm wiring. Train, decompress and
/// encrypt stages pass through to the inner flow untouched.
///
/// With `Config.codec_error_feedback` on, the flow additionally keeps a
/// per-client residual accumulator (EF-SGD style): the coordinates a
/// lossy `top_k*` encode dropped or quantized away are carried over and
/// added back into the *next* round's delta before encoding, so no
/// gradient signal is permanently lost — it is only delayed. Costs one
/// O(P) `f32` buffer per live client flow; off by default, and when off
/// the encode path is byte-identical to the residual-free wrapper.
pub struct CodecClientFlow {
    inner: Box<dyn ClientFlow>,
    codec: Arc<dyn UpdateCodec>,
    /// `Some` when error feedback is enabled; the vec is empty until the
    /// first lossy encode populates it.
    feedback: Option<Vec<f32>>,
}

impl CodecClientFlow {
    pub fn new(
        inner: Box<dyn ClientFlow>,
        codec: Arc<dyn UpdateCodec>,
        error_feedback: bool,
    ) -> CodecClientFlow {
        CodecClientFlow {
            inner,
            codec,
            feedback: if error_feedback { Some(Vec::new()) } else { None },
        }
    }
}

impl ClientFlow for CodecClientFlow {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn decompress(&mut self, payload: &ModelPayload) -> Result<ParamVec> {
        self.inner.decompress(payload)
    }

    fn train(
        &mut self,
        engine: &Engine,
        task: &TrainTask,
        params: ParamVec,
    ) -> Result<(ParamVec, TrainStats)> {
        self.inner.train(engine, task, params)
    }

    fn compress(
        &mut self,
        mut new_params: ParamVec,
        global: &ParamVec,
    ) -> Result<Update> {
        let Some(residual) = self.feedback.as_mut() else {
            // Error feedback off: the exact residual-free encode.
            return self.codec.encode(new_params, global);
        };
        // Fold the carried-over encoding error into this round's
        // parameters before the lossy encode sees them.
        if residual.len() == new_params.len() {
            for (p, r) in new_params.iter_mut().zip(residual.iter()) {
                *p += r;
            }
        } else if !residual.is_empty() {
            // Model size changed under us (new task/flow reuse): the old
            // residual is meaningless, drop it.
            residual.clear();
        }
        let update = self.codec.encode(new_params.clone(), global)?;
        // New residual = what we wanted to send minus what the server
        // will actually reconstruct from the encoded upload.
        let decoded = update.to_dense(global)?;
        residual.resize(new_params.len(), 0.0);
        for ((r, want), got) in
            residual.iter_mut().zip(new_params.iter()).zip(decoded.iter())
        {
            *r = want - got;
        }
        Ok(update)
    }

    fn encrypt(&mut self, update: Update) -> Result<Update> {
        self.inner.encrypt(update)
    }
}

/// Wrap a client-flow factory so every produced flow compresses through
/// `codec` (used by the registry when `Config.codec` is set);
/// `error_feedback` (`Config.codec_error_feedback`) threads the
/// per-client residual accumulator through.
pub fn wrap_client_factory(
    inner: ClientFlowFactory,
    codec: Arc<dyn UpdateCodec>,
    error_feedback: bool,
) -> ClientFlowFactory {
    Arc::new(move || {
        Box::new(CodecClientFlow::new(inner(), codec.clone(), error_feedback))
    })
}

// --------------------------------------------------- telemetry wiring

/// Telemetry-instrumented codec: times every `encode` into the
/// `codec.encode_ms` histogram and runs the `codec.encoded_bytes` /
/// `codec.dense_bytes` counters, so a run's end-of-job metrics snapshot
/// shows the realized compression ratio. Owners that hold a live
/// [`Telemetry`] handle wrap their codec in one of these; with telemetry
/// off each probe is a single branch on top of the inner encode.
pub struct TimedCodec {
    inner: Arc<dyn UpdateCodec>,
    tel: crate::obs::Telemetry,
}

impl TimedCodec {
    pub fn new(
        inner: Arc<dyn UpdateCodec>,
        tel: crate::obs::Telemetry,
    ) -> TimedCodec {
        TimedCodec { inner, tel }
    }
}

impl UpdateCodec for TimedCodec {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn spec(&self) -> String {
        self.inner.spec()
    }

    fn encode(&self, new_params: ParamVec, global: &ParamVec) -> Result<Update> {
        let dense_bytes = global.len() * 4;
        let sw = crate::util::clock::Stopwatch::start();
        let update = self.inner.encode(new_params, global)?;
        self.tel.observe_ms("codec.encode_ms", sw.elapsed_ms());
        self.tel.counter("codec.dense_bytes", dense_bytes as u64);
        self.tel
            .counter("codec.encoded_bytes", update.wire_bytes() as u64);
        Ok(update)
    }

    fn wire_bytes_for(&self, dense_bytes: usize) -> usize {
        self.inner.wire_bytes_for(dense_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::{AggContext, Aggregator, MeanAggregator};
    use crate::util::rng::Rng;

    fn random_vecs(seed: u64, p: usize) -> (ParamVec, ParamVec) {
        let mut rng = Rng::new(seed);
        let global =
            ParamVec((0..p).map(|_| rng.uniform() as f32 - 0.5).collect());
        let new = ParamVec(
            global
                .iter()
                .map(|g| g + (rng.uniform() as f32 - 0.5) * 0.2)
                .collect(),
        );
        (new, global)
    }

    fn encoded(u: &Update) -> &EncodedUpdate {
        match u {
            Update::Encoded(e) => e,
            other => panic!("expected Encoded, got {other:?}"),
        }
    }

    #[test]
    fn f16_conversion_roundtrips_representable_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1e-5] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back, v, "{v}");
        }
        // Subnormal f16 range survives the round trip too.
        let tiny = 2.0f32.powi(-15);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
        // Overflow saturates to inf, NaN stays NaN.
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e6)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Relative error of lossy conversions is bounded by 2^-11.
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let v = (rng.uniform() as f32 - 0.5) * 10.0;
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!(
                (back - v).abs() <= v.abs() * 4.9e-4 + 1e-7,
                "{v} -> {back}"
            );
        }
    }

    #[test]
    fn round_trip_error_bound_per_codec() {
        let p = 512;
        let (new, global) = random_vecs(7, p);
        let max_abs = new
            .iter()
            .zip(global.iter())
            .map(|(n, g)| (n - g).abs())
            .fold(0.0f32, f32::max);

        // identity and top_k(1.0) reconstruct exactly.
        let u = parse("identity").unwrap().encode(new.clone(), &global).unwrap();
        assert_eq!(u.to_dense(&global).unwrap().0, new.0);
        let u = parse("top_k(1.0)").unwrap().encode(new.clone(), &global).unwrap();
        for (got, want) in u.to_dense(&global).unwrap().iter().zip(new.iter()) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        // f16 keeps ~11 bits of mantissa.
        let u =
            parse("top_k_f16(1.0)").unwrap().encode(new.clone(), &global).unwrap();
        for (got, want) in u.to_dense(&global).unwrap().iter().zip(new.iter()) {
            assert!(
                (got - want).abs() <= want.abs() * 1e-3 + 1e-4,
                "{got} vs {want}"
            );
        }
        // i8 error is bounded by half a quantization step per chunk.
        let u =
            parse("top_k_i8(1.0)").unwrap().encode(new.clone(), &global).unwrap();
        let step = max_abs / 127.0;
        for (got, want) in u.to_dense(&global).unwrap().iter().zip(new.iter()) {
            assert!((got - want).abs() <= step, "{got} vs {want}");
        }
    }

    #[test]
    fn top_k_selects_largest_magnitude_coordinates() {
        let p = 100;
        let global = ParamVec::zeros(p);
        let mut new = ParamVec::zeros(p);
        // Magnitudes 3.0 > 2.5 > 2.0 at known spots, noise elsewhere.
        new[17] = -3.0;
        new[42] = 2.5;
        new[77] = -2.0;
        for i in 0..p {
            if new[i] == 0.0 {
                new[i] = 0.01 * ((i % 7) as f32 - 3.0);
            }
        }
        let u = parse("top_k(0.03)").unwrap().encode(new, &global).unwrap();
        let e = encoded(&u);
        assert_eq!(e.indices, vec![17, 42, 77]);
        assert_eq!(e.len, p);
        // Values preserved exactly in f32 mode, ascending index order.
        match &e.values {
            QuantizedValues::F32(v) => assert_eq!(v, &vec![-3.0, 2.5, -2.0]),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn content_hash_is_stable_across_encode_and_decode() {
        let (new, global) = random_vecs(11, 256);
        let codec = parse("top_k_i8(0.1)").unwrap();
        let a = codec.encode(new.clone(), &global).unwrap();
        let b = codec.encode(new, &global).unwrap();
        let (ea, eb) = (encoded(&a), encoded(&b));
        // Same input ⇒ same payload ⇒ same hash.
        assert_eq!(ea.content_hash, eb.content_hash);
        // Decoding (and re-verifying after) never perturbs the stamp.
        ea.verify().unwrap();
        let _ = ea.to_dense(&global).unwrap();
        ea.verify().unwrap();
        assert_eq!(ea.content_hash, ea.compute_hash());
    }

    #[test]
    fn tampered_payload_is_a_typed_integrity_error() {
        let (new, global) = random_vecs(13, 128);
        let u = parse("top_k(0.2)").unwrap().encode(new, &global).unwrap();
        let mut e = encoded(&u).clone();
        match &mut e.values {
            QuantizedValues::F32(v) => v[0] += 1.0,
            other => panic!("unexpected {other:?}"),
        }
        let err = e.verify().unwrap_err();
        assert!(matches!(err, Error::Integrity(_)), "{err}");
        assert!(err.to_string().starts_with("integrity error:"), "{err}");
        // A tampered index trips it too, through every decode path.
        let mut e2 = encoded(&u).clone();
        e2.indices[0] ^= 1;
        assert!(matches!(
            e2.to_dense(&global).unwrap_err(),
            Error::Integrity(_)
        ));
        let mut acc = vec![0.0f64; 128];
        assert!(matches!(
            e2.fold_into(&mut acc, 128, 1.0, 128).unwrap_err(),
            Error::Integrity(_)
        ));
    }

    #[test]
    fn wire_size_prediction_matches_actual_encoding() {
        for spec in
            ["top_k(0.05)", "top_k_f16(0.05)", "top_k_i8(0.05)", "top_k_i8(1.0)"]
        {
            let codec = parse(spec).unwrap();
            for p in [64usize, 1000, 4096] {
                let (new, global) = random_vecs(p as u64, p);
                let u = codec.encode(new, &global).unwrap();
                assert_eq!(
                    encoded(&u).encoded_len,
                    codec.wire_bytes_for(p * 4),
                    "{spec} at P={p}"
                );
                assert_eq!(u.wire_bytes(), encoded(&u).encoded_len);
            }
        }
        // Identity costs exactly the dense bytes — the digest guard.
        assert_eq!(parse("identity").unwrap().wire_bytes_for(1_600_000), 1_600_000);
    }

    #[test]
    fn streaming_fold_matches_decode_then_mean() {
        for threads in [0usize, 4] {
            let p = 8192;
            let global = Arc::new(random_vecs(17, p).1);
            let codec = parse("top_k_i8(0.3)").unwrap();
            let mut ctx = AggContext::new(global.clone()).expect_updates(6);
            ctx.threads = threads;
            ctx.parallel_threshold = 2;
            let mut streaming = MeanAggregator::from_ctx(&ctx);
            let mut reference = MeanAggregator::from_ctx(&ctx);
            for c in 0..6u64 {
                let (new, _) = random_vecs(100 + c, p);
                let w = 1.0 + c as f64;
                let u = codec.encode(new, &global).unwrap();
                reference.add(&Update::Dense(u.to_dense(&global).unwrap()), w)
                    .unwrap();
                streaming.add(&u, w).unwrap();
            }
            let want = reference.finish().unwrap();
            let got = streaming.finish().unwrap();
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    ((g - w) as f64).abs() < 1e-6,
                    "threads={threads} coord {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn codec_specs_parse_and_reject_bad_fractions() {
        assert_eq!(parse("identity").unwrap().spec(), "identity");
        assert_eq!(parse("top_k").unwrap().spec(), "top_k(0.01)");
        assert_eq!(parse("top_k_i8(0.05)").unwrap().spec(), "top_k_i8(0.05)");
        for bad in
            ["top_k(0)", "top_k(1.5)", "top_k(-0.1)", "top_k(x)", "identity(2)", "gzip"]
        {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn codec_client_flow_replaces_the_compress_stage() {
        let (new, global) = random_vecs(19, 64);
        let mut flow = CodecClientFlow::new(
            Box::new(crate::flow::DefaultClientFlow),
            parse("top_k(0.1)").unwrap(),
            false,
        );
        let u = flow.compress(new.clone(), &global).unwrap();
        assert!(matches!(u, Update::Encoded(_)));
        assert!(u.wire_bytes() < 64 * 4);
        // Identity wraps to a plain dense upload, byte-for-byte.
        let mut flow = CodecClientFlow::new(
            Box::new(crate::flow::DefaultClientFlow),
            parse("identity").unwrap(),
            false,
        );
        let u = flow.compress(new.clone(), &global).unwrap();
        assert_eq!(u, Update::Dense(new));
    }

    #[test]
    fn error_feedback_off_matches_the_plain_codec_byte_for_byte() {
        let (new, global) = random_vecs(43, 128);
        let codec = parse("top_k_i8(0.1)").unwrap();
        let mut flow = CodecClientFlow::new(
            Box::new(crate::flow::DefaultClientFlow),
            codec.clone(),
            false,
        );
        // Two consecutive rounds: with feedback disabled the wrapper
        // must be stateless and identical to calling the codec directly.
        for _ in 0..2 {
            let via_flow = flow.compress(new.clone(), &global).unwrap();
            let direct = codec.encode(new.clone(), &global).unwrap();
            assert_eq!(via_flow, direct);
        }
    }

    #[test]
    fn error_feedback_recovers_dropped_coordinates_on_the_next_round() {
        let global = ParamVec::zeros(4);
        let new = ParamVec(vec![1.0, 0.9, 0.0, 0.0]);
        // top_k(0.25) over P=4 keeps exactly one coordinate: round one
        // sends coord 0 (|1.0| > |0.9|) and drops coord 1.
        let make = |ef: bool| {
            CodecClientFlow::new(
                Box::new(crate::flow::DefaultClientFlow),
                parse("top_k(0.25)").unwrap(),
                ef,
            )
        };
        let mut with_ef = make(true);
        let mut without = make(false);
        for flow in [&mut with_ef, &mut without] {
            let first = flow.compress(new.clone(), &global).unwrap();
            let decoded = first.to_dense(&global).unwrap();
            assert!(decoded[0] != 0.0 && decoded[1] == 0.0);
        }
        // Round two, same training outcome. Without feedback coord 0
        // wins forever and coord 1's signal is lost; with feedback the
        // carried residual (0.9) doubles coord 1's effective delta to
        // 1.8, which now outranks coord 0 and ships.
        let second = without.compress(new.clone(), &global).unwrap();
        let decoded = second.to_dense(&global).unwrap();
        assert!(decoded[0] != 0.0 && decoded[1] == 0.0);
        let second = with_ef.compress(new.clone(), &global).unwrap();
        let decoded = second.to_dense(&global).unwrap();
        assert_eq!(decoded[0], 0.0, "satisfied coord 0 yields its slot");
        assert!(
            (decoded[1] - 1.8).abs() < 1e-6,
            "residual-corrected coord 1 ships: {}",
            decoded[1]
        );
    }

    #[test]
    fn error_feedback_is_inert_under_a_lossless_codec() {
        let (new, global) = random_vecs(7, 32);
        let mut flow = CodecClientFlow::new(
            Box::new(crate::flow::DefaultClientFlow),
            parse("identity").unwrap(),
            true,
        );
        for _ in 0..3 {
            // Identity reconstructs exactly, so the residual stays zero
            // and every round uploads the plain dense params.
            let u = flow.compress(new.clone(), &global).unwrap();
            assert_eq!(u, Update::Dense(new.clone()));
        }
    }

    #[test]
    fn timed_codec_counts_bytes_and_encode_latency() {
        use crate::obs::{NullSink, Telemetry};
        use crate::util::clock::VirtualClock;

        let (new, global) = random_vecs(31, 256);
        let tel = Telemetry::new(
            std::sync::Arc::new(VirtualClock::new()),
            std::sync::Arc::new(NullSink),
            None,
        );
        let timed = TimedCodec::new(parse("top_k(0.1)").unwrap(), tel.clone());
        assert_eq!(timed.name(), "top_k");
        assert_eq!(timed.spec(), "top_k(0.1)");
        let u = timed.encode(new.clone(), &global).unwrap();
        assert_eq!(tel.counter_value("codec.dense_bytes"), 256 * 4);
        assert_eq!(
            tel.counter_value("codec.encoded_bytes"),
            u.wire_bytes() as u64
        );
        let (p50, _, p99) = tel.quantiles_ms("codec.encode_ms").unwrap();
        assert!(p50 >= 0.0 && p99 >= p50);
        // Wire-size prediction passes through to the inner codec.
        assert_eq!(
            timed.wire_bytes_for(256 * 4),
            parse("top_k(0.1)").unwrap().wire_bytes_for(256 * 4)
        );
        // Off telemetry: the wrapper still encodes, probes are inert.
        let off = TimedCodec::new(parse("top_k(0.1)").unwrap(), Telemetry::off());
        let u2 = off.encode(new, &global).unwrap();
        assert_eq!(u.wire_bytes(), u2.wire_bytes());
    }
}
