//! Deployment manager (paper §VII): seamless and scalable deployment.
//!
//! Docker/Kubernetes are substituted by **process containers** (DESIGN.md
//! substitution #3): each FL component (registry, client services) runs as
//! a supervised OS process of the easyfl binary with a role subcommand —
//! the same lifecycle (build → deploy → register → train → teardown) and
//! the same discovery problem, without a container runtime in the image.
//! The deployment manager is what the Fig 8 / deployment-time experiments
//! drive.

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::comm::protocol::Message;
use crate::comm::rpc;
use crate::config::Config;
use crate::error::{Error, Result};

/// A supervised component process ("container").
pub struct Container {
    pub name: String,
    pub addr: String,
    child: Child,
}

impl Container {
    /// Liveness probe (Ping → Pong).
    pub fn is_ready(&self) -> bool {
        rpc::call(&self.addr, &Message::Ping)
            .map(|m| m == Message::Pong)
            .unwrap_or(false)
    }

    /// Block until ready or timeout.
    pub fn wait_ready(&self, timeout: Duration) -> Result<()> {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if self.is_ready() {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        Err(Error::Deploy(format!("{} not ready within {timeout:?}", self.name)))
    }
}

/// The deployment: spawns, probes and tears down component processes.
#[derive(Default)]
pub struct Deployment {
    containers: Vec<Container>,
    next_port: u16,
}

impl Deployment {
    /// Allocate ports from `base_port` upward.
    pub fn new(base_port: u16) -> Deployment {
        Deployment { containers: Vec::new(), next_port: base_port }
    }

    fn alloc_port(&mut self) -> u16 {
        let p = self.next_port;
        self.next_port += 1;
        p
    }

    fn spawn(&mut self, name: &str, port: u16, args: &[String]) -> Result<&Container> {
        let exe = std::env::current_exe()
            .map_err(|e| Error::Deploy(format!("current_exe: {e}")))?;
        let child = Command::new(exe)
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .map_err(|e| Error::Deploy(format!("spawn {name}: {e}")))?;
        self.containers.push(Container {
            name: name.to_string(),
            addr: format!("127.0.0.1:{port}"),
            child,
        });
        Ok(self.containers.last().unwrap())
    }

    /// Deploy a registry service; returns its address.
    pub fn deploy_registry(&mut self) -> Result<String> {
        let port = self.alloc_port();
        let args = vec![
            "registry".to_string(),
            "--port".to_string(),
            port.to_string(),
        ];
        self.spawn("registry", port, &args)?;
        let c = self.containers.last().unwrap();
        c.wait_ready(Duration::from_secs(10))?;
        Ok(c.addr.clone())
    }

    /// Deploy one client service that self-registers with the registry.
    pub fn deploy_client(
        &mut self,
        cfg: &Config,
        client_index: usize,
        registry_addr: &str,
    ) -> Result<String> {
        let port = self.alloc_port();
        let args = vec![
            "client".to_string(),
            "--port".to_string(),
            port.to_string(),
            "--registry".to_string(),
            registry_addr.to_string(),
            "--client-index".to_string(),
            client_index.to_string(),
            "--dataset".to_string(),
            cfg.dataset.name().to_string(),
            "--partition".to_string(),
            cfg.partition.name(),
            "--num-clients".to_string(),
            cfg.num_clients.to_string(),
            "--clients-per-round".to_string(),
            cfg.clients_per_round.min(cfg.num_clients.max(1)).to_string(),
            "--max-samples".to_string(),
            cfg.max_samples.to_string(),
            "--seed".to_string(),
            cfg.seed.to_string(),
            "--artifacts".to_string(),
            cfg.artifacts_dir.display().to_string(),
            "--batch-size".to_string(),
            cfg.batch_size.to_string(),
            "--algorithm".to_string(),
            cfg.algorithm.clone(),
            "--fedprox-mu".to_string(),
            cfg.fedprox_mu.to_string(),
            "--stc-sparsity".to_string(),
            cfg.stc_sparsity.to_string(),
        ];
        self.spawn(&format!("client-{client_index}"), port, &args)?;
        Ok(self.containers.last().unwrap().addr.clone())
    }

    /// Wait for all deployed containers to answer pings.
    pub fn wait_all_ready(&self, timeout: Duration) -> Result<()> {
        for c in &self.containers {
            c.wait_ready(timeout)?;
        }
        Ok(())
    }

    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// Tear everything down (also done on drop).
    pub fn teardown(&mut self) {
        for c in &mut self.containers {
            let _ = c.child.kill();
            let _ = c.child.wait();
        }
        self.containers.clear();
    }
}

impl Drop for Deployment {
    fn drop(&mut self) {
        self.teardown();
    }
}
