//! Surrogate training: trace-driven loss/accuracy curves.
//!
//! SimNet's default backend replaces real gradient computation with a
//! closed-form convergence curve keyed by the federation's *partition
//! skew* (via [`crate::data::partition::label_skew`]) — that's what lets
//! a 100k-client, 500-round run finish in seconds while preserving the
//! orderings the paper's Table IV reports: IID converges higher and
//! faster than dir(0.5), which beats class(2). Progress is measured in
//! *effective aggregated rounds*: a sync round that aggregates only half
//! its target cohort contributes 0.5, and async updates are discounted
//! by their staleness weight, so participation and staleness visibly
//! bend the curve.

use crate::data::partition::label_skew;
use crate::data::ClientSpec;

/// Exponential-saturation accuracy / decay loss curves.
#[derive(Debug, Clone, PartialEq)]
pub struct SurrogateModel {
    /// Average total-variation label skew in [0, 1].
    pub skew: f64,
    /// Asymptotic test accuracy.
    pub acc_ceiling: f64,
    /// Convergence rate per effective round.
    pub rate: f64,
    /// Initial training loss (≈ ln(num_classes) for random init).
    pub loss_start: f64,
    /// Asymptotic training loss.
    pub loss_floor: f64,
}

impl SurrogateModel {
    /// Build from an explicit skew degree (0 = IID, →1 = single-class).
    pub fn from_skew(num_classes: usize, skew: f64) -> SurrogateModel {
        let skew = skew.clamp(0.0, 1.0);
        SurrogateModel {
            skew,
            // Table IV shape: skewed partitions plateau lower...
            acc_ceiling: (0.97 - 0.45 * skew).clamp(0.05, 0.97),
            // ...and converge slower.
            rate: 0.08 * (1.0 - 0.6 * skew).max(0.1),
            loss_start: (num_classes.max(2) as f64).ln(),
            loss_floor: 0.05 + 0.8 * skew,
        }
    }

    /// Build from the federation's client specs (measures their skew).
    pub fn from_clients(num_classes: usize, clients: &[ClientSpec]) -> SurrogateModel {
        SurrogateModel::from_skew(num_classes, label_skew(clients))
    }

    /// Test accuracy after `progress` effective rounds.
    pub fn accuracy(&self, progress: f64) -> f64 {
        self.acc_ceiling * (1.0 - (-self.rate * progress.max(0.0)).exp())
    }

    /// Training loss after `progress` effective rounds.
    pub fn loss(&self, progress: f64) -> f64 {
        self.loss_floor
            + (self.loss_start - self.loss_floor)
                * (-self.rate * progress.max(0.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, Partition};
    use crate::data::partition::build_clients;
    use crate::util::rng::Rng;

    #[test]
    fn accuracy_rises_and_loss_falls_monotonically() {
        let m = SurrogateModel::from_skew(10, 0.3);
        let mut prev_acc = -1.0;
        let mut prev_loss = f64::MAX;
        for r in 0..400 {
            let acc = m.accuracy(r as f64);
            let loss = m.loss(r as f64);
            assert!(acc > prev_acc, "round {r}");
            assert!(loss < prev_loss, "round {r}");
            assert!((0.0..1.0).contains(&acc));
            assert!(loss > 0.0);
            prev_acc = acc;
            prev_loss = loss;
        }
        assert!(m.accuracy(1e6) <= m.acc_ceiling + 1e-12);
    }

    #[test]
    fn skew_lowers_ceiling_and_slows_convergence() {
        let iid = SurrogateModel::from_skew(10, 0.0);
        let skewed = SurrogateModel::from_skew(10, 0.8);
        assert!(iid.acc_ceiling > skewed.acc_ceiling);
        assert!(iid.rate > skewed.rate);
        assert!(iid.accuracy(50.0) > skewed.accuracy(50.0));
        assert!(iid.loss(50.0) < skewed.loss(50.0));
    }

    #[test]
    fn partition_ordering_matches_table4() {
        let mk = |p| {
            let mut rng = Rng::new(11);
            let clients =
                build_clients(DatasetKind::Cifar10, 80, p, false, 0, &mut rng)
                    .unwrap();
            SurrogateModel::from_clients(10, &clients)
        };
        let iid = mk(Partition::Iid);
        let dir = mk(Partition::Dirichlet(0.5));
        let class2 = mk(Partition::ByClass(2));
        let acc = |m: &SurrogateModel| m.accuracy(200.0);
        assert!(
            acc(&iid) > acc(&dir) && acc(&dir) > acc(&class2),
            "{} {} {}",
            acc(&iid),
            acc(&dir),
            acc(&class2)
        );
    }

    #[test]
    fn partial_participation_slows_progress() {
        let m = SurrogateModel::from_skew(10, 0.2);
        // 100 rounds at half participation ≙ 50 effective rounds.
        assert!(m.accuracy(100.0) > m.accuracy(50.0));
    }
}
