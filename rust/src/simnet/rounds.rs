//! The SimNet engines: synchronous deadline rounds and async FedBuff.
//!
//! Two round engines run on the same event queue, client population,
//! cost model and availability traces:
//!
//! * **Sync** — each round over-selects `K · over_select` clients from
//!   the available pool, allocates them to the `num_devices` virtual
//!   devices with the *real* scheduler [`Strategy`] (GreedyAda / Random /
//!   Slowest — unchanged), aggregates as soon as the first `K` reports
//!   arrive or the deadline fires, and drops the stragglers back into
//!   the pool.
//! * **Async (FedBuff)** — keeps up to `async_concurrency` clients
//!   training at all times and aggregates every `async_buffer` arrivals
//!   with staleness-discounted weights `(1 + staleness)^-α`.
//!
//! Training is surrogate by default (seconds for 100k clients × 500
//! rounds); setting `sim.real_training` plugs the real [`Server`] /
//! Engine in for small cohorts.

use std::collections::BTreeSet;
use std::path::Path;
use std::sync::Arc;

use crate::aggregate::{AggContext, FedBuffBuffer};
use crate::config::{Config, SimMode};
use crate::coordinator::Server;
use crate::data::partition::build_clients;
use crate::data::synth;
use crate::error::{Error, Result};
use crate::flow::Update;
use crate::gossip::{GossipEngine, PeerGraph};
use crate::hierarchy::{HierPlane, Topology};
use crate::model::ParamVec;
use crate::obs::{Histogram, Span, Telemetry};
use crate::registry;
use crate::runtime::checkpoint;
use crate::runtime::{CheckpointReader, CheckpointWriter};
use crate::scheduler::{make_strategy, Strategy};
use crate::tracking::{RoundMetrics, Tracker};
use crate::util::clock::{Stopwatch, VirtualClock};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::adversary::AdversaryModel;
use super::chaos::Fault;
use super::churn::{ChurnCredits, ChurnModel};
use super::client_state::{AvailabilityModel, ClientPhase, ClientState, Pool};
use super::cost::CostModel;
use super::events::{EventKind, EventQueue, QueueSnapshot};
use super::surrogate::SurrogateModel;

/// Skew is a population statistic; estimating it from a bounded sample
/// keeps million-client federations cheap to set up.
const SKEW_SAMPLE_CLIENTS: usize = 10_000;

/// Parameter length of the surrogate update plane the adversary path
/// reduces through the real registered aggregators: wide enough for
/// per-coordinate rank statistics to be meaningful, small enough that a
/// reduction per aggregation costs nothing.
const SURROGATE_P: usize = 32;

/// Outcome of one SimNet run — the numbers the `simulate` CLI prints
/// and [`crate::platform::SimSweep`] tabulates.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// "sync" | "async".
    pub mode: String,
    /// Scheduler strategy name (sync engine only).
    pub allocation: String,
    pub availability: String,
    pub num_clients: usize,
    /// Rounds actually aggregated.
    pub rounds: usize,
    /// Virtual time of the last aggregation.
    pub makespan_ms: f64,
    /// Events processed (throughput = events / wall_ms).
    pub events: u64,
    pub selected: u64,
    pub reported: u64,
    pub dropped: u64,
    /// reported / selected.
    pub participation: f64,
    /// Mean staleness of aggregated updates (0 for sync).
    pub avg_staleness: f64,
    pub final_accuracy: f64,
    pub final_train_loss: f64,
    pub comm_bytes: usize,
    /// Order-sensitive digest of the full event trace; equal seeds ⇒
    /// equal digests.
    pub trace_digest: u64,
    /// Real elapsed wall time of the run.
    pub wall_ms: f64,
    /// True when every configured round actually aggregated; false for
    /// truncated runs (e.g. a starved async engine broke out early).
    pub converged: bool,
    /// True when a cancellation probe stopped the run at a round
    /// boundary (see [`SimNet::run_cancellable`]); the report covers the
    /// rounds that completed before the cancel.
    pub cancelled: bool,
    /// Registered aggregator the run reduced with ("mean" unless
    /// `Config.agg` overrode it).
    pub aggregator: String,
    /// Federation topology the run simulated ("flat" | "edges(n)" | ...).
    pub topology: String,
    /// Bytes that crossed into the cloud aggregator: every reporter's
    /// update for a flat topology, one dense partial per active edge per
    /// aggregation for a hierarchical one — the fan-in headline
    /// `examples/hier_scale.rs` benchmarks.
    pub bytes_to_cloud: usize,
    /// Adversary model configured for the run (inert at fraction 0).
    pub adversary: String,
    /// Fraction of the population behaving Byzantine.
    pub adversary_frac: f64,
    /// Mean per-coordinate distance of the aggregate outside the honest
    /// reporters' envelope, averaged over aggregations — 0 both when the
    /// aggregator contained every attack and when the adversary plane
    /// was off.
    pub envelope_deviation: f64,
    /// p50 of per-report client service time (compute + upload, virtual
    /// ms) over the whole run — the tail the deadline actually fights.
    pub client_ms_p50: f64,
    pub client_ms_p95: f64,
    pub client_ms_p99: f64,
    /// p50 of the *wall-clock* time each aggregation-window fold took on
    /// the host (straggler sweep + robust reduce + fan-in + metrics).
    pub fold_ms_p50: f64,
    pub fold_ms_p95: f64,
    pub fold_ms_p99: f64,
    /// Faults the chaos plane injected over the run (0 with `chaos`
    /// empty — the plane is completely inert then).
    pub faults_injected: u64,
    /// Final consensus distance of a gossip run: the maximum pairwise
    /// L∞ parameter divergence across honest clients (exact, not
    /// sampled). 0 for the server engines, which hold one global model
    /// by construction.
    pub consensus_distance: f64,
}

impl SimReport {
    /// Events processed per second of wall time.
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }

    /// Rounds aggregated per second of wall time.
    pub fn rounds_per_sec(&self) -> f64 {
        self.rounds as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }

    /// Throughput fields as a JSON object — merged into `BENCH_*.json`
    /// artifacts by [`crate::util::bench::write_bench`].
    pub fn bench_fields(&self) -> Json {
        obj([
            ("clients", Json::Num(self.num_clients as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("events_per_sec", Json::Num(self.events_per_sec())),
            ("rounds_per_sec", Json::Num(self.rounds_per_sec())),
            ("makespan_ms", Json::Num(self.makespan_ms)),
            ("client_ms_p50", Json::Num(self.client_ms_p50)),
            ("client_ms_p95", Json::Num(self.client_ms_p95)),
            ("client_ms_p99", Json::Num(self.client_ms_p99)),
            ("fold_ms_p50", Json::Num(self.fold_ms_p50)),
            ("fold_ms_p95", Json::Num(self.fold_ms_p95)),
            ("fold_ms_p99", Json::Num(self.fold_ms_p99)),
            ("consensus_distance", Json::Num(self.consensus_distance)),
        ])
    }

    /// Throughput benchmark JSON (the `BENCH_simnet.json` CI artifact);
    /// shared by the `simulate --bench-out` flag and `simnet_scale`.
    pub fn bench_json(&self) -> String {
        let mut text = self.bench_fields().to_pretty();
        text.push('\n');
        text
    }

    /// Project onto the training [`crate::api::Report`] shape so SimNet
    /// jobs ride the same `Platform` plumbing as real sessions.
    pub fn to_report(&self) -> crate::api::Report {
        crate::api::Report {
            final_accuracy: self.final_accuracy,
            best_accuracy: self.final_accuracy,
            final_train_loss: self.final_train_loss,
            avg_round_ms: if self.rounds > 0 {
                self.makespan_ms / self.rounds as f64
            } else {
                0.0
            },
            comm_bytes: self.comm_bytes,
            rounds: self.rounds,
            converged: self.converged,
        }
    }
}

/// A discrete-event federation simulator over one [`Config`].
pub struct SimNet {
    cfg: Config,
    availability: AvailabilityModel,
    cost: CostModel,
    surrogate: SurrogateModel,
    strategy: Box<dyn Strategy>,
    tracker: Arc<Tracker>,
    queue: EventQueue,
    clients: Vec<ClientState>,
    pool: Pool,
    rng: Rng,
    /// Real-Engine backend for small cohorts (`sim.real_training`).
    server: Option<Server>,
    /// Global model version = aggregations performed.
    version: usize,
    /// Effective aggregated rounds (drives the surrogate curves).
    progress: f64,
    total_selected: u64,
    total_reported: u64,
    total_dropped: u64,
    staleness_sum: f64,
    staleness_n: u64,
    /// Set when a cancellation probe fired at a round boundary.
    cancelled: bool,
    /// Registered aggregator the adversary plane (and report) names.
    agg_name: String,
    /// Aggregation-tree shape; non-flat runs reduce per edge, ship one
    /// partial per active edge to the cloud, and pay an edge hop per
    /// aggregation. Flat runs are bit-identical to the pre-hierarchy
    /// timeline.
    topology: Topology,
    /// Cloud fan-in accumulated over the run (see
    /// [`SimReport::bytes_to_cloud`]).
    bytes_to_cloud: usize,
    /// Wire size of one client upload: `model_bytes` when no codec is
    /// configured, the codec's predicted encoded size otherwise. Every
    /// uplink costing site (upload delay, `comm_bytes`, flat
    /// `bytes_to_cloud`) charges this instead of the flat dense size.
    uplink_bytes: usize,
    /// Attack corrupting Byzantine clients' surrogate deltas.
    adversary: AdversaryModel,
    /// Per-client Byzantine flag, fixed at setup (seed-deterministic).
    adversarial: Vec<bool>,
    /// Dedicated adversary RNG: forked off the seed, never off the main
    /// stream, so `adversary_frac = 0` burns nothing and the event trace
    /// is identical with the plane on or off.
    adv_rng: Rng,
    env_dev_sum: f64,
    env_dev_n: u64,
    /// Telemetry plane. Spans carry *virtual* time: `vclock` mirrors the
    /// event queue's clock, written only when telemetry is on. Probes
    /// draw no RNG and push no events, so `telemetry = off` timelines
    /// are bit-identical (regression-tested below).
    tel: Telemetry,
    vclock: Arc<VirtualClock>,
    /// Per-report client service times (virtual ms), whole run.
    client_hist: Histogram,
    /// Wall-clock latency of each aggregation-window fold.
    fold_hist: Histogram,
    /// Elastic-membership model applied between rounds (`"none"` = off).
    churn: ChurnModel,
    /// Dedicated churn RNG: joiner device/bandwidth/phase and leaver
    /// picks draw only here, so `"none"` burns nothing and pre-churn
    /// trace digests are bit-identical.
    churn_rng: Rng,
    /// Fractional per-round join/leave credit (checkpointed so resumed
    /// runs churn exactly like the uninterrupted one).
    churn_credits: ChurnCredits,
    /// Clients retired by churn: pending events for them pop inert and
    /// they never re-enter the pool.
    departed: Vec<bool>,
    /// Chaos plane, pre-resolved from `Config.chaos`.
    kill_at: Option<usize>,
    drop_frac: Option<f64>,
    partitioned: Option<usize>,
    corrupt_ckpt: bool,
    /// `drop_midframe(f)`: reports cut mid-frame in transit.
    midframe_frac: Option<f64>,
    /// `stall_frames(f, ms)`: reports stalling partially written, then
    /// completing `ms` later.
    stall: Option<(f64, f64)>,
    /// Dedicated chaos RNG (`drop_frames` draws; an empty fault list
    /// burns nothing).
    chaos_rng: Rng,
    /// Faults injected so far (mirrors the `chaos.faults` counter).
    faults_injected: u64,
    /// Rounds / comm bytes completed before this process when resuming
    /// from a checkpoint; the in-memory tracker only sees the resumed
    /// segment, so reports add these offsets back.
    base_rounds: usize,
    base_comm_bytes: usize,
    /// Gossip state matrix carried out of a checkpoint restore until
    /// `run_gossip` hands it to the engine (`None` otherwise).
    gossip_states: Option<Vec<f32>>,
    /// Latest consensus distance of a gossip run (0 for server engines).
    consensus_distance: f64,
}

/// Engine-loop locals restored from a checkpoint (everything else lives
/// on [`SimNet`] fields and is restored in place).
struct ResumeAux {
    rounds_done: usize,
    makespan: f64,
    t_last: f64,
}

/// Rebuild one RNG stream from its checkpointed `(state, spare)` pair.
fn take_rng(r: &mut CheckpointReader) -> Result<Rng> {
    let state = r.take_u64()?;
    let spare = r.take_opt_f64()?;
    Ok(Rng::restore(state, spare))
}

impl SimNet {
    /// Build a simulator with its own in-memory tracker.
    pub fn from_config(cfg: &Config) -> Result<SimNet> {
        let label = format!(
            "simnet-{}-{}-{}-{}",
            cfg.sim.mode.name(),
            cfg.allocation.name(),
            cfg.partition.name(),
            cfg.seed
        );
        Self::with_tracker(cfg, Arc::new(Tracker::new(&label)))
    }

    /// Build a simulator recording into an existing tracker.
    pub fn with_tracker(cfg: &Config, tracker: Arc<Tracker>) -> Result<SimNet> {
        cfg.validate()?;
        let num_clients = if cfg.num_clients > 0 {
            cfg.num_clients
        } else {
            synth::natural_clients(cfg.dataset)
        };
        let availability =
            registry::with_global(|r| r.availability(&cfg.sim.availability))?;
        let cost =
            registry::with_global(|r| r.cost_model(&cfg.sim.cost_model, cfg))?;
        let adversary =
            registry::with_global(|r| r.adversary(&cfg.sim.adversary))?;
        let topology = registry::with_global(|r| r.topology(&cfg.topology))?;
        let churn = registry::with_global(|r| r.churn(&cfg.sim.churn))?;
        // Chaos plane: resolve every fault spec up front so a bad one
        // fails fast, and collapse the list into per-kind knobs.
        let mut kill_at = None;
        let mut drop_frac = None;
        let mut partitioned = None;
        let mut corrupt_ckpt = false;
        let mut midframe_frac = None;
        let mut stall = None;
        for spec in &cfg.chaos {
            match registry::with_global(|r| r.fault(spec))? {
                Fault::KillServerAtRound { round } => kill_at = Some(round),
                Fault::DropFrames { frac } => drop_frac = Some(frac),
                Fault::PartitionEdge { cluster } => {
                    partitioned = Some(cluster)
                }
                Fault::CorruptCheckpoint => corrupt_ckpt = true,
                Fault::DropMidframe { frac } => midframe_frac = Some(frac),
                Fault::StallFrames { frac, delay_ms } => {
                    stall = Some((frac, delay_ms))
                }
            }
        }
        if partitioned.is_some() && topology.is_flat() {
            return Err(Error::Config(
                "partition_edge needs a hierarchical topology (a flat run \
                 has no edge clusters to partition)"
                    .into(),
            ));
        }
        // Gossip cross-validation: the peer engine and the peer shapes
        // come as a pair, and the engine only composes with the planes
        // that make sense without a server.
        let gossip = cfg.sim.engine == "gossip";
        if gossip != topology.is_peer() {
            return Err(Error::Config(if gossip {
                format!(
                    "sim.engine = \"gossip\" needs a peer topology \
                     (gossip(k) | ring), got {:?}",
                    topology.name()
                )
            } else {
                format!(
                    "peer topology {:?} needs sim.engine = \"gossip\"",
                    topology.name()
                )
            }));
        }
        if gossip {
            if cfg.sim.real_training {
                return Err(Error::Config(
                    "gossip engine is surrogate-only (sim.real_training \
                     is incompatible)"
                        .into(),
                ));
            }
            if cfg.sim.churn != "none" {
                return Err(Error::Config(
                    "gossip engine needs sim.churn = \"none\" (the peer \
                     graph is fixed for the run)"
                        .into(),
                ));
            }
            if partitioned.is_some() {
                return Err(Error::Config(
                    "partition_edge targets edge clusters; a gossip run \
                     has none"
                        .into(),
                ));
            }
            let k = topology.peer_degree().unwrap_or(0);
            PeerGraph::validate_dims(
                if k == 2 { "ring" } else { "gossip" },
                k,
                num_clients,
            )?;
        }
        let agg_name = cfg.agg.clone().unwrap_or_else(|| "mean".to_string());
        if cfg.agg.is_some() || cfg.sim.adversary_frac > 0.0 || gossip {
            // Fail fast on an unknown or misconfigured aggregator before
            // the run starts (the probe also validates trim/clip knobs).
            let probe =
                AggContext::from_config(Arc::new(ParamVec::zeros(1)), cfg);
            registry::with_global(|r| r.aggregator(&agg_name, &probe))?;
        }
        if let Some(edge_agg) = &cfg.edge_agg {
            let probe =
                AggContext::from_config(Arc::new(ParamVec::zeros(1)), cfg);
            registry::with_global(|r| r.aggregator(edge_agg, &probe))?;
        }
        // Codec-compressed uplinks change the wire size every costing
        // site charges. The surrogate plane carries no real updates, so
        // the encoded size is a deterministic per-run constant: the
        // codec's predicted wire size for a dense `model_bytes` update.
        // No codec — or `"identity"` — yields `model_bytes` exactly, and
        // the probe draws no RNG, so unencoded trace digests stay
        // bit-identical.
        let uplink_bytes = match &cfg.codec {
            Some(spec) => {
                let codec = registry::with_global(|r| r.codec(spec))?;
                codec.wire_bytes_for(cost.model_bytes)
            }
            None => cost.model_bytes,
        };
        let mut rng = Rng::new(cfg.seed ^ 0x5349_4D4E_4554); // "SIMNET"

        // The adversary stream is seeded independently of the main RNG:
        // flipping `adversary_frac` must never shift selection,
        // scheduling or availability draws (trace digests stay equal).
        let mut adv_rng = Rng::new(cfg.seed ^ 0x4144_5645_5253); // "ADVERS"

        // Churn and chaos get the same treatment: dedicated streams that
        // burn nothing while their plane is off, so every pre-existing
        // digest survives the knobs being merely *available*.
        let churn_rng = Rng::new(cfg.seed ^ 0x4348_5552_4E21); // "CHURN!"
        let chaos_rng = Rng::new(cfg.seed ^ 0x4348_414F_5321); // "CHAOS!"

        // Partition skew drives the surrogate curves; estimate it from a
        // bounded client sample so huge populations stay cheap.
        let (num_classes, _, _) = synth::shape_of(cfg.dataset);
        let specs = build_clients(
            cfg.dataset,
            num_clients.min(SKEW_SAMPLE_CLIENTS),
            cfg.partition,
            cfg.unbalanced,
            cfg.max_samples,
            &mut rng.fork(0x5045),
        )?;
        let surrogate = SurrogateModel::from_clients(num_classes, &specs);

        let mut clients = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let device = cost.sample_device(&mut rng);
            let bandwidth = cost.sample_bandwidth(&mut rng);
            clients.push(ClientState::new(device, bandwidth));
        }

        let server = if cfg.sim.real_training {
            // SimNet owns the run's trace/metrics output files; the
            // backing real-training server keeps its own (wall-clock)
            // telemetry off so the two never write the same paths.
            let mut inner = cfg.clone();
            inner.telemetry = false;
            inner.trace_out = None;
            inner.metrics_out = None;
            let mut builder = crate::api::SessionBuilder::new(inner);
            Some(builder.build()?.build_server()?)
        } else {
            None
        };

        // Seed-deterministic Byzantine cohort: exactly ⌊frac·n⌉ clients,
        // drawn from the dedicated adversary stream.
        let mut adversarial = vec![false; num_clients];
        if cfg.sim.adversary_frac > 0.0 {
            let k = ((cfg.sim.adversary_frac * num_clients as f64).round()
                as usize)
                .min(num_clients.saturating_sub(1));
            for c in adv_rng.choose_indices(num_clients, k) {
                adversarial[c] = true;
            }
        }

        tracker.set_config("sim_mode", cfg.sim.mode.name().to_string());
        tracker.set_config("availability", availability.name());
        tracker.set_config("cost_model", cost.name.clone());
        tracker.set_config("allocation", cfg.allocation.name().to_string());
        tracker.set_config("num_clients", num_clients.to_string());
        tracker.set_config("aggregator", agg_name.clone());
        tracker.set_config("topology", topology.name());
        if let Some(codec) = &cfg.codec {
            tracker.set_config("codec", codec.clone());
        }
        if cfg.sim.adversary_frac > 0.0 {
            tracker.set_config("adversary", adversary.name());
            tracker
                .set_config("adversary_frac", cfg.sim.adversary_frac.to_string());
        }
        if !churn.is_none() {
            tracker.set_config("churn", churn.name());
        }
        if !cfg.chaos.is_empty() {
            tracker.set_config("chaos", cfg.chaos.join(","));
        }
        if gossip {
            tracker.set_config("engine", "gossip".to_string());
            tracker
                .set_config("gossip_rounds", cfg.sim.gossip_rounds.to_string());
        }

        let vclock = Arc::new(VirtualClock::new());
        let tel = Telemetry::from_config(cfg, vclock.clone())?;
        tracker.set_telemetry(tel.clone());

        Ok(SimNet {
            strategy: make_strategy(
                cfg.allocation,
                cfg.default_client_time_ms,
                cfg.profile_momentum,
            ),
            availability,
            cost,
            surrogate,
            tracker,
            queue: EventQueue::new(),
            pool: Pool::new(num_clients),
            clients,
            rng,
            server,
            version: 0,
            progress: 0.0,
            total_selected: 0,
            total_reported: 0,
            total_dropped: 0,
            staleness_sum: 0.0,
            staleness_n: 0,
            cancelled: false,
            agg_name,
            topology,
            bytes_to_cloud: 0,
            uplink_bytes,
            adversary,
            adversarial,
            adv_rng,
            env_dev_sum: 0.0,
            env_dev_n: 0,
            tel,
            vclock,
            client_hist: Histogram::new(),
            fold_hist: Histogram::new(),
            churn,
            churn_rng,
            churn_credits: ChurnCredits::default(),
            departed: vec![false; num_clients],
            kill_at,
            drop_frac,
            partitioned,
            corrupt_ckpt,
            midframe_frac,
            stall,
            chaos_rng,
            faults_injected: 0,
            base_rounds: 0,
            base_comm_bytes: 0,
            gossip_states: None,
            consensus_distance: 0.0,
            cfg: cfg.clone(),
        })
    }

    pub fn tracker(&self) -> Arc<Tracker> {
        self.tracker.clone()
    }

    /// The run's telemetry handle (off unless the config enabled it).
    pub fn telemetry(&self) -> Telemetry {
        self.tel.clone()
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Lifecycle phase of one client (tests / diagnostics).
    pub fn client_phase(&self, client: usize) -> ClientPhase {
        self.clients[client].phase
    }

    /// Size of the available pool right now.
    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }

    /// Run the configured engine to completion.
    pub fn run(&mut self) -> Result<SimReport> {
        self.run_cancellable(&|| false)
    }

    /// Run, polling `cancel` at every aggregation boundary. A triggered
    /// probe stops the simulation, releases every client, and returns a
    /// partial report with [`SimReport::cancelled`] set — this is what
    /// [`crate::platform::Platform::submit_sim`] jobs poll
    /// `JobCtx::cancelled` through.
    pub fn run_cancellable(
        &mut self,
        cancel: &dyn Fn() -> bool,
    ) -> Result<SimReport> {
        // Resume before dispatching so both engines start from the
        // restored event queue / RNG streams / population instead of
        // re-seeding them.
        let resume = match self.cfg.resume_from.clone() {
            Some(path) => Some(self.restore_checkpoint(&path)?),
            None => None,
        };
        if self.cfg.sim.engine == "gossip" {
            return self.run_gossip(cancel, resume);
        }
        match self.cfg.sim.mode {
            SimMode::Sync => self.run_sync(cancel, resume),
            SimMode::Async => self.run_async(cancel, resume),
        }
    }

    // ------------------------------------------------------ population

    /// Seed every client's availability trace and initial pool state.
    fn init_population(&mut self) {
        for c in 0..self.clients.len() {
            let phase = self.availability.sample_phase_ms(&mut self.rng);
            let online = self.availability.initial_online(phase, &mut self.rng);
            self.clients[c].avail_phase_ms = phase;
            self.clients[c].online = online;
            self.clients[c].release();
            if online {
                self.pool.insert(c);
            }
            let next =
                self.availability.next_toggle_ms(online, phase, 0.0, &mut self.rng);
            if next.is_finite() {
                let kind = if online {
                    EventKind::Offline { client: c }
                } else {
                    EventKind::Online { client: c }
                };
                self.queue.push(next, kind);
            }
        }
    }

    /// Apply an availability flip and schedule the next one.
    fn handle_toggle(&mut self, client: usize, online: bool, now_ms: f64) {
        if self.departed[client] {
            // Churned-out clients keep their pending toggle events in the
            // queue (popping them still folds into the trace digest
            // deterministically) but the flips themselves are inert: the
            // client never re-enters the pool and schedules no successor.
            return;
        }
        self.clients[client].online = online;
        if !self.clients[client].is_busy() {
            // Idle clients move between pool and offline immediately;
            // busy clients finish their round first (release() decides).
            if self.clients[client].release() {
                self.pool.insert(client);
            } else {
                self.pool.remove(client);
            }
        }
        let phase = self.clients[client].avail_phase_ms;
        let next =
            self.availability.next_toggle_ms(online, phase, now_ms, &mut self.rng);
        if next.is_finite() {
            let kind = if online {
                EventKind::Offline { client }
            } else {
                EventKind::Online { client }
            };
            self.queue.push(next, kind);
        }
    }

    /// True when an in-flight event still refers to the client's current
    /// selection (stale reports/dropouts are ignored).
    fn live_event(&self, client: usize, epoch: u64) -> bool {
        let c = &self.clients[client];
        c.epoch == epoch && c.is_busy()
    }

    /// Pull up to `k` clients out of the pool into Training.
    fn select_cohort(&mut self, k: usize) -> Vec<usize> {
        let cohort = self.pool.sample(k, &mut self.rng);
        for &c in &cohort {
            self.clients[c].select(self.version);
            self.clients[c].begin_training();
        }
        self.total_selected += cohort.len() as u64;
        cohort
    }

    /// Schedule one client's report (or mid-round dropout) starting at
    /// `start_ms`; returns the duration it occupies its device slot.
    fn schedule_client(&mut self, client: usize, start_ms: f64) -> f64 {
        let device = self.clients[client].device_class;
        let bandwidth = self.clients[client].bandwidth_bytes_per_ms;
        let compute = self.cost.compute_ms(device, &mut self.rng);
        // Charge the actual wire size (codec-encoded when configured);
        // one RNG draw either way, so unencoded digests are untouched.
        let upload =
            self.cost
                .upload_bytes_ms(self.uplink_bytes, bandwidth, &mut self.rng);
        let total = compute + upload;
        // Wire accounting for the codec dashboards: what this upload
        // costs on the wire vs what a dense one would have. Counters are
        // no-ops when telemetry is off and draw no RNG either way.
        self.tel.counter("codec.encoded_bytes", self.uplink_bytes as u64);
        self.tel.counter("codec.dense_bytes", self.cost.model_bytes as u64);
        self.clients[client].service_ms = total;
        let epoch = self.clients[client].epoch;
        let dropout = self.cfg.sim.dropout;
        if dropout > 0.0 && self.rng.uniform() < dropout {
            // Abandon at a uniform point of the round; the device slot
            // frees early.
            let duration = total * self.rng.uniform();
            self.queue
                .push(start_ms + duration, EventKind::Dropout { client, epoch });
            duration
        } else {
            self.queue
                .push(start_ms + total, EventKind::Report { client, epoch });
            total
        }
    }

    /// Mark a finished (reported/dropped) client and return it to the
    /// pool when its availability trace says it is still online.
    fn release(&mut self, client: usize) {
        if self.clients[client].release() {
            self.pool.insert(client);
        }
    }

    /// Loss/accuracy for the round just aggregated: surrogate curves by
    /// default, one real Engine round when `sim.real_training` is set.
    fn backend_metrics(&mut self, round: usize) -> Result<(f64, f64)> {
        let real = match self.server.as_mut() {
            Some(server) => Some(server.run_round(round)?),
            None => None,
        };
        Ok(match real {
            Some(m) => {
                let acc = m.test_accuracy.unwrap_or(m.train_accuracy);
                (m.train_loss, acc)
            }
            None => (
                self.surrogate.loss(self.progress),
                self.surrogate.accuracy(self.progress),
            ),
        })
    }

    // -------------------------------------------------- adversary plane

    /// True when reports must pass through the surrogate-update
    /// aggregation (Byzantine clients are present).
    fn adversary_active(&self) -> bool {
        self.cfg.sim.adversary_frac > 0.0
    }

    /// Reduce one aggregation window's surrogate updates through the
    /// *real* registered aggregator and score the result.
    ///
    /// Every reporter contributes a surrogate delta on a small
    /// [`SURROGATE_P`]-dimensional plane: honest clients a unit descent
    /// step with per-client jitter, Byzantine clients whatever their
    /// [`AdversaryModel`] fabricates. The reduced delta is scored as
    /// `1 − RMS(aggregate − honest step)`, clamped to [-1, 1]: the
    /// fraction of a full descent step this aggregation actually
    /// achieved, with *any* deviation — a reversed direction (sign
    /// flips), a diluted step (free-riders) or injected variance
    /// (scaled noise) — eating into it deterministically. That factor
    /// scales the surrogate progress increment. Alongside, the
    /// per-coordinate distance of the aggregate outside the honest
    /// envelope is accumulated into the run's `envelope_deviation`
    /// (the robustness headline the [`crate::platform::RobustSweep`]
    /// table reports).
    fn robust_aggregate(&mut self, reporters: &[(usize, f64)]) -> Result<f64> {
        let global = Arc::new(ParamVec::zeros(SURROGATE_P));
        let ctx = AggContext::from_config(global, &self.cfg)
            .expect_updates(reporters.len());
        // The surrogate plane reduces through the same hierarchy the
        // real rounds would: per-edge tier aggregators (cfg.edge_agg,
        // falling back to cfg.agg) under the cloud fold — so per-tier
        // robustness is measured, not assumed. Flat topologies degrade
        // to exactly the single registered aggregator as before.
        let clients: Vec<usize> = reporters.iter().map(|&(c, _)| c).collect();
        let mut plane =
            HierPlane::from_registry(&self.topology, ctx, &clients)?;
        let mut honest_lo = [f32::INFINITY; SURROGATE_P];
        let mut honest_hi = [f32::NEG_INFINITY; SURROGATE_P];
        let mut honest = 0usize;
        for &(client, weight) in reporters {
            let mut delta: Vec<f32> = (0..SURROGATE_P)
                .map(|_| (1.0 + 0.1 * (self.adv_rng.uniform() - 0.5)) as f32)
                .collect();
            if self.adversarial[client] {
                self.adversary.corrupt(&mut delta, &mut self.adv_rng);
            } else {
                honest += 1;
                for (i, v) in delta.iter().enumerate() {
                    honest_lo[i] = honest_lo[i].min(*v);
                    honest_hi[i] = honest_hi[i].max(*v);
                }
            }
            plane.add(client, &Update::Dense(ParamVec(delta)), weight)?;
        }
        let (out, _) = plane.finish()?;
        if honest > 0 {
            let mut dev = 0.0f64;
            for (i, v) in out.iter().enumerate() {
                let v = *v as f64;
                dev += (honest_lo[i] as f64 - v).max(0.0)
                    + (v - honest_hi[i] as f64).max(0.0);
            }
            self.env_dev_sum += dev / SURROGATE_P as f64;
            self.env_dev_n += 1;
        }
        let mse = out
            .iter()
            .map(|v| (*v as f64 - 1.0).powi(2))
            .sum::<f64>()
            / SURROGATE_P as f64;
        Ok((1.0 - mse.sqrt()).clamp(-1.0, 1.0))
    }

    /// Close one aggregation window's cloud fan-in: returns the bytes
    /// that crossed into the cloud (every reporter's update when flat,
    /// one dense partial per active edge otherwise) and the extra
    /// virtual time the edge tier adds. Flat windows add exactly 0 ms
    /// and draw no RNG, so pre-hierarchy trace digests are bit-for-bit
    /// unchanged regardless of any hierarchy knob.
    fn close_fanin<I: Iterator<Item = usize>>(
        &mut self,
        reporters: I,
        reported: usize,
    ) -> (usize, f64) {
        if reported == 0 {
            return (0, 0.0);
        }
        let (bytes, hop_ms) = if self.topology.is_flat() {
            // Flat fan-in ships each reporter's update as-is: the
            // per-variant encoded size, not a flat dense charge.
            (reported * self.uplink_bytes, 0.0)
        } else {
            // Edges decode client uploads and ship *dense* partials, so
            // the backhaul still carries model_bytes per active edge.
            // The cloud additionally pays its (deterministic) ingest
            // serialization — 0 with the presets' infinite rate.
            let clusters: BTreeSet<usize> =
                reporters.map(|c| self.topology.cluster_of(c)).collect();
            let bytes = clusters.len() * self.cost.model_bytes;
            let hop =
                self.cost.edge_hop_ms() + self.cost.cloud_ingest_ms(bytes);
            (bytes, hop)
        };
        self.bytes_to_cloud += bytes;
        (bytes, hop_ms)
    }

    // ------------------------------------------------------ sync engine

    fn run_sync(
        &mut self,
        cancel: &dyn Fn() -> bool,
        resume: Option<ResumeAux>,
    ) -> Result<SimReport> {
        let sw = Stopwatch::start();
        let rounds = self.cfg.rounds;
        let k_target = self.cfg.clients_per_round;
        let k_select =
            ((k_target as f64) * self.cfg.sim.over_select).ceil() as usize;
        let deadline_ms = self.cfg.sim.deadline_ms;

        let mut round = 0usize;
        let mut t0 = 0.0f64;
        let mut cohort: Vec<usize> = Vec::new();
        let mut target = 0usize;
        let mut reported = 0usize;
        let mut round_dropped = 0usize;
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut awaiting = false;
        let mut round_span = Span::noop();

        // A checkpoint is taken between rounds (after the next
        // RoundStart is queued), so a resumed run re-enters the loop
        // exactly where the uninterrupted one would be: no cohort in
        // flight, the restored queue carrying RoundStart + pending
        // availability toggles.
        let (mut rounds_done, mut makespan) = match resume {
            Some(aux) => (aux.rounds_done, aux.makespan),
            None => {
                self.init_population();
                self.queue.push(0.0, EventKind::RoundStart { round: 0 });
                (0, 0.0)
            }
        };
        while rounds_done < rounds {
            let Some(ev) = self.queue.pop() else {
                self.tracker
                    .warn("simnet: event queue drained before all rounds ran");
                break;
            };
            let t = ev.time_ms;
            if self.tel.enabled() {
                self.vclock.set_ms(t);
            }
            let mut finish_now = false;
            match ev.kind {
                EventKind::Online { client } => self.handle_toggle(client, true, t),
                EventKind::Offline { client } => {
                    self.handle_toggle(client, false, t)
                }
                EventKind::RoundStart { round: r } => {
                    round = r;
                    t0 = t;
                    reported = 0;
                    round_dropped = 0;
                    measured.clear();
                    cohort = self.select_cohort(k_select);
                    target = k_target.min(cohort.len());
                    awaiting = true;
                    round_span = self.tel.span_with("sim.round", || {
                        vec![
                            ("round", r.to_string()),
                            ("cohort", cohort.len().to_string()),
                        ]
                    });
                    // Over-selected cohort queues per device; clients on
                    // one device run back-to-back (the makespan model
                    // the scheduler optimizes).
                    let groups = self.strategy.allocate(
                        &cohort,
                        self.cfg.num_devices.max(1),
                        &mut self.rng,
                    );
                    for group in &groups {
                        let mut cursor = t0;
                        for &c in group {
                            cursor += self.schedule_client(c, cursor);
                        }
                    }
                    // An empty cohort (everyone offline) still burns its
                    // deadline — the Deadline event closes the round,
                    // and availability toggles can refill the pool
                    // before the next one starts.
                    self.queue
                        .push(t0 + deadline_ms, EventKind::Deadline { round: r });
                }
                EventKind::Report { client, epoch } => {
                    if awaiting && self.live_event(client, epoch) {
                        if self.chaos_report_lost(client) {
                            // Lost in transit (partition / frame drop):
                            // the server sees a dropout, the client just
                            // wasted a round.
                            self.clients[client].drop_out();
                            self.release(client);
                            self.total_dropped += 1;
                            round_dropped += 1;
                            finish_now =
                                reported + round_dropped >= cohort.len();
                        } else if let Some(delay) = self.chaos_stall_ms() {
                            // Stalled frame: the report lands late. Past
                            // the deadline it becomes a straggler drop
                            // like any other.
                            self.queue.push(
                                t + delay,
                                EventKind::Report { client, epoch },
                            );
                        } else {
                            self.clients[client].begin_upload();
                            self.clients[client].report();
                            // Profile the client's own service time
                            // (compute + upload), not its queue-inclusive
                            // completion time — same as the real Server's
                            // observe().
                            measured
                                .push((client, self.clients[client].service_ms));
                            self.release(client);
                            self.total_reported += 1;
                            reported += 1;
                            finish_now = reported >= target
                                || reported + round_dropped >= cohort.len();
                        }
                    }
                }
                EventKind::Dropout { client, epoch } => {
                    if self.live_event(client, epoch) {
                        self.clients[client].drop_out();
                        self.release(client);
                        self.total_dropped += 1;
                        round_dropped += 1;
                        finish_now = awaiting
                            && reported + round_dropped >= cohort.len();
                    }
                }
                EventKind::Deadline { round: r } => {
                    finish_now = awaiting && r == round;
                }
            }
            if awaiting && finish_now {
                let sw_fold = Stopwatch::start();
                let now = self.queue.now_ms();
                // Anything still running missed the aggregation: drop it
                // back into the pool.
                for i in 0..cohort.len() {
                    let c = cohort[i];
                    if self.clients[c].is_busy() {
                        self.clients[c].drop_out();
                        self.release(c);
                        self.total_dropped += 1;
                        round_dropped += 1;
                    }
                }
                self.strategy.observe(&measured);
                let part = if k_target > 0 {
                    (reported as f64 / k_target as f64).min(1.0)
                } else {
                    0.0
                };
                // With Byzantine clients present, the round's effective
                // progress is scaled by how well the configured
                // aggregator preserved the honest descent direction.
                let inc = if self.adversary_active() && !measured.is_empty() {
                    let reporters: Vec<(usize, f64)> =
                        measured.iter().map(|&(c, _)| (c, 1.0)).collect();
                    part * self.robust_aggregate(&reporters)?
                } else {
                    part
                };
                self.progress = (self.progress + inc).max(0.0);
                // Hierarchy fan-in: bytes-to-cloud for the window plus
                // the edge-partial hop (flat rounds close at `now`
                // exactly, as before).
                let (round_bytes, hop_ms) = self
                    .close_fanin(measured.iter().map(|&(c, _)| c), reported);
                let close = now + hop_ms;
                let (train_loss, acc) = self.backend_metrics(round)?;
                let mut service = Histogram::new();
                for &(_, ms) in &measured {
                    service.record_ms(ms);
                }
                // Downlink distributes the dense model to every selected
                // client; the uplink charges each report's actual wire
                // size (equal to model_bytes when no codec is
                // configured, so the legacy (selected + reported) ·
                // model_bytes is preserved).
                let comm = cohort.len() * self.cost.model_bytes
                    + reported * self.uplink_bytes;
                self.record_round(
                    round,
                    close - t0,
                    cohort.len(),
                    reported,
                    round_dropped,
                    0.0,
                    comm,
                    round_bytes,
                    train_loss,
                    acc,
                    &service,
                );
                let fold_ms = sw_fold.elapsed_ms();
                self.fold_hist.record_ms(fold_ms);
                self.tel.observe_ms("sim.fold_ms", fold_ms);
                if self.tel.enabled() {
                    self.vclock.set_ms(close);
                }
                round_span = Span::noop();
                self.version += 1;
                awaiting = false;
                rounds_done += 1;
                makespan = close;
                if rounds_done < rounds {
                    if cancel() {
                        self.cancelled = true;
                        break;
                    }
                    // Between-round churn, then queue the next round so
                    // the checkpoint snapshot includes it; the kill fault
                    // fires *after* its boundary checkpoint, so a killed
                    // run is always resumable at the kill point.
                    self.apply_churn(close);
                    self.queue
                        .push(close, EventKind::RoundStart { round: round + 1 });
                    self.maybe_checkpoint(rounds_done, makespan, close, None)?;
                    if self.chaos_kill_now(rounds_done) {
                        self.cancelled = true;
                        break;
                    }
                }
            }
        }
        drop(round_span);
        self.teardown();
        self.finish_telemetry()?;
        Ok(self.build_report("sync", makespan, sw.elapsed_ms()))
    }

    // ----------------------------------------------------- async engine

    fn run_async(
        &mut self,
        cancel: &dyn Fn() -> bool,
        resume: Option<ResumeAux>,
    ) -> Result<SimReport> {
        let sw = Stopwatch::start();
        let rounds = self.cfg.rounds;
        let k_target = self.cfg.clients_per_round.max(1);
        let buffer_target = if self.cfg.sim.async_buffer > 0 {
            self.cfg.sim.async_buffer
        } else {
            k_target
        };
        let concurrency = if self.cfg.sim.async_concurrency > 0 {
            self.cfg.sim.async_concurrency
        } else {
            2 * k_target
        };

        let mut active = 0usize;
        // FedBuff window from the aggregation plane: staleness discounts
        // become aggregator weights. Surrogate mode keeps the weight
        // ledger only; plugging a real Aggregator streams updates too.
        let mut buffer = FedBuffBuffer::surrogate(self.cfg.sim.staleness_alpha);
        // (client, discounted weight) per window arrival, for the
        // adversary plane's surrogate-update reduction.
        let mut window_members: Vec<(usize, f64)> = Vec::new();
        let mut agg_dropped = 0usize;
        let mut t_last = 0.0f64;
        let mut makespan = 0.0f64;
        let mut window_span = Span::noop();
        let mut window_service = Histogram::new();

        // Async checkpoints land on window flushes, so a restored run
        // resumes with an empty FedBuff buffer and every in-flight
        // client's Report/Dropout already in the restored queue — the
        // refill below replays the post-flush refill the uninterrupted
        // run performed at the same boundary.
        match resume {
            Some(aux) => {
                makespan = aux.makespan;
                t_last = aux.t_last;
                active =
                    self.clients.iter().filter(|c| c.is_busy()).count();
                if self.version < rounds {
                    let now = self.queue.now_ms();
                    self.refill_async(&mut active, concurrency, now);
                }
            }
            None => {
                self.init_population();
                self.refill_async(&mut active, concurrency, 0.0);
            }
        }
        while self.version < rounds {
            let Some(ev) = self.queue.pop() else {
                self.tracker.warn(
                    "simnet: async engine starved (no clients available and \
                     no pending events)",
                );
                break;
            };
            let t = ev.time_ms;
            if self.tel.enabled() {
                self.vclock.set_ms(t);
            }
            match ev.kind {
                EventKind::Online { client } => self.handle_toggle(client, true, t),
                EventKind::Offline { client } => {
                    self.handle_toggle(client, false, t)
                }
                EventKind::Report { client, epoch } => {
                    if !self.live_event(client, epoch) {
                        continue;
                    }
                    if self.chaos_report_lost(client) {
                        // Lost in transit (partition / frame drop): the
                        // window sees a dropout. Falls through to the
                        // loop-bottom refill like any other resolution —
                        // a `continue` here could starve the engine.
                        self.clients[client].drop_out();
                        self.release(client);
                        active -= 1;
                        agg_dropped += 1;
                        self.total_dropped += 1;
                    } else if let Some(delay) = self.chaos_stall_ms() {
                        // Stalled frame: re-queue the report `delay`
                        // later; the client stays busy, so the refill
                        // below cannot double-book its slot.
                        self.queue
                            .push(t + delay, EventKind::Report { client, epoch });
                    } else {
                        let staleness = (self.version
                            - self.clients[client].start_version)
                            as f64;
                        self.clients[client].begin_upload();
                        self.clients[client].report();
                        window_service
                            .record_ms(self.clients[client].service_ms);
                        self.release(client);
                        active -= 1;
                        self.total_reported += 1;
                        if window_members.is_empty() {
                            window_span =
                                self.tel.span_with("sim.window", || {
                                    vec![("round", self.version.to_string())]
                                });
                        }
                        let weight = buffer.push(staleness, None)?;
                        window_members.push((client, weight));
                        self.staleness_sum += staleness;
                        self.staleness_n += 1;
                        if buffer.len() >= buffer_target {
                            // FedBuff aggregation: staleness-discounted
                            // weights, normalized against the sync target
                            // K so sync/async progress is comparable.
                            let sw_fold = Stopwatch::start();
                            let round = self.version;
                            self.version += 1;
                            let base =
                                buffer.total_weight() / k_target as f64;
                            let inc = if self.adversary_active() {
                                base * self.robust_aggregate(&window_members)?
                            } else {
                                base
                            };
                            // Window fan-in before the member list resets
                            // (flat windows close at `t` exactly, as
                            // before).
                            let (window_bytes, hop_ms) = self.close_fanin(
                                window_members.iter().map(|&(c, _)| c),
                                window_members.len(),
                            );
                            let close = t + hop_ms;
                            window_members.clear();
                            self.progress = (self.progress + inc).max(0.0);
                            let (train_loss, acc) =
                                self.backend_metrics(round)?;
                            let window = buffer.flush()?;
                            // Async "selected" = selections *resolved* in
                            // this window (reports + drops), so the
                            // reported ≤ selected invariant holds per
                            // round.
                            let comm = (window.arrivals + agg_dropped)
                                * self.cost.model_bytes
                                + window.arrivals * self.uplink_bytes;
                            self.record_round(
                                round,
                                close - t_last,
                                window.arrivals + agg_dropped,
                                window.arrivals,
                                agg_dropped,
                                window.avg_staleness,
                                comm,
                                window_bytes,
                                train_loss,
                                acc,
                                &window_service,
                            );
                            window_service = Histogram::new();
                            let fold_ms = sw_fold.elapsed_ms();
                            self.fold_hist.record_ms(fold_ms);
                            self.tel.observe_ms("sim.fold_ms", fold_ms);
                            if self.tel.enabled() {
                                self.vclock.set_ms(close);
                            }
                            window_span = Span::noop();
                            agg_dropped = 0;
                            t_last = close;
                            makespan = close;
                            if self.version < rounds {
                                if cancel() {
                                    self.cancelled = true;
                                    break;
                                }
                                // Same boundary order as the sync engine:
                                // churn, checkpoint (buffer just flushed,
                                // so none of its state needs
                                // serializing), then the kill fault —
                                // always after its checkpoint.
                                self.apply_churn(close);
                                self.maybe_checkpoint(
                                    self.version,
                                    makespan,
                                    t_last,
                                    None,
                                )?;
                                if self.chaos_kill_now(self.version) {
                                    self.cancelled = true;
                                    break;
                                }
                            }
                        }
                    }
                }
                EventKind::Dropout { client, epoch } => {
                    if !self.live_event(client, epoch) {
                        continue;
                    }
                    self.clients[client].drop_out();
                    self.release(client);
                    active -= 1;
                    agg_dropped += 1;
                    self.total_dropped += 1;
                }
                EventKind::RoundStart { .. } | EventKind::Deadline { .. } => {}
            }
            if self.version < rounds {
                let now = self.queue.now_ms();
                self.refill_async(&mut active, concurrency, now);
            }
        }
        drop(window_span);
        self.teardown();
        self.finish_telemetry()?;
        Ok(self.build_report("async", makespan, sw.elapsed_ms()))
    }

    /// Keep `concurrency` clients training (FedBuff's server-side pull).
    fn refill_async(&mut self, active: &mut usize, concurrency: usize, now_ms: f64) {
        while *active < concurrency && !self.pool.is_empty() {
            let picked = self.pool.sample(1, &mut self.rng);
            let c = picked[0];
            self.clients[c].select(self.version);
            self.clients[c].begin_training();
            self.total_selected += 1;
            self.schedule_client(c, now_ms);
            *active += 1;
        }
    }

    // --------------------------------------------------- gossip engine

    /// Serverless P2P rounds over a [`PeerGraph`]: every online client
    /// trains locally, ships its state to each neighbor (edge-charged
    /// P2P uploads — `bytes_to_cloud` stays 0 for the whole run) and
    /// folds what it received through the registered aggregator. The
    /// `ring` shape runs the all-reduce variant: one global fold per
    /// round that every participant adopts. Convergence is measured as
    /// consensus distance — the exact maximum pairwise L∞ parameter
    /// divergence across honest clients — surfaced per round through
    /// telemetry and finally in [`SimReport::consensus_distance`].
    fn run_gossip(
        &mut self,
        cancel: &dyn Fn() -> bool,
        resume: Option<ResumeAux>,
    ) -> Result<SimReport> {
        let sw = Stopwatch::start();
        let rounds = self.target_rounds();
        let deadline_ms = self.cfg.sim.deadline_ms;
        let n = self.clients.len();
        let degree = self.topology.peer_degree().unwrap_or(2);
        let ring = matches!(self.topology, Topology::Ring);
        let kind = if ring { "ring" } else { "gossip" };
        // Graph permutation, initial states and drift directions come
        // from a dedicated stream seeded once here: the main stream's
        // draws stay aligned with the server engines, and a resumed run
        // rebuilds the identical graph/drift table from the seed before
        // overwriting the states from the checkpoint.
        let mut gossip_rng = Rng::new(self.cfg.seed ^ 0x474F_5353_4950); // "GOSSIP"
        let graph = PeerGraph::build(kind, degree, n, &mut gossip_rng)?;
        let mut engine = GossipEngine::new(graph, SURROGATE_P, &mut gossip_rng);
        // One registered aggregator reused across every fold (`finish`
        // resets it); robust rules make each neighborhood fold — or the
        // ring's global fold — Byzantine-filtered.
        let ctx = AggContext::from_config(
            Arc::new(ParamVec::zeros(SURROGATE_P)),
            &self.cfg,
        )
        .expect_updates(if ring { n } else { degree + 1 })
        .telemetry(self.tel.clone());
        let mut agg =
            registry::with_global(|r| r.aggregator(&self.agg_name, &ctx))?;

        let mut round = 0usize;
        let mut t0 = 0.0f64;
        let mut cohort: Vec<usize> = Vec::new();
        let mut reporters: Vec<usize> = Vec::new();
        let mut round_dropped = 0usize;
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut awaiting = false;
        let mut round_span = Span::noop();

        // Checkpoints land between rounds exactly like the sync engine's,
        // carrying the state matrix as an appendix; a resumed run
        // re-enters here with the restored queue and the rebuilt engine
        // overwritten from the snapshot.
        let (mut rounds_done, mut makespan) = match resume {
            Some(aux) => {
                if let Some(states) = self.gossip_states.take() {
                    engine.restore(aux.rounds_done, states)?;
                }
                (aux.rounds_done, aux.makespan)
            }
            None => {
                self.init_population();
                self.queue.push(0.0, EventKind::RoundStart { round: 0 });
                (0, 0.0)
            }
        };
        while rounds_done < rounds {
            let Some(ev) = self.queue.pop() else {
                self.tracker
                    .warn("simnet: event queue drained before all rounds ran");
                break;
            };
            let t = ev.time_ms;
            if self.tel.enabled() {
                self.vclock.set_ms(t);
            }
            let mut finish_now = false;
            match ev.kind {
                EventKind::Online { client } => self.handle_toggle(client, true, t),
                EventKind::Offline { client } => {
                    self.handle_toggle(client, false, t)
                }
                EventKind::RoundStart { round: r } => {
                    round = r;
                    t0 = t;
                    reporters.clear();
                    round_dropped = 0;
                    measured.clear();
                    // No server-side selection: every available client
                    // participates. Index order keeps the per-client
                    // schedule draws deterministic.
                    cohort = self.pool.members().to_vec();
                    cohort.sort_unstable();
                    for &c in &cohort {
                        self.clients[c].select(self.version);
                        self.clients[c].begin_training();
                    }
                    self.total_selected += cohort.len() as u64;
                    awaiting = true;
                    round_span = self.tel.span_with("sim.round", || {
                        vec![
                            ("round", r.to_string()),
                            ("cohort", cohort.len().to_string()),
                        ]
                    });
                    // P2P: no device queuing — every peer starts its
                    // round at the boundary on its own hardware.
                    for i in 0..cohort.len() {
                        let c = cohort[i];
                        self.schedule_gossip_client(c, t0, degree);
                    }
                    self.queue
                        .push(t0 + deadline_ms, EventKind::Deadline { round: r });
                }
                EventKind::Report { client, epoch } => {
                    if awaiting && self.live_event(client, epoch) {
                        if self.chaos_report_lost(client) {
                            self.clients[client].drop_out();
                            self.release(client);
                            self.total_dropped += 1;
                            round_dropped += 1;
                            finish_now = reporters.len() + round_dropped
                                >= cohort.len();
                        } else if let Some(delay) = self.chaos_stall_ms() {
                            // Stalled frame: the exchange lands late; past
                            // the deadline the peer misses the round.
                            self.queue.push(
                                t + delay,
                                EventKind::Report { client, epoch },
                            );
                        } else {
                            self.clients[client].begin_upload();
                            self.clients[client].report();
                            measured
                                .push((client, self.clients[client].service_ms));
                            self.release(client);
                            self.total_reported += 1;
                            reporters.push(client);
                            finish_now = reporters.len() + round_dropped
                                >= cohort.len();
                        }
                    }
                }
                EventKind::Dropout { client, epoch } => {
                    if self.live_event(client, epoch) {
                        self.clients[client].drop_out();
                        self.release(client);
                        self.total_dropped += 1;
                        round_dropped += 1;
                        finish_now = awaiting
                            && reporters.len() + round_dropped >= cohort.len();
                    }
                }
                EventKind::Deadline { round: r } => {
                    finish_now = awaiting && r == round;
                }
            }
            if awaiting && finish_now {
                let sw_fold = Stopwatch::start();
                let now = self.queue.now_ms();
                // Peers still mid-exchange missed the round: their
                // neighbors fold without them.
                for i in 0..cohort.len() {
                    let c = cohort[i];
                    if self.clients[c].is_busy() {
                        self.clients[c].drop_out();
                        self.release(c);
                        self.total_dropped += 1;
                        round_dropped += 1;
                    }
                }
                let reported = reporters.len();
                let mut participating = vec![false; n];
                for &c in &reporters {
                    participating[c] = true;
                }
                let span = self.tel.span_with("gossip.exchange", || {
                    vec![
                        ("round", round.to_string()),
                        ("participants", reported.to_string()),
                    ]
                });
                engine.local_train(&participating);
                // Broadcasts are what peers *claim*: the adversary
                // corrupts Byzantine participants' outgoing rows (index
                // order, dedicated stream), poisoning their neighbors
                // but never their own true state.
                let mut broadcasts = engine.states().to_vec();
                if self.adversary_active() {
                    for c in 0..n {
                        if participating[c] && self.adversarial[c] {
                            let row = c * SURROGATE_P;
                            self.adversary.corrupt(
                                &mut broadcasts[row..row + SURROGATE_P],
                                &mut self.adv_rng,
                            );
                        }
                    }
                }
                if ring {
                    engine.ring_all_reduce(
                        &participating,
                        &broadcasts,
                        agg.as_mut(),
                    )?;
                } else {
                    engine.exchange(&participating, &broadcasts, agg.as_mut())?;
                }
                drop(span);
                // Consensus over honest clients only — an adversary's
                // own outlier state is its problem, not the metric's.
                let honest: Vec<bool> =
                    self.adversarial.iter().map(|&a| !a).collect();
                let dist = engine.consensus_distance(&honest);
                self.consensus_distance = dist;
                self.tel.observe_ms("gossip.consensus", dist);
                // Surrogate progress tracks mixing participation; the
                // curves give the fleet-average loss/accuracy.
                let part = reported as f64 / n as f64;
                self.progress = (self.progress + part).max(0.0);
                let (train_loss, acc) = self.backend_metrics(round)?;
                let mut service = Histogram::new();
                for &(_, ms) in &measured {
                    service.record_ms(ms);
                }
                // Every byte is P2P: `degree` uplink frames per reporter,
                // no model downlink, nothing to the cloud.
                let comm = reported * degree * self.uplink_bytes;
                self.record_round(
                    round,
                    now - t0,
                    cohort.len(),
                    reported,
                    round_dropped,
                    0.0,
                    comm,
                    0,
                    train_loss,
                    acc,
                    &service,
                );
                let fold_ms = sw_fold.elapsed_ms();
                self.fold_hist.record_ms(fold_ms);
                self.tel.observe_ms("sim.fold_ms", fold_ms);
                round_span = Span::noop();
                self.version += 1;
                awaiting = false;
                rounds_done += 1;
                makespan = now;
                if rounds_done < rounds {
                    if cancel() {
                        self.cancelled = true;
                        break;
                    }
                    // Same boundary order as the server engines (no
                    // churn — the peer graph is fixed): next round into
                    // the queue so the checkpoint snapshot carries it,
                    // then the kill fault after its checkpoint.
                    self.queue
                        .push(now, EventKind::RoundStart { round: round + 1 });
                    self.maybe_checkpoint(
                        rounds_done,
                        makespan,
                        now,
                        Some(&engine),
                    )?;
                    if self.chaos_kill_now(rounds_done) {
                        self.cancelled = true;
                        break;
                    }
                }
            }
        }
        drop(round_span);
        self.teardown();
        self.finish_telemetry()?;
        Ok(self.build_report("gossip", makespan, sw.elapsed_ms()))
    }

    /// Schedule one gossip participant's exchange: local compute plus
    /// `degree` neighbor uploads (P2P frames leave serially on the
    /// client's uplink — one cost draw per edge, so the wire schedule
    /// reflects the graph). Mirrors [`Self::schedule_client`]'s draw
    /// order: compute, uploads, then the dropout decision.
    fn schedule_gossip_client(
        &mut self,
        client: usize,
        start_ms: f64,
        degree: usize,
    ) {
        let device = self.clients[client].device_class;
        let bandwidth = self.clients[client].bandwidth_bytes_per_ms;
        let compute = self.cost.compute_ms(device, &mut self.rng);
        let mut total = compute;
        for _ in 0..degree {
            total += self.cost.upload_bytes_ms(
                self.uplink_bytes,
                bandwidth,
                &mut self.rng,
            );
        }
        self.tel
            .counter("codec.encoded_bytes", (degree * self.uplink_bytes) as u64);
        self.tel.counter(
            "codec.dense_bytes",
            (degree * self.cost.model_bytes) as u64,
        );
        self.clients[client].service_ms = total;
        let epoch = self.clients[client].epoch;
        let dropout = self.cfg.sim.dropout;
        if dropout > 0.0 && self.rng.uniform() < dropout {
            let duration = total * self.rng.uniform();
            self.queue
                .push(start_ms + duration, EventKind::Dropout { client, epoch });
        } else {
            self.queue
                .push(start_ms + total, EventKind::Report { client, epoch });
        }
    }

    // ---------------------------------------------------- churn plane

    /// Between-round elastic membership: accrue this boundary's
    /// fractional join/leave credit and apply the whole-client part.
    /// `"none"` (the default) returns before touching the churn RNG.
    fn apply_churn(&mut self, now_ms: f64) {
        if self.churn.is_none() {
            return;
        }
        let (join_rate, leave_rate) = self.churn.rates();
        let (joins, leaves) = self.churn_credits.accrue(join_rate, leave_rate);
        for _ in 0..joins {
            self.churn_join(now_ms);
        }
        for _ in 0..leaves {
            self.churn_leave();
        }
    }

    /// Admit one new client: sampled like `init_population` but from the
    /// dedicated churn stream, entering at `now_ms` on the virtual clock.
    fn churn_join(&mut self, now_ms: f64) {
        let c = self.clients.len();
        let device = self.cost.sample_device(&mut self.churn_rng);
        let bandwidth = self.cost.sample_bandwidth(&mut self.churn_rng);
        let mut state = ClientState::new(device, bandwidth);
        let phase = self.availability.sample_phase_ms(&mut self.churn_rng);
        let online =
            self.availability.initial_online(phase, &mut self.churn_rng);
        state.avail_phase_ms = phase;
        state.online = online;
        state.release();
        self.clients.push(state);
        self.adversarial.push(false);
        self.departed.push(false);
        self.pool.grow(self.clients.len());
        if online {
            self.pool.insert(c);
        }
        let next = self.availability.next_toggle_ms(
            online,
            phase,
            now_ms,
            &mut self.churn_rng,
        );
        if next.is_finite() {
            let kind = if online {
                EventKind::Offline { client: c }
            } else {
                EventKind::Online { client: c }
            };
            self.queue.push(next, kind);
        }
    }

    /// Retire one idle client, picked uniformly from the available pool
    /// (busy clients finish their round; an empty pool spends the credit
    /// as a no-op). Departed clients never come back: their pending
    /// availability toggles pop inert.
    fn churn_leave(&mut self) {
        let picked = self.pool.sample(1, &mut self.churn_rng);
        let Some(&c) = picked.first() else {
            return;
        };
        self.departed[c] = true;
        self.clients[c].online = false;
        self.clients[c].release();
    }

    // ---------------------------------------------------- chaos plane

    /// True when the chaos plane eats this report in transit (edge
    /// partition or random frame drop). Draws from the chaos RNG only
    /// when `drop_frames` is armed.
    fn chaos_report_lost(&mut self, client: usize) -> bool {
        if let Some(cluster) = self.partitioned {
            if self.topology.cluster_of(client) == cluster {
                self.faults_injected += 1;
                self.tel.counter("chaos.faults", 1);
                return true;
            }
        }
        if let Some(frac) = self.drop_frac {
            if self.chaos_rng.uniform() < frac {
                self.faults_injected += 1;
                self.tel.counter("chaos.faults", 1);
                return true;
            }
        }
        if let Some(frac) = self.midframe_frac {
            // The reactor's mid-frame cut: bytes partially shipped, the
            // update never lands. Indistinguishable from drop_frames at
            // this abstraction level, but a separate knob (and draw) so
            // wire-level and network-level loss can be mixed.
            if self.chaos_rng.uniform() < frac {
                self.faults_injected += 1;
                self.tel.counter("chaos.faults", 1);
                return true;
            }
        }
        false
    }

    /// `stall_frames(f, ms)`: this report's frame stalls partially
    /// written and completes `ms` later. Returns the extra delay when
    /// the stall fires; draws from the chaos RNG only when armed.
    fn chaos_stall_ms(&mut self) -> Option<f64> {
        let (frac, delay_ms) = self.stall?;
        if self.chaos_rng.uniform() < frac {
            self.faults_injected += 1;
            self.tel.counter("chaos.faults", 1);
            Some(delay_ms)
        } else {
            None
        }
    }

    /// `kill_server_at_round(r)`: hard-stop once `r` rounds aggregated
    /// (the boundary's checkpoint has already been written).
    fn chaos_kill_now(&mut self, rounds_done: usize) -> bool {
        if self.kill_at == Some(rounds_done) {
            self.faults_injected += 1;
            self.tel.counter("chaos.faults", 1);
            self.tracker.warn(&format!(
                "chaos: kill_server_at_round({rounds_done}) fired"
            ));
            true
        } else {
            false
        }
    }

    // ----------------------------------------------------- checkpoints

    /// Write a round-boundary checkpoint when one is due: every
    /// `checkpoint_every` rounds, plus unconditionally at a
    /// `kill_server_at_round` boundary so killed runs are always
    /// resumable. No `checkpoint_dir` ⇒ never.
    fn maybe_checkpoint(
        &mut self,
        rounds_done: usize,
        makespan: f64,
        t_last: f64,
        gossip: Option<&GossipEngine>,
    ) -> Result<()> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(());
        };
        let every = self.cfg.checkpoint_every;
        let due = every > 0 && rounds_done % every == 0;
        let killing = self.kill_at == Some(rounds_done);
        if !(due || killing) {
            return Ok(());
        }
        let span = self.tel.span_with("sim.checkpoint", || {
            vec![("round", rounds_done.to_string())]
        });
        let path = checkpoint::checkpoint_path(&dir, rounds_done);
        let bytes =
            self.write_checkpoint(&path, rounds_done, makespan, t_last, gossip)?;
        self.tel.counter("checkpoint.saves", 1);
        self.tel.counter("checkpoint.bytes", bytes as u64);
        if self.corrupt_ckpt {
            checkpoint::corrupt_file(&path)?;
            self.faults_injected += 1;
            self.tel.counter("chaos.faults", 1);
        }
        // Retention: prune old checkpoints past `checkpoint_keep`, never
        // touching the one just written (it is the newest by round).
        if self.cfg.checkpoint_keep > 0 {
            let pruned =
                checkpoint::prune_checkpoints(&dir, self.cfg.checkpoint_keep)?;
            if !pruned.is_empty() {
                self.tel.counter("checkpoint.pruned", pruned.len() as u64);
            }
        }
        drop(span);
        Ok(())
    }

    /// Serialize the full simulation state at a round boundary: engine
    /// progress, all four RNG streams, churn credits, every client's
    /// lifecycle, the available pool, the scheduler's learned profile,
    /// real-training global params (when on) and the pending event
    /// queue. Histograms are deliberately *not* serialized — a resumed
    /// run's latency quantiles cover the resumed segment only; trace
    /// digests, metrics and membership are exact.
    fn write_checkpoint(
        &self,
        path: &Path,
        rounds_done: usize,
        makespan: f64,
        t_last: f64,
        gossip: Option<&GossipEngine>,
    ) -> Result<usize> {
        let mut w = CheckpointWriter::new();
        w.push_u64(checkpoint::config_fingerprint(&self.cfg));
        w.push_usize(rounds_done);
        w.push_f64(makespan);
        w.push_f64(t_last);
        w.push_usize(self.version);
        w.push_f64(self.progress);
        w.push_u64(self.total_selected);
        w.push_u64(self.total_reported);
        w.push_u64(self.total_dropped);
        w.push_f64(self.staleness_sum);
        w.push_u64(self.staleness_n);
        w.push_usize(self.bytes_to_cloud);
        w.push_f64(self.env_dev_sum);
        w.push_u64(self.env_dev_n);
        w.push_u64(self.faults_injected);
        // Metric offsets: the resuming process starts a fresh tracker,
        // so completed-round and comm-byte totals carry over as bases.
        w.push_usize(self.base_rounds + self.tracker.num_rounds());
        w.push_usize(self.base_comm_bytes + self.tracker.total_comm_bytes());
        for rng in [&self.rng, &self.adv_rng, &self.churn_rng, &self.chaos_rng]
        {
            let (state, spare) = rng.snapshot();
            w.push_u64(state);
            w.push_opt_f64(spare);
        }
        w.push_f64(self.churn_credits.join);
        w.push_f64(self.churn_credits.leave);
        w.push_usize(self.clients.len());
        for (i, c) in self.clients.iter().enumerate() {
            w.push_u64(c.phase.tag());
            w.push_bool(c.online);
            w.push_usize(c.device_class);
            w.push_f64(c.bandwidth_bytes_per_ms);
            w.push_f64(c.avail_phase_ms);
            w.push_u64(c.epoch);
            w.push_usize(c.start_version);
            w.push_f64(c.service_ms);
            w.push_u64(c.reports as u64);
            w.push_u64(c.dropouts as u64);
            w.push_bool(self.adversarial[i]);
            w.push_bool(self.departed[i]);
        }
        let members = self.pool.members();
        w.push_usize(members.len());
        for &m in members {
            w.push_usize(m);
        }
        let (profiled, default_ms) = self.strategy.snapshot_profile();
        w.push_f64(default_ms);
        w.push_usize(profiled.len());
        for &(client, ms) in &profiled {
            w.push_usize(client);
            w.push_f64(ms);
        }
        match self.server.as_ref() {
            Some(server) => {
                let params = server.params();
                w.push_bool(true);
                w.push_usize(params.len());
                for v in params.iter() {
                    w.push_f64(*v as f64);
                }
            }
            None => w.push_bool(false),
        }
        let snap = self.queue.snapshot();
        w.push_u64(snap.now_ms_bits);
        w.push_u64(snap.next_seq);
        w.push_u64(snap.processed);
        w.push_u64(snap.digest);
        w.push_usize(snap.events.len());
        for &(time_bits, seq, tag, a, b) in &snap.events {
            w.push_u64(time_bits);
            w.push_u64(seq);
            w.push_u64(tag);
            w.push_u64(a);
            w.push_u64(b);
        }
        // Gossip appendix: the engine's state matrix (lossless f32→f64).
        // The drift table and peer graph are never serialized — they
        // rebuild bit-identically from the seed; the engine's round
        // counter equals `rounds_done`.
        if let Some(engine) = gossip {
            let states = engine.states();
            w.push_usize(states.len());
            for &v in states {
                w.push_f64(v as f64);
            }
        }
        w.write(path)
    }

    /// Restore a checkpoint written by [`Self::write_checkpoint`] into
    /// this freshly-built simulator. A fingerprint mismatch (the file is
    /// intact but belongs to a different run shape) is a config error;
    /// any truncation, corruption or impossible value is
    /// [`Error::Integrity`].
    fn restore_checkpoint(&mut self, path: &Path) -> Result<ResumeAux> {
        let mut r = CheckpointReader::open(path)?;
        let fingerprint = r.take_u64()?;
        if fingerprint != checkpoint::config_fingerprint(&self.cfg) {
            return Err(Error::Config(format!(
                "checkpoint {} was written by a run with a different \
                 config (seed / rounds / population / model knobs must \
                 match to resume)",
                path.display()
            )));
        }
        let rounds_done = r.take_usize()?;
        let makespan = r.take_f64()?;
        let t_last = r.take_f64()?;
        self.version = r.take_usize()?;
        self.progress = r.take_f64()?;
        self.total_selected = r.take_u64()?;
        self.total_reported = r.take_u64()?;
        self.total_dropped = r.take_u64()?;
        self.staleness_sum = r.take_f64()?;
        self.staleness_n = r.take_u64()?;
        self.bytes_to_cloud = r.take_usize()?;
        self.env_dev_sum = r.take_f64()?;
        self.env_dev_n = r.take_u64()?;
        self.faults_injected = r.take_u64()?;
        self.base_rounds = r.take_usize()?;
        self.base_comm_bytes = r.take_usize()?;
        self.rng = take_rng(&mut r)?;
        self.adv_rng = take_rng(&mut r)?;
        self.churn_rng = take_rng(&mut r)?;
        self.chaos_rng = take_rng(&mut r)?;
        self.churn_credits.join = r.take_f64()?;
        self.churn_credits.leave = r.take_f64()?;
        let n = r.take_usize()?;
        let mut clients = Vec::with_capacity(n);
        let mut adversarial = Vec::with_capacity(n);
        let mut departed = Vec::with_capacity(n);
        for _ in 0..n {
            let tag = r.take_u64()?;
            let phase = ClientPhase::from_tag(tag).ok_or_else(|| {
                Error::Integrity(format!("unknown client phase tag {tag}"))
            })?;
            let online = r.take_bool()?;
            let device_class = r.take_usize()?;
            let bandwidth = r.take_f64()?;
            let mut c = ClientState::new(device_class, bandwidth);
            c.phase = phase;
            c.online = online;
            c.avail_phase_ms = r.take_f64()?;
            c.epoch = r.take_u64()?;
            c.start_version = r.take_usize()?;
            c.service_ms = r.take_f64()?;
            c.reports = r.take_u64()? as u32;
            c.dropouts = r.take_u64()? as u32;
            clients.push(c);
            adversarial.push(r.take_bool()?);
            departed.push(r.take_bool()?);
        }
        self.clients = clients;
        self.adversarial = adversarial;
        self.departed = departed;
        let pool_len = r.take_usize()?;
        let mut members = Vec::with_capacity(pool_len);
        for _ in 0..pool_len {
            let m = r.take_usize()?;
            if m >= n {
                return Err(Error::Integrity(format!(
                    "pool member {m} out of range (population {n})"
                )));
            }
            members.push(m);
        }
        self.pool = Pool::from_members(n, members);
        let default_ms = r.take_f64()?;
        let profiled_len = r.take_usize()?;
        let mut profiled = Vec::with_capacity(profiled_len);
        for _ in 0..profiled_len {
            let client = r.take_usize()?;
            let ms = r.take_f64()?;
            profiled.push((client, ms));
        }
        self.strategy.restore_profile(&profiled, default_ms);
        if r.take_bool()? {
            let p = r.take_usize()?;
            let mut params = Vec::with_capacity(p);
            for _ in 0..p {
                params.push(r.take_f64()? as f32);
            }
            match self.server.as_mut() {
                Some(server) => server.set_params(ParamVec(params)),
                None => {
                    return Err(Error::Config(
                        "checkpoint carries real-training params but \
                         sim.real_training is off in the resuming config"
                            .into(),
                    ))
                }
            }
        }
        let now_ms_bits = r.take_u64()?;
        let next_seq = r.take_u64()?;
        let processed = r.take_u64()?;
        let digest = r.take_u64()?;
        let ev_len = r.take_usize()?;
        let mut events = Vec::with_capacity(ev_len);
        for _ in 0..ev_len {
            let time_bits = r.take_u64()?;
            let seq = r.take_u64()?;
            let tag = r.take_u64()?;
            let a = r.take_u64()?;
            let b = r.take_u64()?;
            // Client-carrying events must point inside the restored
            // population (tags: Online/Offline/Report/Dropout).
            if matches!(tag, 1 | 2 | 4 | 5) && a as usize >= n {
                return Err(Error::Integrity(format!(
                    "event client {a} out of range (population {n})"
                )));
            }
            events.push((time_bits, seq, tag, a, b));
        }
        self.queue = EventQueue::restore(&QueueSnapshot {
            now_ms_bits,
            next_seq,
            processed,
            digest,
            events,
        })?;
        if self.cfg.sim.engine == "gossip" {
            let len = r.take_usize()?;
            let mut states = Vec::with_capacity(len);
            for _ in 0..len {
                states.push(r.take_f64()? as f32);
            }
            // Stashed until `run_gossip` has rebuilt the engine from the
            // seed; the length check happens at `GossipEngine::restore`.
            self.gossip_states = Some(states);
        }
        if r.remaining() != 0 {
            return Err(Error::Integrity(format!(
                "checkpoint has {} trailing words",
                r.remaining()
            )));
        }
        self.tel.counter("checkpoint.restores", 1);
        Ok(ResumeAux { rounds_done, makespan, t_last })
    }

    // -------------------------------------------------------- wrap-up

    #[allow(clippy::too_many_arguments)]
    fn record_round(
        &mut self,
        round: usize,
        round_ms: f64,
        selected: usize,
        reported: usize,
        dropped: usize,
        avg_staleness: f64,
        comm_bytes: usize,
        bytes_to_cloud: usize,
        train_loss: f64,
        accuracy: f64,
        service: &Histogram,
    ) {
        self.client_hist.merge(service);
        let (client_ms_p50, client_ms_p95, client_ms_p99) =
            service.quantiles_ms();
        let eval = self.cfg.eval_every > 0
            && (round + 1) % self.cfg.eval_every == 0;
        self.tracker.record_round(RoundMetrics {
            round,
            train_loss,
            train_accuracy: accuracy,
            test_loss: if eval { Some(train_loss) } else { None },
            test_accuracy: if eval { Some(accuracy) } else { None },
            round_ms,
            distribution_ms: 0.0,
            comm_bytes,
            bytes_to_cloud,
            clients: Vec::new(),
            selected,
            reported,
            dropped,
            avg_staleness,
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
        });
    }

    /// Final event-count stamp and sink flush (no-op when telemetry is
    /// off).
    fn finish_telemetry(&self) -> Result<()> {
        self.tel.counter("sim.events", self.queue.processed());
        self.tel.flush()
    }

    /// Release every client back to Available/Offline so no one is left
    /// mid-round when the simulation ends.
    fn teardown(&mut self) {
        for c in 0..self.clients.len() {
            if self.clients[c].release() {
                self.pool.insert(c);
            } else {
                self.pool.remove(c);
            }
        }
    }

    fn build_report(&self, mode: &str, makespan_ms: f64, wall_ms: f64) -> SimReport {
        let final_accuracy = self
            .tracker
            .final_accuracy()
            .unwrap_or_else(|| self.surrogate.accuracy(self.progress));
        // Read the loss off the tracker so real-training runs report the
        // Engine's actual loss, not the surrogate curve.
        let final_train_loss = self
            .tracker
            .loss_curve()
            .last()
            .map(|(_, loss, _)| *loss)
            .unwrap_or_else(|| self.surrogate.loss(self.progress));
        let (client_ms_p50, client_ms_p95, client_ms_p99) =
            self.client_hist.quantiles_ms();
        let (fold_ms_p50, fold_ms_p95, fold_ms_p99) =
            self.fold_hist.quantiles_ms();
        SimReport {
            mode: mode.to_string(),
            allocation: self.cfg.allocation.name().to_string(),
            availability: self.availability.name(),
            num_clients: self.clients.len(),
            rounds: self.base_rounds + self.tracker.num_rounds(),
            makespan_ms,
            events: self.queue.processed(),
            selected: self.total_selected,
            reported: self.total_reported,
            dropped: self.total_dropped,
            participation: if self.total_selected > 0 {
                self.total_reported as f64 / self.total_selected as f64
            } else {
                0.0
            },
            avg_staleness: if self.staleness_n > 0 {
                self.staleness_sum / self.staleness_n as f64
            } else {
                0.0
            },
            final_accuracy,
            final_train_loss,
            comm_bytes: self.base_comm_bytes + self.tracker.total_comm_bytes(),
            trace_digest: self.queue.trace_digest(),
            wall_ms,
            converged: self.base_rounds + self.tracker.num_rounds()
                == self.target_rounds()
                && self.base_rounds + self.tracker.num_rounds() > 0,
            cancelled: self.cancelled,
            aggregator: self.agg_name.clone(),
            topology: self.topology.name(),
            bytes_to_cloud: self.bytes_to_cloud,
            adversary: self.adversary.name(),
            adversary_frac: self.cfg.sim.adversary_frac,
            envelope_deviation: if self.env_dev_n > 0 {
                self.env_dev_sum / self.env_dev_n as f64
            } else {
                0.0
            },
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
            fold_ms_p50,
            fold_ms_p95,
            fold_ms_p99,
            faults_injected: self.faults_injected,
            consensus_distance: self.consensus_distance,
        }
    }

    /// Rounds this run is configured to complete: `sim.gossip_rounds`
    /// overrides the shared `rounds` knob on the gossip engine only.
    fn target_rounds(&self) -> usize {
        if self.cfg.sim.engine == "gossip" && self.cfg.sim.gossip_rounds > 0 {
            self.cfg.sim.gossip_rounds
        } else {
            self.cfg.rounds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Allocation, DatasetKind, Partition, SimMode};

    fn sim_cfg(mode: SimMode) -> Config {
        let mut cfg = Config::for_dataset(DatasetKind::Cifar10);
        cfg.num_clients = 400;
        cfg.clients_per_round = 20;
        cfg.rounds = 12;
        cfg.partition = Partition::Dirichlet(0.5);
        cfg.num_devices = 4;
        cfg.sim.mode = mode;
        cfg.sim.dropout = 0.1;
        // Generous deadline: most rounds close on their K-th report, a
        // few on the deadline — both paths exercised.
        cfg.sim.deadline_ms = 120_000.0;
        cfg.seed = 7;
        cfg
    }

    #[test]
    fn sync_engine_runs_all_rounds_and_tracks_participation() {
        let cfg = sim_cfg(SimMode::Sync);
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        assert_eq!(report.mode, "sync");
        assert_eq!(report.rounds, 12);
        assert!(report.makespan_ms > 0.0);
        assert!(report.selected >= report.reported);
        assert_eq!(report.selected, report.reported + report.dropped);
        assert!(report.participation > 0.5, "{}", report.participation);
        assert!(report.final_accuracy > 0.0);
        assert!(report.converged, "all configured rounds aggregated");
        assert_eq!(report.avg_staleness, 0.0, "sync rounds are never stale");
        // The always-on quantiles populate without any telemetry config.
        assert!(report.client_ms_p50 > 0.0);
        assert!(report.client_ms_p50 <= report.client_ms_p95);
        assert!(report.client_ms_p95 <= report.client_ms_p99);
        // Every round's reporters fit under the over-selected cohort.
        let t = net.tracker();
        let json = t.to_json();
        for r in json.get("rounds").as_arr().unwrap() {
            let selected = r.req_usize("selected").unwrap();
            let reported = r.req_usize("reported").unwrap();
            assert!(reported <= selected, "reported {reported} > selected {selected}");
            assert!(reported <= cfg.clients_per_round);
            // Per-round client-time quantiles ride the tracker JSON.
            let p50 = r.get("client_ms_p50").as_f64().unwrap();
            let p99 = r.get("client_ms_p99").as_f64().unwrap();
            assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} vs p99 {p99}");
        }
    }

    #[test]
    fn async_engine_aggregates_with_staleness() {
        let mut cfg = sim_cfg(SimMode::Async);
        cfg.sim.async_buffer = 10;
        cfg.sim.async_concurrency = 60;
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        assert_eq!(report.mode, "async");
        assert_eq!(report.rounds, 12);
        assert!(report.makespan_ms > 0.0);
        // 60 concurrent trainers vs buffer 10: most updates land after
        // at least one intervening aggregation.
        assert!(report.avg_staleness > 0.0);
        assert!(report.final_accuracy > 0.0);
    }

    #[test]
    fn all_clients_are_released_after_a_run() {
        for mode in [SimMode::Sync, SimMode::Async] {
            let cfg = sim_cfg(mode);
            let mut net = SimNet::from_config(&cfg).unwrap();
            net.run().unwrap();
            for c in 0..net.num_clients() {
                let phase = net.client_phase(c);
                assert!(
                    matches!(phase, ClientPhase::Available | ClientPhase::Offline),
                    "client {c} stuck in {phase:?} after {mode:?} run"
                );
            }
        }
    }

    #[test]
    fn greedy_beats_slowest_allocation_on_makespan() {
        // Full-cohort aggregation (no over-selection, no dropout, lax
        // deadline) so round time is exactly the scheduling makespan the
        // strategies compete on.
        let run = |alloc| {
            let mut cfg = sim_cfg(SimMode::Sync);
            cfg.allocation = alloc;
            // Small population so adaptive profiling sees repeat clients.
            cfg.num_clients = 30;
            cfg.sim.dropout = 0.0;
            cfg.sim.over_select = 1.0;
            cfg.sim.deadline_ms = 1e9;
            cfg.rounds = 20;
            let mut net = SimNet::from_config(&cfg).unwrap();
            net.run().unwrap().makespan_ms
        };
        let greedy = run(Allocation::GreedyAda);
        let slowest = run(Allocation::Slowest);
        assert!(
            greedy < slowest,
            "greedyada {greedy} should beat slowest {slowest}"
        );
    }

    #[test]
    fn cancellation_probe_stops_at_round_boundaries() {
        for mode in [SimMode::Sync, SimMode::Async] {
            let cfg = sim_cfg(mode);
            let mut net = SimNet::from_config(&cfg).unwrap();
            let tracker = net.tracker();
            let report = net
                .run_cancellable(&|| tracker.num_rounds() >= 3)
                .unwrap();
            assert!(report.cancelled, "{mode:?} run must report the cancel");
            assert!(!report.converged);
            assert_eq!(report.rounds, 3, "{mode:?} stops at the boundary");
            // Teardown still ran: nobody is stuck mid-round.
            for c in 0..net.num_clients() {
                let phase = net.client_phase(c);
                assert!(
                    matches!(phase, ClientPhase::Available | ClientPhase::Offline),
                    "client {c} stuck in {phase:?} after cancelled {mode:?} run"
                );
            }
        }
    }

    #[test]
    fn uncancelled_runs_report_cancelled_false() {
        let report = SimNet::from_config(&sim_cfg(SimMode::Sync))
            .unwrap()
            .run()
            .unwrap();
        assert!(!report.cancelled);
        assert!(report.converged);
    }

    #[test]
    fn sign_flip_adversaries_slow_the_mean_but_not_the_trimmed_mean() {
        let run = |agg: Option<&str>, frac: f64| {
            let mut cfg = sim_cfg(SimMode::Sync);
            cfg.sim.dropout = 0.0;
            cfg.sim.adversary = "sign-flip".into();
            cfg.sim.adversary_frac = frac;
            cfg.agg = agg.map(|s| s.to_string());
            cfg.agg_trim_frac = 0.35;
            SimNet::from_config(&cfg).unwrap().run().unwrap()
        };
        let clean = run(None, 0.0);
        let attacked_mean = run(None, 0.3);
        let attacked_trim = run(Some("trimmed_mean"), 0.3);
        assert_eq!(clean.envelope_deviation, 0.0, "plane off ⇒ no deviation");
        assert_eq!(attacked_mean.aggregator, "mean");
        assert_eq!(attacked_trim.aggregator, "trimmed_mean");
        assert_eq!(attacked_mean.adversary, "sign-flip");
        assert!(
            attacked_mean.final_accuracy < clean.final_accuracy,
            "attack must hurt the plain mean: {} !< {}",
            attacked_mean.final_accuracy,
            clean.final_accuracy
        );
        assert!(
            attacked_trim.final_accuracy > attacked_mean.final_accuracy,
            "trimmed mean must recover: {} !> {}",
            attacked_trim.final_accuracy,
            attacked_mean.final_accuracy
        );
        assert!(
            attacked_mean.envelope_deviation
                > attacked_trim.envelope_deviation,
            "mean strays outside the honest envelope: {} !> {}",
            attacked_mean.envelope_deviation,
            attacked_trim.envelope_deviation
        );
    }

    #[test]
    fn unknown_aggregator_or_adversary_fails_fast_at_construction() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.agg = Some("medoid".into());
        let err = SimNet::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("medoid"), "{err}");
        assert!(err.contains("trimmed_mean"), "{err}");

        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.adversary = "gaslight".into();
        let err = SimNet::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("gaslight"), "{err}");
        assert!(err.contains("sign-flip"), "{err}");
    }

    #[test]
    fn identity_codec_keeps_trace_digests_bit_identical() {
        // The regression guard for the codec subsystem: an unset codec
        // and the explicit "identity" codec must produce the same event
        // trace, makespan and byte accounting as each other — across
        // sync, async and hierarchical timelines.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            let baseline = SimNet::from_config(&base).unwrap().run().unwrap();
            let mut coded = base.clone();
            coded.codec = Some("identity".into());
            let identity = SimNet::from_config(&coded).unwrap().run().unwrap();
            assert_eq!(
                baseline.trace_digest, identity.trace_digest,
                "{mode:?}/{topo}: identity codec shifted the event trace"
            );
            assert_eq!(baseline.makespan_ms, identity.makespan_ms);
            assert_eq!(baseline.comm_bytes, identity.comm_bytes);
            assert_eq!(baseline.bytes_to_cloud, identity.bytes_to_cloud);
            assert_eq!(baseline.rounds, identity.rounds);
        }
    }

    #[test]
    fn telemetry_off_runs_are_bit_identical_to_metrics_only_runs() {
        // The observability regression guard: metrics-only telemetry
        // (NullSink, in-memory registry) must not shift a single event —
        // no extra RNG draws, no queue traffic — across the sync, async
        // and hierarchical timelines.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            let off = SimNet::from_config(&base).unwrap().run().unwrap();
            let mut on_cfg = base.clone();
            on_cfg.telemetry = true;
            let mut traced_net = SimNet::from_config(&on_cfg).unwrap();
            let traced = traced_net.run().unwrap();
            assert_eq!(
                off.trace_digest, traced.trace_digest,
                "{mode:?}/{topo}: telemetry shifted the event trace"
            );
            assert_eq!(off.makespan_ms, traced.makespan_ms);
            assert_eq!(off.comm_bytes, traced.comm_bytes);
            assert_eq!(off.bytes_to_cloud, traced.bytes_to_cloud);
            assert_eq!(off.rounds, traced.rounds);
            // Identical timelines ⇒ identical virtual-time quantiles.
            assert_eq!(off.client_ms_p99, traced.client_ms_p99);
            // The traced run accumulated the metrics the off run skipped.
            let tel = traced_net.telemetry();
            assert_eq!(tel.counter_value("sim.events"), traced.events);
            assert!(tel.quantiles_ms("sim.fold_ms").is_some());
        }
    }

    #[test]
    fn sketch_sampling_and_feedback_knobs_keep_digests_bit_identical() {
        // Regression guard for the ingest/sketch PR's knobs, across the
        // sync, async and hierarchical timelines with an active
        // adversary so the robust surrogate reduction actually runs:
        //
        // * `agg_sketch` — SimNet cohorts sit under the sketch cap, so
        //   the sketch aggregators are in their exact regime and draw no
        //   RNG: every reduced value (and hence the trace) is identical.
        // * `trace_sample` — sampling decisions are pure hashes, so even
        //   a heavily thinned traced run cannot shift the simulation.
        // * `codec_error_feedback` — a client-flow concern; the
        //   simulator's surrogate timeline must not notice the knob.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            base.agg = Some("trimmed_mean".into());
            base.sim.adversary = "sign-flip".into();
            base.sim.adversary_frac = 0.2;
            let exact = SimNet::from_config(&base).unwrap().run().unwrap();

            let mut sk_cfg = base.clone();
            sk_cfg.agg_sketch = true;
            let sketch = SimNet::from_config(&sk_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, sketch.trace_digest,
                "{mode:?}/{topo}: agg_sketch shifted the event trace"
            );
            assert_eq!(exact.makespan_ms, sketch.makespan_ms);
            assert_eq!(exact.final_accuracy, sketch.final_accuracy);

            let mut ts_cfg = base.clone();
            ts_cfg.telemetry = true;
            ts_cfg.trace_sample = 0.25;
            let sampled = SimNet::from_config(&ts_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, sampled.trace_digest,
                "{mode:?}/{topo}: trace_sample shifted the event trace"
            );

            let mut ef_cfg = base.clone();
            ef_cfg.codec = Some("identity".into());
            ef_cfg.codec_error_feedback = true;
            let fed = SimNet::from_config(&ef_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, fed.trace_digest,
                "{mode:?}/{topo}: codec_error_feedback leaked into the sim"
            );
        }
    }

    #[test]
    fn codec_compression_cuts_comm_bytes_and_makespan() {
        let base = sim_cfg(SimMode::Sync);
        let dense = SimNet::from_config(&base).unwrap().run().unwrap();
        let mut cfg = base.clone();
        cfg.codec = Some("top_k_i8(0.05)".into());
        let coded = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(coded.rounds, dense.rounds);
        // Uplinks shrink ~16x; downlinks stay dense, so total comm drops
        // but not by the full codec ratio.
        assert!(
            coded.comm_bytes < dense.comm_bytes,
            "coded {} !< dense {}",
            coded.comm_bytes,
            dense.comm_bytes
        );
        // Smaller uploads ⇒ every report lands earlier ⇒ rounds close
        // sooner over mobile-WAN links.
        assert!(
            coded.makespan_ms < dense.makespan_ms,
            "coded {} !< dense {}",
            coded.makespan_ms,
            dense.makespan_ms
        );
        // Flat fan-in also charges encoded bytes at the cloud.
        assert!(coded.bytes_to_cloud < dense.bytes_to_cloud);
    }

    #[test]
    fn finite_cloud_ingest_charges_hierarchical_fanin() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.topology = "edges(4)".to_string();
        let free = SimNet::from_config(&cfg).unwrap().run().unwrap();
        let mut slow = cfg.clone();
        // 1.6 MB per edge partial at 1000 B/ms = 1.6 s extra per window.
        slow.sim.cloud_ingest_bytes_per_ms = 1_000.0;
        let charged = SimNet::from_config(&slow).unwrap().run().unwrap();
        assert_eq!(free.rounds, charged.rounds);
        assert!(
            charged.makespan_ms > free.makespan_ms,
            "finite ingest must lengthen the run: {} !> {}",
            charged.makespan_ms,
            free.makespan_ms
        );
    }

    #[test]
    fn diurnal_availability_limits_the_pool() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.availability = "diurnal(0.3,1000000)".into();
        cfg.sim.dropout = 0.0;
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        // Roughly 30% of 400 clients online at a time; rounds still run.
        assert_eq!(report.rounds, 12);
        assert!(report.reported > 0);
    }

    #[test]
    fn crash_safe_knobs_off_keep_digests_bit_identical() {
        // Checkpointing must be a pure observer: a run that *writes*
        // checkpoints (but never resumes) is bit-identical to one that
        // doesn't, across sync, async and hierarchical timelines. And a
        // tampered checkpoint must be a typed integrity error, never a
        // silently-wrong resume.
        for (i, (mode, topo)) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ]
        .into_iter()
        .enumerate()
        {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            let clean = SimNet::from_config(&base).unwrap().run().unwrap();
            assert_eq!(clean.faults_injected, 0, "chaos off ⇒ no faults");

            let dir = std::env::temp_dir().join(format!(
                "easyfl_ckpt_neutral_{}_{i}",
                std::process::id()
            ));
            let mut ck_cfg = base.clone();
            ck_cfg.checkpoint_every = 4;
            ck_cfg.checkpoint_dir = Some(dir.clone());
            let saved = SimNet::from_config(&ck_cfg).unwrap().run().unwrap();
            assert_eq!(
                clean.trace_digest, saved.trace_digest,
                "{mode:?}/{topo}: checkpointing shifted the event trace"
            );
            assert_eq!(clean.makespan_ms, saved.makespan_ms);
            assert_eq!(clean.comm_bytes, saved.comm_bytes);
            let ckpt = checkpoint::checkpoint_path(&dir, 4);
            assert!(ckpt.is_file(), "missing {}", ckpt.display());

            // Flip one payload byte: resuming must fail loudly.
            checkpoint::corrupt_file(&ckpt).unwrap();
            let mut bad_cfg = ck_cfg.clone();
            bad_cfg.checkpoint_every = 0;
            bad_cfg.checkpoint_dir = None;
            bad_cfg.resume_from = Some(ckpt);
            let err = SimNet::from_config(&bad_cfg)
                .unwrap()
                .run()
                .unwrap_err();
            assert!(
                matches!(err, Error::Integrity(_)),
                "tampered checkpoint must be Error::Integrity, got {err:?}"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn sync_resume_from_checkpoint_reproduces_the_digest() {
        let base = sim_cfg(SimMode::Sync);
        let clean = SimNet::from_config(&base).unwrap().run().unwrap();

        // Kill the server after round 6 (the boundary checkpoint is
        // written first, so the kill point is always resumable).
        let dir = std::env::temp_dir().join(format!(
            "easyfl_ckpt_resume_{}",
            std::process::id()
        ));
        let mut killed_cfg = base.clone();
        killed_cfg.checkpoint_every = 3;
        killed_cfg.checkpoint_dir = Some(dir.clone());
        killed_cfg.chaos = vec!["kill_server_at_round(6)".into()];
        let killed = SimNet::from_config(&killed_cfg).unwrap().run().unwrap();
        assert!(killed.cancelled, "the kill fault must stop the run");
        assert_eq!(killed.rounds, 6);
        assert!(killed.faults_injected >= 1);

        // Resume in a fresh process-equivalent: new simulator, chaos
        // cleared, state restored from the round-6 checkpoint.
        let mut resume_cfg = base.clone();
        resume_cfg.resume_from = Some(checkpoint::checkpoint_path(&dir, 6));
        let resumed =
            SimNet::from_config(&resume_cfg).unwrap().run().unwrap();
        assert_eq!(
            resumed.trace_digest, clean.trace_digest,
            "resumed run must replay the uninterrupted trace bit-for-bit"
        );
        assert_eq!(resumed.makespan_ms, clean.makespan_ms);
        assert_eq!(resumed.rounds, clean.rounds);
        assert_eq!(resumed.selected, clean.selected);
        assert_eq!(resumed.comm_bytes, clean.comm_bytes);
        assert!(resumed.converged);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn churn_grows_the_population_deterministically() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.churn = "grow(2)".into();
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        // Churn applies at the 11 interior boundaries of a 12-round run.
        assert_eq!(report.num_clients, 400 + 2 * 11);
        assert_eq!(net.num_clients(), 422);
        assert_eq!(report.rounds, 12);

        // Same seed ⇒ same churn ⇒ same trace, twice.
        let again = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.trace_digest, again.trace_digest);
        assert_eq!(again.num_clients, 422);

        // And churn off leaves the population alone.
        let mut off = sim_cfg(SimMode::Sync);
        off.sim.churn = "none".into();
        let still = SimNet::from_config(&off).unwrap().run().unwrap();
        assert_eq!(still.num_clients, 400);
    }

    fn gossip_cfg(k: usize) -> Config {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.engine = "gossip".into();
        cfg.topology = format!("gossip({k})");
        cfg
    }

    #[test]
    fn gossip_engine_runs_p2p_rounds_with_zero_cloud_bytes() {
        let cfg = gossip_cfg(8);
        let report = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.mode, "gossip");
        assert_eq!(report.rounds, 12);
        assert!(report.converged);
        assert_eq!(
            report.bytes_to_cloud, 0,
            "gossip is serverless — nothing may cross into the cloud"
        );
        assert!(report.comm_bytes > 0, "P2P traffic must be charged");
        assert!(report.reported > 0);
        assert!(report.consensus_distance.is_finite());
        assert!(report.consensus_distance > 0.0);

        // More gossip rounds ⇒ more mixing against decaying drift.
        let mut long = gossip_cfg(8);
        long.sim.gossip_rounds = 40;
        let mixed = SimNet::from_config(&long).unwrap().run().unwrap();
        assert_eq!(mixed.rounds, 40, "gossip_rounds overrides rounds");
        assert!(
            mixed.consensus_distance < report.consensus_distance,
            "40 rounds must mix tighter than 12: {} !< {}",
            mixed.consensus_distance,
            report.consensus_distance
        );

        // Same seed ⇒ same trace, twice.
        let again = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.trace_digest, again.trace_digest);
        assert_eq!(report.consensus_distance, again.consensus_distance);
    }

    #[test]
    fn ring_all_reduce_closes_consensus_with_full_participation() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.engine = "gossip".into();
        cfg.topology = "ring".into();
        cfg.sim.dropout = 0.0;
        // Generous deadline: the slowest of all 400 peers must land, or
        // its stale state keeps consensus open.
        cfg.sim.deadline_ms = 10_000_000.0;
        let report = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.mode, "gossip");
        assert_eq!(report.bytes_to_cloud, 0);
        assert!(
            report.consensus_distance < 1e-4,
            "every round's all-reduce puts all participants on one \
             state, got {}",
            report.consensus_distance
        );
    }

    #[test]
    fn gossip_config_pairing_is_validated() {
        // Engine without a peer shape.
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.engine = "gossip".into();
        assert!(SimNet::from_config(&cfg).is_err());
        // Peer shape without the engine.
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.topology = "gossip(8)".into();
        assert!(SimNet::from_config(&cfg).is_err());
        // Gossip composes with neither churn, real training, nor
        // partition_edge.
        let mut cfg = gossip_cfg(8);
        cfg.sim.churn = "grow(2)".into();
        assert!(SimNet::from_config(&cfg).is_err());
        let mut cfg = gossip_cfg(8);
        cfg.sim.real_training = true;
        assert!(SimNet::from_config(&cfg).is_err());
        let mut cfg = gossip_cfg(8);
        cfg.chaos = vec!["partition_edge(0)".into()];
        assert!(SimNet::from_config(&cfg).is_err());
        // Infeasible graph dims fail at construction, and the
        // aggregator probe runs for gossip even without an adversary.
        let mut cfg = gossip_cfg(8);
        cfg.num_clients = 5;
        assert!(SimNet::from_config(&cfg).is_err());
        let mut cfg = gossip_cfg(8);
        cfg.agg = Some("medoid".into());
        assert!(SimNet::from_config(&cfg).is_err());
    }

    #[test]
    fn gossip_resume_from_chaos_kill_reproduces_the_digest() {
        let base = gossip_cfg(8);
        let clean = SimNet::from_config(&base).unwrap().run().unwrap();

        let dir = std::env::temp_dir().join(format!(
            "easyfl_ckpt_gossip_{}",
            std::process::id()
        ));
        let mut killed_cfg = base.clone();
        killed_cfg.checkpoint_every = 3;
        killed_cfg.checkpoint_dir = Some(dir.clone());
        killed_cfg.chaos = vec!["kill_server_at_round(6)".into()];
        let killed = SimNet::from_config(&killed_cfg).unwrap().run().unwrap();
        assert!(killed.cancelled);
        assert_eq!(killed.rounds, 6);

        let mut resume_cfg = base.clone();
        resume_cfg.resume_from = Some(checkpoint::checkpoint_path(&dir, 6));
        let resumed =
            SimNet::from_config(&resume_cfg).unwrap().run().unwrap();
        assert_eq!(
            resumed.trace_digest, clean.trace_digest,
            "resumed gossip run must replay the uninterrupted trace"
        );
        assert_eq!(resumed.makespan_ms, clean.makespan_ms);
        assert_eq!(resumed.rounds, clean.rounds);
        assert_eq!(resumed.consensus_distance, clean.consensus_distance);
        assert_eq!(resumed.bytes_to_cloud, 0);
        assert!(resumed.converged);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn robust_neighborhood_folds_blunt_gossip_adversaries() {
        // A sign-flipping minority poisons mean neighborhood folds but
        // is filtered by per-neighborhood trimmed means — consensus
        // across honest clients stays tighter under the robust rule.
        let mut mean_cfg = gossip_cfg(8);
        mean_cfg.sim.adversary = "scaled-noise".into();
        mean_cfg.sim.adversary_frac = 0.2;
        mean_cfg.sim.gossip_rounds = 20;
        let mut trim_cfg = mean_cfg.clone();
        trim_cfg.agg = Some("trimmed_mean".into());
        trim_cfg.agg_trim_frac = 0.3;
        let mean = SimNet::from_config(&mean_cfg).unwrap().run().unwrap();
        let trim = SimNet::from_config(&trim_cfg).unwrap().run().unwrap();
        assert!(
            trim.consensus_distance < mean.consensus_distance,
            "trimmed folds must out-mix the mean under attack: {} !< {}",
            trim.consensus_distance,
            mean.consensus_distance
        );
        // The attack never shifts the event timeline (dedicated
        // streams): both runs replay the same trace.
        assert_eq!(mean.trace_digest, trim.trace_digest);
    }

    #[test]
    fn wire_chaos_faults_count_and_rounds_still_complete() {
        let base = sim_cfg(SimMode::Sync);
        let clean = SimNet::from_config(&base).unwrap().run().unwrap();
        assert_eq!(clean.faults_injected, 0);

        let mut cut_cfg = base.clone();
        cut_cfg.chaos = vec!["drop_midframe(0.3)".into()];
        let cut = SimNet::from_config(&cut_cfg).unwrap().run().unwrap();
        assert_eq!(cut.rounds, 12);
        assert!(cut.faults_injected > 0, "mid-frame cuts must count");
        assert!(cut.reported < clean.reported);

        let mut stall_cfg = base.clone();
        stall_cfg.chaos = vec!["stall_frames(0.5,2000)".into()];
        let stalled = SimNet::from_config(&stall_cfg).unwrap().run().unwrap();
        assert_eq!(stalled.rounds, 12);
        assert!(stalled.faults_injected > 0, "stalls must count");
        assert!(
            stalled.makespan_ms >= clean.makespan_ms,
            "stalled frames cannot shorten the run: {} < {}",
            stalled.makespan_ms,
            clean.makespan_ms
        );
    }

    #[test]
    fn checkpoint_retention_keeps_only_the_newest() {
        let dir = std::env::temp_dir().join(format!(
            "easyfl_ckpt_retention_{}",
            std::process::id()
        ));
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.checkpoint_every = 3;
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_keep = 1;
        let report = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds, 12);
        // Boundaries at 3, 6 and 9 each saved; only round 9 survives the
        // prune, and it must still be resumable.
        for gone in [3, 6] {
            assert!(
                !checkpoint::checkpoint_path(&dir, gone).exists(),
                "round-{gone} checkpoint should have been pruned"
            );
        }
        let kept = checkpoint::checkpoint_path(&dir, 9);
        assert!(kept.is_file(), "newest checkpoint must survive");
        let mut resume_cfg = cfg.clone();
        resume_cfg.checkpoint_every = 0;
        resume_cfg.checkpoint_dir = None;
        resume_cfg.checkpoint_keep = 0;
        resume_cfg.resume_from = Some(kept);
        let clean = SimNet::from_config(&sim_cfg(SimMode::Sync))
            .unwrap()
            .run()
            .unwrap();
        let resumed =
            SimNet::from_config(&resume_cfg).unwrap().run().unwrap();
        assert_eq!(resumed.trace_digest, clean.trace_digest);
        std::fs::remove_dir_all(&dir).ok();
    }
}
