//! The SimNet engines: synchronous deadline rounds and async FedBuff.
//!
//! Two round engines run on the same event queue, client population,
//! cost model and availability traces:
//!
//! * **Sync** — each round over-selects `K · over_select` clients from
//!   the available pool, allocates them to the `num_devices` virtual
//!   devices with the *real* scheduler [`Strategy`] (GreedyAda / Random /
//!   Slowest — unchanged), aggregates as soon as the first `K` reports
//!   arrive or the deadline fires, and drops the stragglers back into
//!   the pool.
//! * **Async (FedBuff)** — keeps up to `async_concurrency` clients
//!   training at all times and aggregates every `async_buffer` arrivals
//!   with staleness-discounted weights `(1 + staleness)^-α`.
//!
//! Training is surrogate by default (seconds for 100k clients × 500
//! rounds); setting `sim.real_training` plugs the real [`Server`] /
//! Engine in for small cohorts.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::aggregate::{AggContext, FedBuffBuffer};
use crate::config::{Config, SimMode};
use crate::coordinator::Server;
use crate::data::partition::build_clients;
use crate::data::synth;
use crate::error::Result;
use crate::flow::Update;
use crate::hierarchy::{HierPlane, Topology};
use crate::model::ParamVec;
use crate::obs::{Histogram, Span, Telemetry};
use crate::registry;
use crate::scheduler::{make_strategy, Strategy};
use crate::tracking::{RoundMetrics, Tracker};
use crate::util::clock::{Stopwatch, VirtualClock};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::adversary::AdversaryModel;
use super::client_state::{AvailabilityModel, ClientPhase, ClientState, Pool};
use super::cost::CostModel;
use super::events::{EventKind, EventQueue};
use super::surrogate::SurrogateModel;

/// Skew is a population statistic; estimating it from a bounded sample
/// keeps million-client federations cheap to set up.
const SKEW_SAMPLE_CLIENTS: usize = 10_000;

/// Parameter length of the surrogate update plane the adversary path
/// reduces through the real registered aggregators: wide enough for
/// per-coordinate rank statistics to be meaningful, small enough that a
/// reduction per aggregation costs nothing.
const SURROGATE_P: usize = 32;

/// Outcome of one SimNet run — the numbers the `simulate` CLI prints
/// and [`crate::platform::SimSweep`] tabulates.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// "sync" | "async".
    pub mode: String,
    /// Scheduler strategy name (sync engine only).
    pub allocation: String,
    pub availability: String,
    pub num_clients: usize,
    /// Rounds actually aggregated.
    pub rounds: usize,
    /// Virtual time of the last aggregation.
    pub makespan_ms: f64,
    /// Events processed (throughput = events / wall_ms).
    pub events: u64,
    pub selected: u64,
    pub reported: u64,
    pub dropped: u64,
    /// reported / selected.
    pub participation: f64,
    /// Mean staleness of aggregated updates (0 for sync).
    pub avg_staleness: f64,
    pub final_accuracy: f64,
    pub final_train_loss: f64,
    pub comm_bytes: usize,
    /// Order-sensitive digest of the full event trace; equal seeds ⇒
    /// equal digests.
    pub trace_digest: u64,
    /// Real elapsed wall time of the run.
    pub wall_ms: f64,
    /// True when every configured round actually aggregated; false for
    /// truncated runs (e.g. a starved async engine broke out early).
    pub converged: bool,
    /// True when a cancellation probe stopped the run at a round
    /// boundary (see [`SimNet::run_cancellable`]); the report covers the
    /// rounds that completed before the cancel.
    pub cancelled: bool,
    /// Registered aggregator the run reduced with ("mean" unless
    /// `Config.agg` overrode it).
    pub aggregator: String,
    /// Federation topology the run simulated ("flat" | "edges(n)" | ...).
    pub topology: String,
    /// Bytes that crossed into the cloud aggregator: every reporter's
    /// update for a flat topology, one dense partial per active edge per
    /// aggregation for a hierarchical one — the fan-in headline
    /// `examples/hier_scale.rs` benchmarks.
    pub bytes_to_cloud: usize,
    /// Adversary model configured for the run (inert at fraction 0).
    pub adversary: String,
    /// Fraction of the population behaving Byzantine.
    pub adversary_frac: f64,
    /// Mean per-coordinate distance of the aggregate outside the honest
    /// reporters' envelope, averaged over aggregations — 0 both when the
    /// aggregator contained every attack and when the adversary plane
    /// was off.
    pub envelope_deviation: f64,
    /// p50 of per-report client service time (compute + upload, virtual
    /// ms) over the whole run — the tail the deadline actually fights.
    pub client_ms_p50: f64,
    pub client_ms_p95: f64,
    pub client_ms_p99: f64,
    /// p50 of the *wall-clock* time each aggregation-window fold took on
    /// the host (straggler sweep + robust reduce + fan-in + metrics).
    pub fold_ms_p50: f64,
    pub fold_ms_p95: f64,
    pub fold_ms_p99: f64,
}

impl SimReport {
    /// Events processed per second of wall time.
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }

    /// Rounds aggregated per second of wall time.
    pub fn rounds_per_sec(&self) -> f64 {
        self.rounds as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }

    /// Throughput fields as a JSON object — merged into `BENCH_*.json`
    /// artifacts by [`crate::util::bench::write_bench`].
    pub fn bench_fields(&self) -> Json {
        obj([
            ("clients", Json::Num(self.num_clients as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("events_per_sec", Json::Num(self.events_per_sec())),
            ("rounds_per_sec", Json::Num(self.rounds_per_sec())),
            ("makespan_ms", Json::Num(self.makespan_ms)),
            ("client_ms_p50", Json::Num(self.client_ms_p50)),
            ("client_ms_p95", Json::Num(self.client_ms_p95)),
            ("client_ms_p99", Json::Num(self.client_ms_p99)),
            ("fold_ms_p50", Json::Num(self.fold_ms_p50)),
            ("fold_ms_p95", Json::Num(self.fold_ms_p95)),
            ("fold_ms_p99", Json::Num(self.fold_ms_p99)),
        ])
    }

    /// Throughput benchmark JSON (the `BENCH_simnet.json` CI artifact);
    /// shared by the `simulate --bench-out` flag and `simnet_scale`.
    pub fn bench_json(&self) -> String {
        let mut text = self.bench_fields().to_pretty();
        text.push('\n');
        text
    }

    /// Project onto the training [`crate::api::Report`] shape so SimNet
    /// jobs ride the same `Platform` plumbing as real sessions.
    pub fn to_report(&self) -> crate::api::Report {
        crate::api::Report {
            final_accuracy: self.final_accuracy,
            best_accuracy: self.final_accuracy,
            final_train_loss: self.final_train_loss,
            avg_round_ms: if self.rounds > 0 {
                self.makespan_ms / self.rounds as f64
            } else {
                0.0
            },
            comm_bytes: self.comm_bytes,
            rounds: self.rounds,
            converged: self.converged,
        }
    }
}

/// A discrete-event federation simulator over one [`Config`].
pub struct SimNet {
    cfg: Config,
    availability: AvailabilityModel,
    cost: CostModel,
    surrogate: SurrogateModel,
    strategy: Box<dyn Strategy>,
    tracker: Arc<Tracker>,
    queue: EventQueue,
    clients: Vec<ClientState>,
    pool: Pool,
    rng: Rng,
    /// Real-Engine backend for small cohorts (`sim.real_training`).
    server: Option<Server>,
    /// Global model version = aggregations performed.
    version: usize,
    /// Effective aggregated rounds (drives the surrogate curves).
    progress: f64,
    total_selected: u64,
    total_reported: u64,
    total_dropped: u64,
    staleness_sum: f64,
    staleness_n: u64,
    /// Set when a cancellation probe fired at a round boundary.
    cancelled: bool,
    /// Registered aggregator the adversary plane (and report) names.
    agg_name: String,
    /// Aggregation-tree shape; non-flat runs reduce per edge, ship one
    /// partial per active edge to the cloud, and pay an edge hop per
    /// aggregation. Flat runs are bit-identical to the pre-hierarchy
    /// timeline.
    topology: Topology,
    /// Cloud fan-in accumulated over the run (see
    /// [`SimReport::bytes_to_cloud`]).
    bytes_to_cloud: usize,
    /// Wire size of one client upload: `model_bytes` when no codec is
    /// configured, the codec's predicted encoded size otherwise. Every
    /// uplink costing site (upload delay, `comm_bytes`, flat
    /// `bytes_to_cloud`) charges this instead of the flat dense size.
    uplink_bytes: usize,
    /// Attack corrupting Byzantine clients' surrogate deltas.
    adversary: AdversaryModel,
    /// Per-client Byzantine flag, fixed at setup (seed-deterministic).
    adversarial: Vec<bool>,
    /// Dedicated adversary RNG: forked off the seed, never off the main
    /// stream, so `adversary_frac = 0` burns nothing and the event trace
    /// is identical with the plane on or off.
    adv_rng: Rng,
    env_dev_sum: f64,
    env_dev_n: u64,
    /// Telemetry plane. Spans carry *virtual* time: `vclock` mirrors the
    /// event queue's clock, written only when telemetry is on. Probes
    /// draw no RNG and push no events, so `telemetry = off` timelines
    /// are bit-identical (regression-tested below).
    tel: Telemetry,
    vclock: Arc<VirtualClock>,
    /// Per-report client service times (virtual ms), whole run.
    client_hist: Histogram,
    /// Wall-clock latency of each aggregation-window fold.
    fold_hist: Histogram,
}

impl SimNet {
    /// Build a simulator with its own in-memory tracker.
    pub fn from_config(cfg: &Config) -> Result<SimNet> {
        let label = format!(
            "simnet-{}-{}-{}-{}",
            cfg.sim.mode.name(),
            cfg.allocation.name(),
            cfg.partition.name(),
            cfg.seed
        );
        Self::with_tracker(cfg, Arc::new(Tracker::new(&label)))
    }

    /// Build a simulator recording into an existing tracker.
    pub fn with_tracker(cfg: &Config, tracker: Arc<Tracker>) -> Result<SimNet> {
        cfg.validate()?;
        let num_clients = if cfg.num_clients > 0 {
            cfg.num_clients
        } else {
            synth::natural_clients(cfg.dataset)
        };
        let availability =
            registry::with_global(|r| r.availability(&cfg.sim.availability))?;
        let cost =
            registry::with_global(|r| r.cost_model(&cfg.sim.cost_model, cfg))?;
        let adversary =
            registry::with_global(|r| r.adversary(&cfg.sim.adversary))?;
        let topology = registry::with_global(|r| r.topology(&cfg.topology))?;
        let agg_name = cfg.agg.clone().unwrap_or_else(|| "mean".to_string());
        if cfg.agg.is_some() || cfg.sim.adversary_frac > 0.0 {
            // Fail fast on an unknown or misconfigured aggregator before
            // the run starts (the probe also validates trim/clip knobs).
            let probe =
                AggContext::from_config(Arc::new(ParamVec::zeros(1)), cfg);
            registry::with_global(|r| r.aggregator(&agg_name, &probe))?;
        }
        if let Some(edge_agg) = &cfg.edge_agg {
            let probe =
                AggContext::from_config(Arc::new(ParamVec::zeros(1)), cfg);
            registry::with_global(|r| r.aggregator(edge_agg, &probe))?;
        }
        // Codec-compressed uplinks change the wire size every costing
        // site charges. The surrogate plane carries no real updates, so
        // the encoded size is a deterministic per-run constant: the
        // codec's predicted wire size for a dense `model_bytes` update.
        // No codec — or `"identity"` — yields `model_bytes` exactly, and
        // the probe draws no RNG, so unencoded trace digests stay
        // bit-identical.
        let uplink_bytes = match &cfg.codec {
            Some(spec) => {
                let codec = registry::with_global(|r| r.codec(spec))?;
                codec.wire_bytes_for(cost.model_bytes)
            }
            None => cost.model_bytes,
        };
        let mut rng = Rng::new(cfg.seed ^ 0x5349_4D4E_4554); // "SIMNET"

        // The adversary stream is seeded independently of the main RNG:
        // flipping `adversary_frac` must never shift selection,
        // scheduling or availability draws (trace digests stay equal).
        let mut adv_rng = Rng::new(cfg.seed ^ 0x4144_5645_5253); // "ADVERS"

        // Partition skew drives the surrogate curves; estimate it from a
        // bounded client sample so huge populations stay cheap.
        let (num_classes, _, _) = synth::shape_of(cfg.dataset);
        let specs = build_clients(
            cfg.dataset,
            num_clients.min(SKEW_SAMPLE_CLIENTS),
            cfg.partition,
            cfg.unbalanced,
            cfg.max_samples,
            &mut rng.fork(0x5045),
        )?;
        let surrogate = SurrogateModel::from_clients(num_classes, &specs);

        let mut clients = Vec::with_capacity(num_clients);
        for _ in 0..num_clients {
            let device = cost.sample_device(&mut rng);
            let bandwidth = cost.sample_bandwidth(&mut rng);
            clients.push(ClientState::new(device, bandwidth));
        }

        let server = if cfg.sim.real_training {
            // SimNet owns the run's trace/metrics output files; the
            // backing real-training server keeps its own (wall-clock)
            // telemetry off so the two never write the same paths.
            let mut inner = cfg.clone();
            inner.telemetry = false;
            inner.trace_out = None;
            inner.metrics_out = None;
            let mut builder = crate::api::SessionBuilder::new(inner);
            Some(builder.build()?.build_server()?)
        } else {
            None
        };

        // Seed-deterministic Byzantine cohort: exactly ⌊frac·n⌉ clients,
        // drawn from the dedicated adversary stream.
        let mut adversarial = vec![false; num_clients];
        if cfg.sim.adversary_frac > 0.0 {
            let k = ((cfg.sim.adversary_frac * num_clients as f64).round()
                as usize)
                .min(num_clients.saturating_sub(1));
            for c in adv_rng.choose_indices(num_clients, k) {
                adversarial[c] = true;
            }
        }

        tracker.set_config("sim_mode", cfg.sim.mode.name().to_string());
        tracker.set_config("availability", availability.name());
        tracker.set_config("cost_model", cost.name.clone());
        tracker.set_config("allocation", cfg.allocation.name().to_string());
        tracker.set_config("num_clients", num_clients.to_string());
        tracker.set_config("aggregator", agg_name.clone());
        tracker.set_config("topology", topology.name());
        if let Some(codec) = &cfg.codec {
            tracker.set_config("codec", codec.clone());
        }
        if cfg.sim.adversary_frac > 0.0 {
            tracker.set_config("adversary", adversary.name());
            tracker
                .set_config("adversary_frac", cfg.sim.adversary_frac.to_string());
        }

        let vclock = Arc::new(VirtualClock::new());
        let tel = Telemetry::from_config(cfg, vclock.clone())?;
        tracker.set_telemetry(tel.clone());

        Ok(SimNet {
            strategy: make_strategy(
                cfg.allocation,
                cfg.default_client_time_ms,
                cfg.profile_momentum,
            ),
            availability,
            cost,
            surrogate,
            tracker,
            queue: EventQueue::new(),
            pool: Pool::new(num_clients),
            clients,
            rng,
            server,
            version: 0,
            progress: 0.0,
            total_selected: 0,
            total_reported: 0,
            total_dropped: 0,
            staleness_sum: 0.0,
            staleness_n: 0,
            cancelled: false,
            agg_name,
            topology,
            bytes_to_cloud: 0,
            uplink_bytes,
            adversary,
            adversarial,
            adv_rng,
            env_dev_sum: 0.0,
            env_dev_n: 0,
            tel,
            vclock,
            client_hist: Histogram::new(),
            fold_hist: Histogram::new(),
            cfg: cfg.clone(),
        })
    }

    pub fn tracker(&self) -> Arc<Tracker> {
        self.tracker.clone()
    }

    /// The run's telemetry handle (off unless the config enabled it).
    pub fn telemetry(&self) -> Telemetry {
        self.tel.clone()
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Lifecycle phase of one client (tests / diagnostics).
    pub fn client_phase(&self, client: usize) -> ClientPhase {
        self.clients[client].phase
    }

    /// Size of the available pool right now.
    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }

    /// Run the configured engine to completion.
    pub fn run(&mut self) -> Result<SimReport> {
        self.run_cancellable(&|| false)
    }

    /// Run, polling `cancel` at every aggregation boundary. A triggered
    /// probe stops the simulation, releases every client, and returns a
    /// partial report with [`SimReport::cancelled`] set — this is what
    /// [`crate::platform::Platform::submit_sim`] jobs poll
    /// `JobCtx::cancelled` through.
    pub fn run_cancellable(
        &mut self,
        cancel: &dyn Fn() -> bool,
    ) -> Result<SimReport> {
        match self.cfg.sim.mode {
            SimMode::Sync => self.run_sync(cancel),
            SimMode::Async => self.run_async(cancel),
        }
    }

    // ------------------------------------------------------ population

    /// Seed every client's availability trace and initial pool state.
    fn init_population(&mut self) {
        for c in 0..self.clients.len() {
            let phase = self.availability.sample_phase_ms(&mut self.rng);
            let online = self.availability.initial_online(phase, &mut self.rng);
            self.clients[c].avail_phase_ms = phase;
            self.clients[c].online = online;
            self.clients[c].release();
            if online {
                self.pool.insert(c);
            }
            let next =
                self.availability.next_toggle_ms(online, phase, 0.0, &mut self.rng);
            if next.is_finite() {
                let kind = if online {
                    EventKind::Offline { client: c }
                } else {
                    EventKind::Online { client: c }
                };
                self.queue.push(next, kind);
            }
        }
    }

    /// Apply an availability flip and schedule the next one.
    fn handle_toggle(&mut self, client: usize, online: bool, now_ms: f64) {
        self.clients[client].online = online;
        if !self.clients[client].is_busy() {
            // Idle clients move between pool and offline immediately;
            // busy clients finish their round first (release() decides).
            if self.clients[client].release() {
                self.pool.insert(client);
            } else {
                self.pool.remove(client);
            }
        }
        let phase = self.clients[client].avail_phase_ms;
        let next =
            self.availability.next_toggle_ms(online, phase, now_ms, &mut self.rng);
        if next.is_finite() {
            let kind = if online {
                EventKind::Offline { client }
            } else {
                EventKind::Online { client }
            };
            self.queue.push(next, kind);
        }
    }

    /// True when an in-flight event still refers to the client's current
    /// selection (stale reports/dropouts are ignored).
    fn live_event(&self, client: usize, epoch: u64) -> bool {
        let c = &self.clients[client];
        c.epoch == epoch && c.is_busy()
    }

    /// Pull up to `k` clients out of the pool into Training.
    fn select_cohort(&mut self, k: usize) -> Vec<usize> {
        let cohort = self.pool.sample(k, &mut self.rng);
        for &c in &cohort {
            self.clients[c].select(self.version);
            self.clients[c].begin_training();
        }
        self.total_selected += cohort.len() as u64;
        cohort
    }

    /// Schedule one client's report (or mid-round dropout) starting at
    /// `start_ms`; returns the duration it occupies its device slot.
    fn schedule_client(&mut self, client: usize, start_ms: f64) -> f64 {
        let device = self.clients[client].device_class;
        let bandwidth = self.clients[client].bandwidth_bytes_per_ms;
        let compute = self.cost.compute_ms(device, &mut self.rng);
        // Charge the actual wire size (codec-encoded when configured);
        // one RNG draw either way, so unencoded digests are untouched.
        let upload =
            self.cost
                .upload_bytes_ms(self.uplink_bytes, bandwidth, &mut self.rng);
        let total = compute + upload;
        // Wire accounting for the codec dashboards: what this upload
        // costs on the wire vs what a dense one would have. Counters are
        // no-ops when telemetry is off and draw no RNG either way.
        self.tel.counter("codec.encoded_bytes", self.uplink_bytes as u64);
        self.tel.counter("codec.dense_bytes", self.cost.model_bytes as u64);
        self.clients[client].service_ms = total;
        let epoch = self.clients[client].epoch;
        let dropout = self.cfg.sim.dropout;
        if dropout > 0.0 && self.rng.uniform() < dropout {
            // Abandon at a uniform point of the round; the device slot
            // frees early.
            let duration = total * self.rng.uniform();
            self.queue
                .push(start_ms + duration, EventKind::Dropout { client, epoch });
            duration
        } else {
            self.queue
                .push(start_ms + total, EventKind::Report { client, epoch });
            total
        }
    }

    /// Mark a finished (reported/dropped) client and return it to the
    /// pool when its availability trace says it is still online.
    fn release(&mut self, client: usize) {
        if self.clients[client].release() {
            self.pool.insert(client);
        }
    }

    /// Loss/accuracy for the round just aggregated: surrogate curves by
    /// default, one real Engine round when `sim.real_training` is set.
    fn backend_metrics(&mut self, round: usize) -> Result<(f64, f64)> {
        let real = match self.server.as_mut() {
            Some(server) => Some(server.run_round(round)?),
            None => None,
        };
        Ok(match real {
            Some(m) => {
                let acc = m.test_accuracy.unwrap_or(m.train_accuracy);
                (m.train_loss, acc)
            }
            None => (
                self.surrogate.loss(self.progress),
                self.surrogate.accuracy(self.progress),
            ),
        })
    }

    // -------------------------------------------------- adversary plane

    /// True when reports must pass through the surrogate-update
    /// aggregation (Byzantine clients are present).
    fn adversary_active(&self) -> bool {
        self.cfg.sim.adversary_frac > 0.0
    }

    /// Reduce one aggregation window's surrogate updates through the
    /// *real* registered aggregator and score the result.
    ///
    /// Every reporter contributes a surrogate delta on a small
    /// [`SURROGATE_P`]-dimensional plane: honest clients a unit descent
    /// step with per-client jitter, Byzantine clients whatever their
    /// [`AdversaryModel`] fabricates. The reduced delta is scored as
    /// `1 − RMS(aggregate − honest step)`, clamped to [-1, 1]: the
    /// fraction of a full descent step this aggregation actually
    /// achieved, with *any* deviation — a reversed direction (sign
    /// flips), a diluted step (free-riders) or injected variance
    /// (scaled noise) — eating into it deterministically. That factor
    /// scales the surrogate progress increment. Alongside, the
    /// per-coordinate distance of the aggregate outside the honest
    /// envelope is accumulated into the run's `envelope_deviation`
    /// (the robustness headline the [`crate::platform::RobustSweep`]
    /// table reports).
    fn robust_aggregate(&mut self, reporters: &[(usize, f64)]) -> Result<f64> {
        let global = Arc::new(ParamVec::zeros(SURROGATE_P));
        let ctx = AggContext::from_config(global, &self.cfg)
            .expect_updates(reporters.len());
        // The surrogate plane reduces through the same hierarchy the
        // real rounds would: per-edge tier aggregators (cfg.edge_agg,
        // falling back to cfg.agg) under the cloud fold — so per-tier
        // robustness is measured, not assumed. Flat topologies degrade
        // to exactly the single registered aggregator as before.
        let clients: Vec<usize> = reporters.iter().map(|&(c, _)| c).collect();
        let mut plane =
            HierPlane::from_registry(&self.topology, ctx, &clients)?;
        let mut honest_lo = [f32::INFINITY; SURROGATE_P];
        let mut honest_hi = [f32::NEG_INFINITY; SURROGATE_P];
        let mut honest = 0usize;
        for &(client, weight) in reporters {
            let mut delta: Vec<f32> = (0..SURROGATE_P)
                .map(|_| (1.0 + 0.1 * (self.adv_rng.uniform() - 0.5)) as f32)
                .collect();
            if self.adversarial[client] {
                self.adversary.corrupt(&mut delta, &mut self.adv_rng);
            } else {
                honest += 1;
                for (i, v) in delta.iter().enumerate() {
                    honest_lo[i] = honest_lo[i].min(*v);
                    honest_hi[i] = honest_hi[i].max(*v);
                }
            }
            plane.add(client, &Update::Dense(ParamVec(delta)), weight)?;
        }
        let (out, _) = plane.finish()?;
        if honest > 0 {
            let mut dev = 0.0f64;
            for (i, v) in out.iter().enumerate() {
                let v = *v as f64;
                dev += (honest_lo[i] as f64 - v).max(0.0)
                    + (v - honest_hi[i] as f64).max(0.0);
            }
            self.env_dev_sum += dev / SURROGATE_P as f64;
            self.env_dev_n += 1;
        }
        let mse = out
            .iter()
            .map(|v| (*v as f64 - 1.0).powi(2))
            .sum::<f64>()
            / SURROGATE_P as f64;
        Ok((1.0 - mse.sqrt()).clamp(-1.0, 1.0))
    }

    /// Close one aggregation window's cloud fan-in: returns the bytes
    /// that crossed into the cloud (every reporter's update when flat,
    /// one dense partial per active edge otherwise) and the extra
    /// virtual time the edge tier adds. Flat windows add exactly 0 ms
    /// and draw no RNG, so pre-hierarchy trace digests are bit-for-bit
    /// unchanged regardless of any hierarchy knob.
    fn close_fanin<I: Iterator<Item = usize>>(
        &mut self,
        reporters: I,
        reported: usize,
    ) -> (usize, f64) {
        if reported == 0 {
            return (0, 0.0);
        }
        let (bytes, hop_ms) = if self.topology.is_flat() {
            // Flat fan-in ships each reporter's update as-is: the
            // per-variant encoded size, not a flat dense charge.
            (reported * self.uplink_bytes, 0.0)
        } else {
            // Edges decode client uploads and ship *dense* partials, so
            // the backhaul still carries model_bytes per active edge.
            // The cloud additionally pays its (deterministic) ingest
            // serialization — 0 with the presets' infinite rate.
            let clusters: BTreeSet<usize> =
                reporters.map(|c| self.topology.cluster_of(c)).collect();
            let bytes = clusters.len() * self.cost.model_bytes;
            let hop =
                self.cost.edge_hop_ms() + self.cost.cloud_ingest_ms(bytes);
            (bytes, hop)
        };
        self.bytes_to_cloud += bytes;
        (bytes, hop_ms)
    }

    // ------------------------------------------------------ sync engine

    fn run_sync(&mut self, cancel: &dyn Fn() -> bool) -> Result<SimReport> {
        let sw = Stopwatch::start();
        let rounds = self.cfg.rounds;
        let k_target = self.cfg.clients_per_round;
        let k_select =
            ((k_target as f64) * self.cfg.sim.over_select).ceil() as usize;
        let deadline_ms = self.cfg.sim.deadline_ms;
        self.init_population();

        let mut round = 0usize;
        let mut t0 = 0.0f64;
        let mut cohort: Vec<usize> = Vec::new();
        let mut target = 0usize;
        let mut reported = 0usize;
        let mut round_dropped = 0usize;
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut awaiting = false;
        let mut rounds_done = 0usize;
        let mut makespan = 0.0f64;
        let mut round_span = Span::noop();

        self.queue.push(0.0, EventKind::RoundStart { round: 0 });
        while rounds_done < rounds {
            let Some(ev) = self.queue.pop() else {
                self.tracker
                    .warn("simnet: event queue drained before all rounds ran");
                break;
            };
            let t = ev.time_ms;
            if self.tel.enabled() {
                self.vclock.set_ms(t);
            }
            let mut finish_now = false;
            match ev.kind {
                EventKind::Online { client } => self.handle_toggle(client, true, t),
                EventKind::Offline { client } => {
                    self.handle_toggle(client, false, t)
                }
                EventKind::RoundStart { round: r } => {
                    round = r;
                    t0 = t;
                    reported = 0;
                    round_dropped = 0;
                    measured.clear();
                    cohort = self.select_cohort(k_select);
                    target = k_target.min(cohort.len());
                    awaiting = true;
                    round_span = self.tel.span_with("sim.round", || {
                        vec![
                            ("round", r.to_string()),
                            ("cohort", cohort.len().to_string()),
                        ]
                    });
                    // Over-selected cohort queues per device; clients on
                    // one device run back-to-back (the makespan model
                    // the scheduler optimizes).
                    let groups = self.strategy.allocate(
                        &cohort,
                        self.cfg.num_devices.max(1),
                        &mut self.rng,
                    );
                    for group in &groups {
                        let mut cursor = t0;
                        for &c in group {
                            cursor += self.schedule_client(c, cursor);
                        }
                    }
                    // An empty cohort (everyone offline) still burns its
                    // deadline — the Deadline event closes the round,
                    // and availability toggles can refill the pool
                    // before the next one starts.
                    self.queue
                        .push(t0 + deadline_ms, EventKind::Deadline { round: r });
                }
                EventKind::Report { client, epoch } => {
                    if awaiting && self.live_event(client, epoch) {
                        self.clients[client].begin_upload();
                        self.clients[client].report();
                        // Profile the client's own service time (compute
                        // + upload), not its queue-inclusive completion
                        // time — same as the real Server's observe().
                        measured.push((client, self.clients[client].service_ms));
                        self.release(client);
                        self.total_reported += 1;
                        reported += 1;
                        finish_now = reported >= target
                            || reported + round_dropped >= cohort.len();
                    }
                }
                EventKind::Dropout { client, epoch } => {
                    if self.live_event(client, epoch) {
                        self.clients[client].drop_out();
                        self.release(client);
                        self.total_dropped += 1;
                        round_dropped += 1;
                        finish_now = awaiting
                            && reported + round_dropped >= cohort.len();
                    }
                }
                EventKind::Deadline { round: r } => {
                    finish_now = awaiting && r == round;
                }
            }
            if awaiting && finish_now {
                let sw_fold = Stopwatch::start();
                let now = self.queue.now_ms();
                // Anything still running missed the aggregation: drop it
                // back into the pool.
                for i in 0..cohort.len() {
                    let c = cohort[i];
                    if self.clients[c].is_busy() {
                        self.clients[c].drop_out();
                        self.release(c);
                        self.total_dropped += 1;
                        round_dropped += 1;
                    }
                }
                self.strategy.observe(&measured);
                let part = if k_target > 0 {
                    (reported as f64 / k_target as f64).min(1.0)
                } else {
                    0.0
                };
                // With Byzantine clients present, the round's effective
                // progress is scaled by how well the configured
                // aggregator preserved the honest descent direction.
                let inc = if self.adversary_active() && !measured.is_empty() {
                    let reporters: Vec<(usize, f64)> =
                        measured.iter().map(|&(c, _)| (c, 1.0)).collect();
                    part * self.robust_aggregate(&reporters)?
                } else {
                    part
                };
                self.progress = (self.progress + inc).max(0.0);
                // Hierarchy fan-in: bytes-to-cloud for the window plus
                // the edge-partial hop (flat rounds close at `now`
                // exactly, as before).
                let (round_bytes, hop_ms) = self
                    .close_fanin(measured.iter().map(|&(c, _)| c), reported);
                let close = now + hop_ms;
                let (train_loss, acc) = self.backend_metrics(round)?;
                let mut service = Histogram::new();
                for &(_, ms) in &measured {
                    service.record_ms(ms);
                }
                self.record_round(
                    round,
                    close - t0,
                    cohort.len(),
                    reported,
                    round_dropped,
                    0.0,
                    round_bytes,
                    train_loss,
                    acc,
                    &service,
                );
                let fold_ms = sw_fold.elapsed_ms();
                self.fold_hist.record_ms(fold_ms);
                self.tel.observe_ms("sim.fold_ms", fold_ms);
                if self.tel.enabled() {
                    self.vclock.set_ms(close);
                }
                round_span = Span::noop();
                self.version += 1;
                awaiting = false;
                rounds_done += 1;
                makespan = close;
                if rounds_done < rounds {
                    if cancel() {
                        self.cancelled = true;
                        break;
                    }
                    self.queue
                        .push(close, EventKind::RoundStart { round: round + 1 });
                }
            }
        }
        drop(round_span);
        self.teardown();
        self.finish_telemetry()?;
        Ok(self.build_report("sync", makespan, sw.elapsed_ms()))
    }

    // ----------------------------------------------------- async engine

    fn run_async(&mut self, cancel: &dyn Fn() -> bool) -> Result<SimReport> {
        let sw = Stopwatch::start();
        let rounds = self.cfg.rounds;
        let k_target = self.cfg.clients_per_round.max(1);
        let buffer_target = if self.cfg.sim.async_buffer > 0 {
            self.cfg.sim.async_buffer
        } else {
            k_target
        };
        let concurrency = if self.cfg.sim.async_concurrency > 0 {
            self.cfg.sim.async_concurrency
        } else {
            2 * k_target
        };
        self.init_population();

        let mut active = 0usize;
        // FedBuff window from the aggregation plane: staleness discounts
        // become aggregator weights. Surrogate mode keeps the weight
        // ledger only; plugging a real Aggregator streams updates too.
        let mut buffer = FedBuffBuffer::surrogate(self.cfg.sim.staleness_alpha);
        // (client, discounted weight) per window arrival, for the
        // adversary plane's surrogate-update reduction.
        let mut window_members: Vec<(usize, f64)> = Vec::new();
        let mut agg_dropped = 0usize;
        let mut t_last = 0.0f64;
        let mut makespan = 0.0f64;
        let mut window_span = Span::noop();
        let mut window_service = Histogram::new();

        self.refill_async(&mut active, concurrency, 0.0);
        while self.version < rounds {
            let Some(ev) = self.queue.pop() else {
                self.tracker.warn(
                    "simnet: async engine starved (no clients available and \
                     no pending events)",
                );
                break;
            };
            let t = ev.time_ms;
            if self.tel.enabled() {
                self.vclock.set_ms(t);
            }
            match ev.kind {
                EventKind::Online { client } => self.handle_toggle(client, true, t),
                EventKind::Offline { client } => {
                    self.handle_toggle(client, false, t)
                }
                EventKind::Report { client, epoch } => {
                    if !self.live_event(client, epoch) {
                        continue;
                    }
                    let staleness =
                        (self.version - self.clients[client].start_version) as f64;
                    self.clients[client].begin_upload();
                    self.clients[client].report();
                    window_service.record_ms(self.clients[client].service_ms);
                    self.release(client);
                    active -= 1;
                    self.total_reported += 1;
                    if window_members.is_empty() {
                        window_span = self.tel.span_with("sim.window", || {
                            vec![("round", self.version.to_string())]
                        });
                    }
                    let weight = buffer.push(staleness, None)?;
                    window_members.push((client, weight));
                    self.staleness_sum += staleness;
                    self.staleness_n += 1;
                    if buffer.len() >= buffer_target {
                        // FedBuff aggregation: staleness-discounted
                        // weights, normalized against the sync target K
                        // so sync/async progress is comparable.
                        let sw_fold = Stopwatch::start();
                        let round = self.version;
                        self.version += 1;
                        let base = buffer.total_weight() / k_target as f64;
                        let inc = if self.adversary_active() {
                            base * self.robust_aggregate(&window_members)?
                        } else {
                            base
                        };
                        // Window fan-in before the member list resets
                        // (flat windows close at `t` exactly, as before).
                        let (window_bytes, hop_ms) = self.close_fanin(
                            window_members.iter().map(|&(c, _)| c),
                            window_members.len(),
                        );
                        let close = t + hop_ms;
                        window_members.clear();
                        self.progress = (self.progress + inc).max(0.0);
                        let (train_loss, acc) = self.backend_metrics(round)?;
                        let window = buffer.flush()?;
                        // Async "selected" = selections *resolved* in
                        // this window (reports + drops), so the
                        // reported ≤ selected invariant holds per round.
                        self.record_round(
                            round,
                            close - t_last,
                            window.arrivals + agg_dropped,
                            window.arrivals,
                            agg_dropped,
                            window.avg_staleness,
                            window_bytes,
                            train_loss,
                            acc,
                            &window_service,
                        );
                        window_service = Histogram::new();
                        let fold_ms = sw_fold.elapsed_ms();
                        self.fold_hist.record_ms(fold_ms);
                        self.tel.observe_ms("sim.fold_ms", fold_ms);
                        if self.tel.enabled() {
                            self.vclock.set_ms(close);
                        }
                        window_span = Span::noop();
                        agg_dropped = 0;
                        t_last = close;
                        makespan = close;
                        if self.version < rounds && cancel() {
                            self.cancelled = true;
                            break;
                        }
                    }
                }
                EventKind::Dropout { client, epoch } => {
                    if !self.live_event(client, epoch) {
                        continue;
                    }
                    self.clients[client].drop_out();
                    self.release(client);
                    active -= 1;
                    agg_dropped += 1;
                    self.total_dropped += 1;
                }
                EventKind::RoundStart { .. } | EventKind::Deadline { .. } => {}
            }
            if self.version < rounds {
                let now = self.queue.now_ms();
                self.refill_async(&mut active, concurrency, now);
            }
        }
        drop(window_span);
        self.teardown();
        self.finish_telemetry()?;
        Ok(self.build_report("async", makespan, sw.elapsed_ms()))
    }

    /// Keep `concurrency` clients training (FedBuff's server-side pull).
    fn refill_async(&mut self, active: &mut usize, concurrency: usize, now_ms: f64) {
        while *active < concurrency && !self.pool.is_empty() {
            let picked = self.pool.sample(1, &mut self.rng);
            let c = picked[0];
            self.clients[c].select(self.version);
            self.clients[c].begin_training();
            self.total_selected += 1;
            self.schedule_client(c, now_ms);
            *active += 1;
        }
    }

    // -------------------------------------------------------- wrap-up

    #[allow(clippy::too_many_arguments)]
    fn record_round(
        &mut self,
        round: usize,
        round_ms: f64,
        selected: usize,
        reported: usize,
        dropped: usize,
        avg_staleness: f64,
        bytes_to_cloud: usize,
        train_loss: f64,
        accuracy: f64,
        service: &Histogram,
    ) {
        self.client_hist.merge(service);
        let (client_ms_p50, client_ms_p95, client_ms_p99) =
            service.quantiles_ms();
        let eval = self.cfg.eval_every > 0
            && (round + 1) % self.cfg.eval_every == 0;
        self.tracker.record_round(RoundMetrics {
            round,
            train_loss,
            train_accuracy: accuracy,
            test_loss: if eval { Some(train_loss) } else { None },
            test_accuracy: if eval { Some(accuracy) } else { None },
            round_ms,
            distribution_ms: 0.0,
            // Downlink distributes the dense model to every selected
            // client; the uplink charges each report's actual wire size
            // (equal to model_bytes when no codec is configured, so the
            // legacy (selected + reported) · model_bytes is preserved).
            comm_bytes: selected * self.cost.model_bytes
                + reported * self.uplink_bytes,
            bytes_to_cloud,
            clients: Vec::new(),
            selected,
            reported,
            dropped,
            avg_staleness,
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
        });
    }

    /// Final event-count stamp and sink flush (no-op when telemetry is
    /// off).
    fn finish_telemetry(&self) -> Result<()> {
        self.tel.counter("sim.events", self.queue.processed());
        self.tel.flush()
    }

    /// Release every client back to Available/Offline so no one is left
    /// mid-round when the simulation ends.
    fn teardown(&mut self) {
        for c in 0..self.clients.len() {
            if self.clients[c].release() {
                self.pool.insert(c);
            } else {
                self.pool.remove(c);
            }
        }
    }

    fn build_report(&self, mode: &str, makespan_ms: f64, wall_ms: f64) -> SimReport {
        let final_accuracy = self
            .tracker
            .final_accuracy()
            .unwrap_or_else(|| self.surrogate.accuracy(self.progress));
        // Read the loss off the tracker so real-training runs report the
        // Engine's actual loss, not the surrogate curve.
        let final_train_loss = self
            .tracker
            .loss_curve()
            .last()
            .map(|(_, loss, _)| *loss)
            .unwrap_or_else(|| self.surrogate.loss(self.progress));
        let (client_ms_p50, client_ms_p95, client_ms_p99) =
            self.client_hist.quantiles_ms();
        let (fold_ms_p50, fold_ms_p95, fold_ms_p99) =
            self.fold_hist.quantiles_ms();
        SimReport {
            mode: mode.to_string(),
            allocation: self.cfg.allocation.name().to_string(),
            availability: self.availability.name(),
            num_clients: self.clients.len(),
            rounds: self.tracker.num_rounds(),
            makespan_ms,
            events: self.queue.processed(),
            selected: self.total_selected,
            reported: self.total_reported,
            dropped: self.total_dropped,
            participation: if self.total_selected > 0 {
                self.total_reported as f64 / self.total_selected as f64
            } else {
                0.0
            },
            avg_staleness: if self.staleness_n > 0 {
                self.staleness_sum / self.staleness_n as f64
            } else {
                0.0
            },
            final_accuracy,
            final_train_loss,
            comm_bytes: self.tracker.total_comm_bytes(),
            trace_digest: self.queue.trace_digest(),
            wall_ms,
            converged: self.tracker.num_rounds() == self.cfg.rounds
                && self.tracker.num_rounds() > 0,
            cancelled: self.cancelled,
            aggregator: self.agg_name.clone(),
            topology: self.topology.name(),
            bytes_to_cloud: self.bytes_to_cloud,
            adversary: self.adversary.name(),
            adversary_frac: self.cfg.sim.adversary_frac,
            envelope_deviation: if self.env_dev_n > 0 {
                self.env_dev_sum / self.env_dev_n as f64
            } else {
                0.0
            },
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
            fold_ms_p50,
            fold_ms_p95,
            fold_ms_p99,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Allocation, DatasetKind, Partition, SimMode};

    fn sim_cfg(mode: SimMode) -> Config {
        let mut cfg = Config::for_dataset(DatasetKind::Cifar10);
        cfg.num_clients = 400;
        cfg.clients_per_round = 20;
        cfg.rounds = 12;
        cfg.partition = Partition::Dirichlet(0.5);
        cfg.num_devices = 4;
        cfg.sim.mode = mode;
        cfg.sim.dropout = 0.1;
        // Generous deadline: most rounds close on their K-th report, a
        // few on the deadline — both paths exercised.
        cfg.sim.deadline_ms = 120_000.0;
        cfg.seed = 7;
        cfg
    }

    #[test]
    fn sync_engine_runs_all_rounds_and_tracks_participation() {
        let cfg = sim_cfg(SimMode::Sync);
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        assert_eq!(report.mode, "sync");
        assert_eq!(report.rounds, 12);
        assert!(report.makespan_ms > 0.0);
        assert!(report.selected >= report.reported);
        assert_eq!(report.selected, report.reported + report.dropped);
        assert!(report.participation > 0.5, "{}", report.participation);
        assert!(report.final_accuracy > 0.0);
        assert!(report.converged, "all configured rounds aggregated");
        assert_eq!(report.avg_staleness, 0.0, "sync rounds are never stale");
        // The always-on quantiles populate without any telemetry config.
        assert!(report.client_ms_p50 > 0.0);
        assert!(report.client_ms_p50 <= report.client_ms_p95);
        assert!(report.client_ms_p95 <= report.client_ms_p99);
        // Every round's reporters fit under the over-selected cohort.
        let t = net.tracker();
        let json = t.to_json();
        for r in json.get("rounds").as_arr().unwrap() {
            let selected = r.req_usize("selected").unwrap();
            let reported = r.req_usize("reported").unwrap();
            assert!(reported <= selected, "reported {reported} > selected {selected}");
            assert!(reported <= cfg.clients_per_round);
            // Per-round client-time quantiles ride the tracker JSON.
            let p50 = r.get("client_ms_p50").as_f64().unwrap();
            let p99 = r.get("client_ms_p99").as_f64().unwrap();
            assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} vs p99 {p99}");
        }
    }

    #[test]
    fn async_engine_aggregates_with_staleness() {
        let mut cfg = sim_cfg(SimMode::Async);
        cfg.sim.async_buffer = 10;
        cfg.sim.async_concurrency = 60;
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        assert_eq!(report.mode, "async");
        assert_eq!(report.rounds, 12);
        assert!(report.makespan_ms > 0.0);
        // 60 concurrent trainers vs buffer 10: most updates land after
        // at least one intervening aggregation.
        assert!(report.avg_staleness > 0.0);
        assert!(report.final_accuracy > 0.0);
    }

    #[test]
    fn all_clients_are_released_after_a_run() {
        for mode in [SimMode::Sync, SimMode::Async] {
            let cfg = sim_cfg(mode);
            let mut net = SimNet::from_config(&cfg).unwrap();
            net.run().unwrap();
            for c in 0..net.num_clients() {
                let phase = net.client_phase(c);
                assert!(
                    matches!(phase, ClientPhase::Available | ClientPhase::Offline),
                    "client {c} stuck in {phase:?} after {mode:?} run"
                );
            }
        }
    }

    #[test]
    fn greedy_beats_slowest_allocation_on_makespan() {
        // Full-cohort aggregation (no over-selection, no dropout, lax
        // deadline) so round time is exactly the scheduling makespan the
        // strategies compete on.
        let run = |alloc| {
            let mut cfg = sim_cfg(SimMode::Sync);
            cfg.allocation = alloc;
            // Small population so adaptive profiling sees repeat clients.
            cfg.num_clients = 30;
            cfg.sim.dropout = 0.0;
            cfg.sim.over_select = 1.0;
            cfg.sim.deadline_ms = 1e9;
            cfg.rounds = 20;
            let mut net = SimNet::from_config(&cfg).unwrap();
            net.run().unwrap().makespan_ms
        };
        let greedy = run(Allocation::GreedyAda);
        let slowest = run(Allocation::Slowest);
        assert!(
            greedy < slowest,
            "greedyada {greedy} should beat slowest {slowest}"
        );
    }

    #[test]
    fn cancellation_probe_stops_at_round_boundaries() {
        for mode in [SimMode::Sync, SimMode::Async] {
            let cfg = sim_cfg(mode);
            let mut net = SimNet::from_config(&cfg).unwrap();
            let tracker = net.tracker();
            let report = net
                .run_cancellable(&|| tracker.num_rounds() >= 3)
                .unwrap();
            assert!(report.cancelled, "{mode:?} run must report the cancel");
            assert!(!report.converged);
            assert_eq!(report.rounds, 3, "{mode:?} stops at the boundary");
            // Teardown still ran: nobody is stuck mid-round.
            for c in 0..net.num_clients() {
                let phase = net.client_phase(c);
                assert!(
                    matches!(phase, ClientPhase::Available | ClientPhase::Offline),
                    "client {c} stuck in {phase:?} after cancelled {mode:?} run"
                );
            }
        }
    }

    #[test]
    fn uncancelled_runs_report_cancelled_false() {
        let report = SimNet::from_config(&sim_cfg(SimMode::Sync))
            .unwrap()
            .run()
            .unwrap();
        assert!(!report.cancelled);
        assert!(report.converged);
    }

    #[test]
    fn sign_flip_adversaries_slow_the_mean_but_not_the_trimmed_mean() {
        let run = |agg: Option<&str>, frac: f64| {
            let mut cfg = sim_cfg(SimMode::Sync);
            cfg.sim.dropout = 0.0;
            cfg.sim.adversary = "sign-flip".into();
            cfg.sim.adversary_frac = frac;
            cfg.agg = agg.map(|s| s.to_string());
            cfg.agg_trim_frac = 0.35;
            SimNet::from_config(&cfg).unwrap().run().unwrap()
        };
        let clean = run(None, 0.0);
        let attacked_mean = run(None, 0.3);
        let attacked_trim = run(Some("trimmed_mean"), 0.3);
        assert_eq!(clean.envelope_deviation, 0.0, "plane off ⇒ no deviation");
        assert_eq!(attacked_mean.aggregator, "mean");
        assert_eq!(attacked_trim.aggregator, "trimmed_mean");
        assert_eq!(attacked_mean.adversary, "sign-flip");
        assert!(
            attacked_mean.final_accuracy < clean.final_accuracy,
            "attack must hurt the plain mean: {} !< {}",
            attacked_mean.final_accuracy,
            clean.final_accuracy
        );
        assert!(
            attacked_trim.final_accuracy > attacked_mean.final_accuracy,
            "trimmed mean must recover: {} !> {}",
            attacked_trim.final_accuracy,
            attacked_mean.final_accuracy
        );
        assert!(
            attacked_mean.envelope_deviation
                > attacked_trim.envelope_deviation,
            "mean strays outside the honest envelope: {} !> {}",
            attacked_mean.envelope_deviation,
            attacked_trim.envelope_deviation
        );
    }

    #[test]
    fn unknown_aggregator_or_adversary_fails_fast_at_construction() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.agg = Some("krum".into());
        let err = SimNet::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("krum"), "{err}");
        assert!(err.contains("trimmed_mean"), "{err}");

        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.adversary = "gaslight".into();
        let err = SimNet::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("gaslight"), "{err}");
        assert!(err.contains("sign-flip"), "{err}");
    }

    #[test]
    fn identity_codec_keeps_trace_digests_bit_identical() {
        // The regression guard for the codec subsystem: an unset codec
        // and the explicit "identity" codec must produce the same event
        // trace, makespan and byte accounting as each other — across
        // sync, async and hierarchical timelines.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            let baseline = SimNet::from_config(&base).unwrap().run().unwrap();
            let mut coded = base.clone();
            coded.codec = Some("identity".into());
            let identity = SimNet::from_config(&coded).unwrap().run().unwrap();
            assert_eq!(
                baseline.trace_digest, identity.trace_digest,
                "{mode:?}/{topo}: identity codec shifted the event trace"
            );
            assert_eq!(baseline.makespan_ms, identity.makespan_ms);
            assert_eq!(baseline.comm_bytes, identity.comm_bytes);
            assert_eq!(baseline.bytes_to_cloud, identity.bytes_to_cloud);
            assert_eq!(baseline.rounds, identity.rounds);
        }
    }

    #[test]
    fn telemetry_off_runs_are_bit_identical_to_metrics_only_runs() {
        // The observability regression guard: metrics-only telemetry
        // (NullSink, in-memory registry) must not shift a single event —
        // no extra RNG draws, no queue traffic — across the sync, async
        // and hierarchical timelines.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            let off = SimNet::from_config(&base).unwrap().run().unwrap();
            let mut on_cfg = base.clone();
            on_cfg.telemetry = true;
            let mut traced_net = SimNet::from_config(&on_cfg).unwrap();
            let traced = traced_net.run().unwrap();
            assert_eq!(
                off.trace_digest, traced.trace_digest,
                "{mode:?}/{topo}: telemetry shifted the event trace"
            );
            assert_eq!(off.makespan_ms, traced.makespan_ms);
            assert_eq!(off.comm_bytes, traced.comm_bytes);
            assert_eq!(off.bytes_to_cloud, traced.bytes_to_cloud);
            assert_eq!(off.rounds, traced.rounds);
            // Identical timelines ⇒ identical virtual-time quantiles.
            assert_eq!(off.client_ms_p99, traced.client_ms_p99);
            // The traced run accumulated the metrics the off run skipped.
            let tel = traced_net.telemetry();
            assert_eq!(tel.counter_value("sim.events"), traced.events);
            assert!(tel.quantiles_ms("sim.fold_ms").is_some());
        }
    }

    #[test]
    fn sketch_sampling_and_feedback_knobs_keep_digests_bit_identical() {
        // Regression guard for the ingest/sketch PR's knobs, across the
        // sync, async and hierarchical timelines with an active
        // adversary so the robust surrogate reduction actually runs:
        //
        // * `agg_sketch` — SimNet cohorts sit under the sketch cap, so
        //   the sketch aggregators are in their exact regime and draw no
        //   RNG: every reduced value (and hence the trace) is identical.
        // * `trace_sample` — sampling decisions are pure hashes, so even
        //   a heavily thinned traced run cannot shift the simulation.
        // * `codec_error_feedback` — a client-flow concern; the
        //   simulator's surrogate timeline must not notice the knob.
        for (mode, topo) in [
            (SimMode::Sync, "flat"),
            (SimMode::Async, "flat"),
            (SimMode::Sync, "edges(4)"),
        ] {
            let mut base = sim_cfg(mode);
            base.topology = topo.to_string();
            if matches!(mode, SimMode::Async) {
                base.sim.async_buffer = 10;
                base.sim.async_concurrency = 60;
            }
            base.agg = Some("trimmed_mean".into());
            base.sim.adversary = "sign-flip".into();
            base.sim.adversary_frac = 0.2;
            let exact = SimNet::from_config(&base).unwrap().run().unwrap();

            let mut sk_cfg = base.clone();
            sk_cfg.agg_sketch = true;
            let sketch = SimNet::from_config(&sk_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, sketch.trace_digest,
                "{mode:?}/{topo}: agg_sketch shifted the event trace"
            );
            assert_eq!(exact.makespan_ms, sketch.makespan_ms);
            assert_eq!(exact.final_accuracy, sketch.final_accuracy);

            let mut ts_cfg = base.clone();
            ts_cfg.telemetry = true;
            ts_cfg.trace_sample = 0.25;
            let sampled = SimNet::from_config(&ts_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, sampled.trace_digest,
                "{mode:?}/{topo}: trace_sample shifted the event trace"
            );

            let mut ef_cfg = base.clone();
            ef_cfg.codec = Some("identity".into());
            ef_cfg.codec_error_feedback = true;
            let fed = SimNet::from_config(&ef_cfg).unwrap().run().unwrap();
            assert_eq!(
                exact.trace_digest, fed.trace_digest,
                "{mode:?}/{topo}: codec_error_feedback leaked into the sim"
            );
        }
    }

    #[test]
    fn codec_compression_cuts_comm_bytes_and_makespan() {
        let base = sim_cfg(SimMode::Sync);
        let dense = SimNet::from_config(&base).unwrap().run().unwrap();
        let mut cfg = base.clone();
        cfg.codec = Some("top_k_i8(0.05)".into());
        let coded = SimNet::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(coded.rounds, dense.rounds);
        // Uplinks shrink ~16x; downlinks stay dense, so total comm drops
        // but not by the full codec ratio.
        assert!(
            coded.comm_bytes < dense.comm_bytes,
            "coded {} !< dense {}",
            coded.comm_bytes,
            dense.comm_bytes
        );
        // Smaller uploads ⇒ every report lands earlier ⇒ rounds close
        // sooner over mobile-WAN links.
        assert!(
            coded.makespan_ms < dense.makespan_ms,
            "coded {} !< dense {}",
            coded.makespan_ms,
            dense.makespan_ms
        );
        // Flat fan-in also charges encoded bytes at the cloud.
        assert!(coded.bytes_to_cloud < dense.bytes_to_cloud);
    }

    #[test]
    fn finite_cloud_ingest_charges_hierarchical_fanin() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.topology = "edges(4)".to_string();
        let free = SimNet::from_config(&cfg).unwrap().run().unwrap();
        let mut slow = cfg.clone();
        // 1.6 MB per edge partial at 1000 B/ms = 1.6 s extra per window.
        slow.sim.cloud_ingest_bytes_per_ms = 1_000.0;
        let charged = SimNet::from_config(&slow).unwrap().run().unwrap();
        assert_eq!(free.rounds, charged.rounds);
        assert!(
            charged.makespan_ms > free.makespan_ms,
            "finite ingest must lengthen the run: {} !> {}",
            charged.makespan_ms,
            free.makespan_ms
        );
    }

    #[test]
    fn diurnal_availability_limits_the_pool() {
        let mut cfg = sim_cfg(SimMode::Sync);
        cfg.sim.availability = "diurnal(0.3,1000000)".into();
        cfg.sim.dropout = 0.0;
        let mut net = SimNet::from_config(&cfg).unwrap();
        let report = net.run().unwrap();
        // Roughly 30% of 400 clients online at a time; rounds still run.
        assert_eq!(report.rounds, 12);
        assert!(report.reported > 0);
    }
}
