//! Elastic membership: seed-deterministic between-round client churn.
//!
//! A [`ChurnModel`] adds and/or removes clients at aggregation
//! boundaries, extending the lifecycle machine in
//! [`crate::simnet::client_state`] with join/leave transitions. All
//! randomness (device class, bandwidth, availability phase of a joiner;
//! which idle client departs) comes from a dedicated churn RNG stream,
//! so `"none"` — the default — burns zero RNG and leaves every
//! pre-existing trace digest bit-identical.
//!
//! Per-round rates may be fractional: `grow(0.5)` admits one client
//! every other round via a fractional-credit accumulator that is
//! serialized into round checkpoints, so a resumed run churns exactly
//! like the uninterrupted one.

use crate::error::{Error, Result};

/// A between-round membership change model (registered under `sim.churn`).
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnModel {
    /// No churn: zero RNG draws, zero membership changes.
    None,
    /// `grow(n)`: admit `n` new clients per round (may be fractional).
    Grow { per_round: f64 },
    /// `shrink(n)`: retire `n` idle clients per round (may be fractional).
    Shrink { per_round: f64 },
    /// `flux(j,l)`: admit `j` and retire `l` clients per round.
    Flux { join_per_round: f64, leave_per_round: f64 },
}

fn parse_args(spec: &str) -> Result<Vec<f64>> {
    let Some(inner) = spec
        .find('(')
        .map(|i| &spec[i + 1..])
        .and_then(|r| r.strip_suffix(')'))
    else {
        return Ok(Vec::new());
    };
    inner
        .split(',')
        .map(|a| {
            a.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("bad churn arg {a:?} in {spec:?}"))
            })
        })
        .collect()
}

fn rate(spec: &str, args: &[f64], i: usize, what: &str) -> Result<f64> {
    let r = args.get(i).copied().unwrap_or(1.0);
    if !(r >= 0.0 && r.is_finite()) {
        return Err(Error::Config(format!(
            "{what} rate must be finite and ≥ 0, got {spec:?}"
        )));
    }
    Ok(r)
}

impl ChurnModel {
    /// Parse a spec string (head selects the model, args set per-round
    /// rates). Accepted heads are exactly the registered names — the
    /// registry resolves the head before calling this.
    pub fn parse(spec: &str) -> Result<ChurnModel> {
        let head = crate::registry::spec_head(spec);
        let args = parse_args(spec)?;
        match head.as_str() {
            "none" | "off" => Ok(ChurnModel::None),
            "grow" => {
                Ok(ChurnModel::Grow { per_round: rate(spec, &args, 0, "grow")? })
            }
            "shrink" => Ok(ChurnModel::Shrink {
                per_round: rate(spec, &args, 0, "shrink")?,
            }),
            "flux" => Ok(ChurnModel::Flux {
                join_per_round: rate(spec, &args, 0, "flux join")?,
                leave_per_round: rate(spec, &args, 1, "flux leave")?,
            }),
            other => Err(Error::Config(format!(
                "unknown churn model {other:?} (none | grow(n) | shrink(n) \
                 | flux(j,l))"
            ))),
        }
    }

    pub fn name(&self) -> String {
        match self {
            ChurnModel::None => "none".into(),
            ChurnModel::Grow { per_round } => format!("grow({per_round})"),
            ChurnModel::Shrink { per_round } => format!("shrink({per_round})"),
            ChurnModel::Flux { join_per_round, leave_per_round } => {
                format!("flux({join_per_round},{leave_per_round})")
            }
        }
    }

    /// True when this model never changes membership (no RNG stream is
    /// touched at all for `None`).
    pub fn is_none(&self) -> bool {
        matches!(self, ChurnModel::None)
            || matches!(
                self,
                ChurnModel::Grow { per_round: r } | ChurnModel::Shrink { per_round: r }
                    if *r == 0.0
            )
            || matches!(
                self,
                ChurnModel::Flux { join_per_round: j, leave_per_round: l }
                    if *j == 0.0 && *l == 0.0
            )
    }

    /// Per-round (join, leave) rates.
    pub fn rates(&self) -> (f64, f64) {
        match *self {
            ChurnModel::None => (0.0, 0.0),
            ChurnModel::Grow { per_round } => (per_round, 0.0),
            ChurnModel::Shrink { per_round } => (0.0, per_round),
            ChurnModel::Flux { join_per_round, leave_per_round } => {
                (join_per_round, leave_per_round)
            }
        }
    }
}

/// Fractional-credit accumulator: integer churn counts per boundary.
///
/// Rates below one client/round accrue as credit; each call returns the
/// whole clients owed this boundary and keeps the remainder. The credit
/// pair is persisted in round checkpoints (as f64 bits) so resumed runs
/// replay churn identically.
#[derive(Debug, Clone, Default)]
pub struct ChurnCredits {
    pub join: f64,
    pub leave: f64,
}

impl ChurnCredits {
    /// Accrue one round's rates and withdraw the integer parts.
    pub fn accrue(&mut self, join_rate: f64, leave_rate: f64) -> (usize, usize) {
        self.join += join_rate;
        self.leave += leave_rate;
        let j = self.join.floor();
        let l = self.leave.floor();
        self.join -= j;
        self.leave -= l;
        (j as usize, l as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_round_trip() {
        for (spec, want) in [
            ("none", ChurnModel::None),
            ("grow(2)", ChurnModel::Grow { per_round: 2.0 }),
            ("grow", ChurnModel::Grow { per_round: 1.0 }),
            ("shrink(0.5)", ChurnModel::Shrink { per_round: 0.5 }),
            (
                "flux(2,1)",
                ChurnModel::Flux { join_per_round: 2.0, leave_per_round: 1.0 },
            ),
        ] {
            let m = ChurnModel::parse(spec).unwrap();
            assert_eq!(m, want, "{spec}");
            // name() re-parses to the same model.
            assert_eq!(ChurnModel::parse(&m.name()).unwrap(), m);
        }
        assert!(ChurnModel::parse("evaporate").is_err());
        assert!(ChurnModel::parse("grow(x)").is_err());
        assert!(ChurnModel::parse("grow(-1)").is_err());
        assert!(ChurnModel::parse("flux(1,-2)").is_err());
    }

    #[test]
    fn zero_rates_count_as_none() {
        assert!(ChurnModel::None.is_none());
        assert!(ChurnModel::parse("grow(0)").unwrap().is_none());
        assert!(ChurnModel::parse("flux(0,0)").unwrap().is_none());
        assert!(!ChurnModel::parse("grow(0.1)").unwrap().is_none());
    }

    #[test]
    fn fractional_credits_accumulate_exactly() {
        let mut c = ChurnCredits::default();
        let mut joined = 0;
        for _ in 0..10 {
            let (j, l) = c.accrue(0.5, 0.0);
            joined += j;
            assert_eq!(l, 0);
        }
        // 0.5/round over 10 rounds ⇒ exactly 5 joins.
        assert_eq!(joined, 5);
        assert!(c.join < 1.0);

        // Integer rates withdraw fully every round.
        let mut c = ChurnCredits::default();
        assert_eq!(c.accrue(2.0, 1.0), (2, 1));
        assert_eq!(c.accrue(2.0, 1.0), (2, 1));
    }
}
