//! Per-client lifecycle state machine + availability models.
//!
//! Every simulated client walks the FLGo-style lifecycle
//!
//! ```text
//! offline ⇄ available → selected → training → uploading → reported
//!                          └──────────┴────────────┴────→ dropped
//! ```
//!
//! driven by a seeded [`AvailabilityModel`] (when does the device come
//! online?) and a per-selection dropout probability (does it abandon the
//! round?). Reported *and* dropped clients are always released back to
//! the available pool (or offline, if their availability trace flipped
//! while they were busy) — no client is ever leaked mid-round.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Lifecycle phase of one simulated client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientPhase {
    /// Device is off / unreachable.
    Offline,
    /// Online and selectable.
    Available,
    /// Picked for the current round / async slot.
    Selected,
    /// Running local epochs.
    Training,
    /// Sending its update to the server.
    Uploading,
    /// Update received by the server (terminal for the round).
    Reported,
    /// Abandoned the round (terminal for the round).
    Dropped,
}

impl ClientPhase {
    /// True while the client occupies a round slot.
    pub fn is_busy(self) -> bool {
        matches!(
            self,
            ClientPhase::Selected | ClientPhase::Training | ClientPhase::Uploading
        )
    }
}

/// One simulated client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub phase: ClientPhase,
    /// Availability-trace state (pool membership is derived from this
    /// plus `phase` by the engine).
    pub online: bool,
    /// Device tier index into the cost model's catalog.
    pub device_class: usize,
    /// Uplink bandwidth in bytes/ms (upload = model_bytes / bandwidth).
    pub bandwidth_bytes_per_ms: f64,
    /// Per-client availability phase offset (diurnal models).
    pub avail_phase_ms: f64,
    /// Selection epoch; in-flight events from stale selections are
    /// ignored when their epoch no longer matches.
    pub epoch: u64,
    /// Global model version the client started training from (async
    /// staleness = current version − start_version at report time).
    pub start_version: usize,
    /// Duration of the client's own current round (compute + upload),
    /// excluding device-queue waits — what adaptive profiling observes.
    pub service_ms: f64,
    pub reports: u32,
    pub dropouts: u32,
}

impl ClientState {
    pub fn new(device_class: usize, bandwidth_bytes_per_ms: f64) -> ClientState {
        ClientState {
            phase: ClientPhase::Offline,
            online: false,
            device_class,
            bandwidth_bytes_per_ms,
            avail_phase_ms: 0.0,
            epoch: 0,
            start_version: 0,
            service_ms: 0.0,
            reports: 0,
            dropouts: 0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.phase.is_busy()
    }

    /// Available → Selected. Bumps the epoch so any stale in-flight
    /// events from a previous selection are ignored.
    pub fn select(&mut self, version: usize) {
        debug_assert_eq!(self.phase, ClientPhase::Available);
        self.phase = ClientPhase::Selected;
        self.epoch += 1;
        self.start_version = version;
    }

    /// Selected → Training.
    pub fn begin_training(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Selected);
        self.phase = ClientPhase::Training;
    }

    /// Training → Uploading.
    pub fn begin_upload(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Training);
        self.phase = ClientPhase::Uploading;
    }

    /// Uploading → Reported.
    pub fn report(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Uploading);
        self.phase = ClientPhase::Reported;
        self.reports += 1;
    }

    /// Any busy phase → Dropped.
    pub fn drop_out(&mut self) {
        debug_assert!(self.is_busy(), "drop_out from {:?}", self.phase);
        self.phase = ClientPhase::Dropped;
        self.dropouts += 1;
    }

    /// Terminal (or busy, at simulation teardown) → Available/Offline
    /// according to the availability trace. Returns true when the client
    /// re-enters the available pool.
    pub fn release(&mut self) -> bool {
        self.phase = if self.online {
            ClientPhase::Available
        } else {
            ClientPhase::Offline
        };
        self.online
    }
}

// -------------------------------------------------------- availability

/// Named, seeded availability trace generators. Resolved through the
/// component registry so configs select them by string name:
/// `"always-on"`, `"diurnal"`, `"diurnal(0.6)"`, `"flaky(1800000,600000)"`.
#[derive(Debug, Clone, PartialEq)]
pub enum AvailabilityModel {
    /// Every client is always online (the 100k-in-seconds default).
    AlwaysOn,
    /// Square-wave day/night cycle with per-client phase offsets.
    Diurnal { period_ms: f64, duty: f64 },
    /// Memoryless on/off churn with exponential dwell times.
    Flaky { mean_on_ms: f64, mean_off_ms: f64 },
}

/// One simulated day, the default diurnal period.
const DAY_MS: f64 = 86_400_000.0;

fn parse_args(spec: &str) -> Result<Vec<f64>> {
    let Some(inner) = spec
        .find('(')
        .map(|i| &spec[i + 1..])
        .and_then(|r| r.strip_suffix(')'))
    else {
        return Ok(Vec::new());
    };
    inner
        .split(',')
        .map(|a| {
            a.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("bad availability arg {a:?} in {spec:?}"))
            })
        })
        .collect()
}

impl AvailabilityModel {
    /// Parse a spec string (head selects the model, args tune it).
    pub fn parse(spec: &str) -> Result<AvailabilityModel> {
        let head = crate::registry::spec_head(spec);
        let args = parse_args(spec)?;
        match head.as_str() {
            "always-on" | "always" | "on" => Ok(AvailabilityModel::AlwaysOn),
            "diurnal" => {
                let duty = args.first().copied().unwrap_or(0.5);
                let period_ms = args.get(1).copied().unwrap_or(DAY_MS);
                if !(duty > 0.0 && duty <= 1.0) || !(period_ms > 0.0) {
                    return Err(Error::Config(format!(
                        "diurnal needs duty in (0,1] and period > 0, got {spec:?}"
                    )));
                }
                if duty >= 1.0 {
                    // A 100% duty cycle never flips — same as always-on.
                    return Ok(AvailabilityModel::AlwaysOn);
                }
                Ok(AvailabilityModel::Diurnal { period_ms, duty })
            }
            "flaky" => {
                let mean_on_ms = args.first().copied().unwrap_or(1_800_000.0);
                let mean_off_ms = args.get(1).copied().unwrap_or(1_800_000.0);
                if !(mean_on_ms > 0.0 && mean_off_ms > 0.0) {
                    return Err(Error::Config(format!(
                        "flaky needs positive mean on/off ms, got {spec:?}"
                    )));
                }
                Ok(AvailabilityModel::Flaky { mean_on_ms, mean_off_ms })
            }
            other => Err(Error::Config(format!(
                "unknown availability model {other:?} (always-on | diurnal | flaky)"
            ))),
        }
    }

    pub fn name(&self) -> String {
        match self {
            AvailabilityModel::AlwaysOn => "always-on".into(),
            AvailabilityModel::Diurnal { period_ms, duty } => {
                format!("diurnal({duty},{period_ms})")
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                format!("flaky({mean_on_ms},{mean_off_ms})")
            }
        }
    }

    /// Per-client phase offset (only diurnal traces use it).
    pub fn sample_phase_ms(&self, rng: &mut Rng) -> f64 {
        match self {
            AvailabilityModel::Diurnal { period_ms, .. } => {
                rng.uniform() * period_ms
            }
            _ => 0.0,
        }
    }

    /// Is the client online at t = 0?
    pub fn initial_online(&self, phase_ms: f64, rng: &mut Rng) -> bool {
        match *self {
            AvailabilityModel::AlwaysOn => true,
            AvailabilityModel::Diurnal { period_ms, duty } => {
                (phase_ms % period_ms) < duty * period_ms
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                // Stationary distribution of the on/off chain.
                rng.uniform() < mean_on_ms / (mean_on_ms + mean_off_ms)
            }
        }
    }

    /// Absolute time of the next on/off flip after `now_ms`
    /// (`f64::INFINITY` ⇒ never flips — the engine skips the event).
    pub fn next_toggle_ms(
        &self,
        online: bool,
        phase_ms: f64,
        now_ms: f64,
        rng: &mut Rng,
    ) -> f64 {
        match *self {
            AvailabilityModel::AlwaysOn => f64::INFINITY,
            AvailabilityModel::Diurnal { period_ms, duty } => {
                let on_ms = duty * period_ms;
                let local = (now_ms + phase_ms) % period_ms;
                if online {
                    // Next flip at the end of the on-window. Toggles are
                    // only scheduled right after entering a window, so
                    // `local` is always strictly inside it.
                    now_ms + (on_ms - local).max(0.0)
                } else {
                    now_ms + (period_ms - local).max(0.0)
                }
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                let mean = if online { mean_on_ms } else { mean_off_ms };
                let u = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE);
                now_ms + (-u.ln()) * mean
            }
        }
    }
}

// --------------------------------------------------------------- pool

/// O(1) insert/remove/sample set of available client ids — the engine's
/// "available pool". Swap-remove keeps sampling O(k) regardless of
/// federation size (a 1M-client pool costs two `Vec<usize>`).
#[derive(Debug, Clone)]
pub struct Pool {
    members: Vec<usize>,
    /// Position of each client in `members` (`usize::MAX` ⇒ absent).
    pos: Vec<usize>,
}

impl Pool {
    pub fn new(num_clients: usize) -> Pool {
        Pool { members: Vec::new(), pos: vec![usize::MAX; num_clients] }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, client: usize) -> bool {
        self.pos[client] != usize::MAX
    }

    pub fn insert(&mut self, client: usize) {
        if self.contains(client) {
            return;
        }
        self.pos[client] = self.members.len();
        self.members.push(client);
    }

    pub fn remove(&mut self, client: usize) {
        let p = self.pos[client];
        if p == usize::MAX {
            return;
        }
        let last = self.members.len() - 1;
        self.members.swap(p, last);
        self.pos[self.members[p]] = p;
        self.members.pop();
        self.pos[client] = usize::MAX;
    }

    /// Draw up to `k` distinct clients uniformly, removing them from the
    /// pool (they are about to be Selected).
    pub fn sample(&mut self, k: usize, rng: &mut Rng) -> Vec<usize> {
        let k = k.min(self.members.len());
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = rng.below(self.members.len() as u64) as usize;
            let c = self.members[i];
            self.remove(c);
            out.push(c);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_walks_the_full_machine() {
        let mut c = ClientState::new(2, 1000.0);
        c.online = true;
        assert!(c.release());
        assert_eq!(c.phase, ClientPhase::Available);
        c.select(3);
        assert_eq!(c.epoch, 1);
        assert_eq!(c.start_version, 3);
        c.begin_training();
        c.begin_upload();
        c.report();
        assert_eq!(c.phase, ClientPhase::Reported);
        assert_eq!(c.reports, 1);
        assert!(c.release());
        // Second selection bumps the epoch; dropout path.
        c.select(4);
        c.begin_training();
        c.drop_out();
        assert_eq!(c.phase, ClientPhase::Dropped);
        assert_eq!(c.dropouts, 1);
        c.online = false;
        assert!(!c.release());
        assert_eq!(c.phase, ClientPhase::Offline);
    }

    #[test]
    fn availability_specs_parse() {
        assert_eq!(
            AvailabilityModel::parse("always-on").unwrap(),
            AvailabilityModel::AlwaysOn
        );
        match AvailabilityModel::parse("diurnal(0.25)").unwrap() {
            AvailabilityModel::Diurnal { duty, .. } => {
                assert!((duty - 0.25).abs() < 1e-12)
            }
            other => panic!("{other:?}"),
        }
        match AvailabilityModel::parse("flaky(1000,2000)").unwrap() {
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                assert_eq!(mean_on_ms, 1000.0);
                assert_eq!(mean_off_ms, 2000.0);
            }
            other => panic!("{other:?}"),
        }
        assert!(AvailabilityModel::parse("lunar").is_err());
        assert!(AvailabilityModel::parse("diurnal(2.0)").is_err());
    }

    #[test]
    fn always_on_never_toggles() {
        let m = AvailabilityModel::AlwaysOn;
        let mut rng = Rng::new(1);
        assert!(m.initial_online(0.0, &mut rng));
        assert!(m.next_toggle_ms(true, 0.0, 5.0, &mut rng).is_infinite());
    }

    #[test]
    fn diurnal_toggles_advance_and_alternate() {
        let m = AvailabilityModel::Diurnal { period_ms: 100.0, duty: 0.6 };
        let mut rng = Rng::new(2);
        // Phase 0: online in [0, 60), offline in [60, 100).
        assert!(m.initial_online(0.0, &mut rng));
        let t_off = m.next_toggle_ms(true, 0.0, 0.0, &mut rng);
        assert!((t_off - 60.0).abs() < 1e-6, "{t_off}");
        let t_on = m.next_toggle_ms(false, 0.0, t_off, &mut rng);
        assert!((t_on - 100.0).abs() < 1e-6, "{t_on}");
    }

    #[test]
    fn flaky_dwell_times_follow_means() {
        let m = AvailabilityModel::Flaky { mean_on_ms: 500.0, mean_off_ms: 50.0 };
        let mut rng = Rng::new(3);
        let n = 4000;
        let avg_on: f64 = (0..n)
            .map(|_| m.next_toggle_ms(true, 0.0, 0.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        let avg_off: f64 = (0..n)
            .map(|_| m.next_toggle_ms(false, 0.0, 0.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((avg_on - 500.0).abs() < 50.0, "{avg_on}");
        assert!((avg_off - 50.0).abs() < 5.0, "{avg_off}");
        // Stationary online fraction ≈ 500/550.
        let online = (0..n).filter(|_| m.initial_online(0.0, &mut rng)).count();
        let frac = online as f64 / n as f64;
        assert!((frac - 500.0 / 550.0).abs() < 0.05, "{frac}");
    }

    #[test]
    fn pool_sample_is_distinct_and_removing() {
        let mut pool = Pool::new(100);
        for c in 0..100 {
            pool.insert(c);
        }
        let mut rng = Rng::new(4);
        let picked = pool.sample(30, &mut rng);
        assert_eq!(picked.len(), 30);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30, "samples must be distinct");
        assert_eq!(pool.len(), 70);
        for &c in &picked {
            assert!(!pool.contains(c));
            pool.insert(c);
        }
        assert_eq!(pool.len(), 100);
        // Over-asking returns everything.
        let all = pool.sample(1000, &mut rng);
        assert_eq!(all.len(), 100);
        assert!(pool.is_empty());
    }

    #[test]
    fn pool_remove_is_idempotent() {
        let mut pool = Pool::new(3);
        pool.insert(1);
        pool.remove(1);
        pool.remove(1);
        pool.remove(0);
        assert_eq!(pool.len(), 0);
        pool.insert(1);
        pool.insert(1);
        assert_eq!(pool.len(), 1);
    }
}
