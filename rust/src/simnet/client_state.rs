//! Per-client lifecycle state machine + availability models.
//!
//! Every simulated client walks the FLGo-style lifecycle
//!
//! ```text
//! offline ⇄ available → selected → training → uploading → reported
//!                          └──────────┴────────────┴────→ dropped
//! ```
//!
//! driven by a seeded [`AvailabilityModel`] (when does the device come
//! online?) and a per-selection dropout probability (does it abandon the
//! round?). Reported *and* dropped clients are always released back to
//! the available pool (or offline, if their availability trace flipped
//! while they were busy) — no client is ever leaked mid-round.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Lifecycle phase of one simulated client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientPhase {
    /// Device is off / unreachable.
    Offline,
    /// Online and selectable.
    Available,
    /// Picked for the current round / async slot.
    Selected,
    /// Running local epochs.
    Training,
    /// Sending its update to the server.
    Uploading,
    /// Update received by the server (terminal for the round).
    Reported,
    /// Abandoned the round (terminal for the round).
    Dropped,
}

impl ClientPhase {
    /// True while the client occupies a round slot.
    pub fn is_busy(self) -> bool {
        matches!(
            self,
            ClientPhase::Selected | ClientPhase::Training | ClientPhase::Uploading
        )
    }

    /// Stable small tag for checkpoint serialization.
    pub fn tag(self) -> u64 {
        match self {
            ClientPhase::Offline => 0,
            ClientPhase::Available => 1,
            ClientPhase::Selected => 2,
            ClientPhase::Training => 3,
            ClientPhase::Uploading => 4,
            ClientPhase::Reported => 5,
            ClientPhase::Dropped => 6,
        }
    }

    /// Inverse of [`ClientPhase::tag`]; `None` on a tag no phase owns
    /// (a corrupt checkpoint, surfaced as `Error::Integrity` upstream).
    pub fn from_tag(tag: u64) -> Option<ClientPhase> {
        Some(match tag {
            0 => ClientPhase::Offline,
            1 => ClientPhase::Available,
            2 => ClientPhase::Selected,
            3 => ClientPhase::Training,
            4 => ClientPhase::Uploading,
            5 => ClientPhase::Reported,
            6 => ClientPhase::Dropped,
            _ => return None,
        })
    }
}

/// One simulated client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub phase: ClientPhase,
    /// Availability-trace state (pool membership is derived from this
    /// plus `phase` by the engine).
    pub online: bool,
    /// Device tier index into the cost model's catalog.
    pub device_class: usize,
    /// Uplink bandwidth in bytes/ms (upload = model_bytes / bandwidth).
    pub bandwidth_bytes_per_ms: f64,
    /// Per-client availability phase offset (diurnal models).
    pub avail_phase_ms: f64,
    /// Selection epoch; in-flight events from stale selections are
    /// ignored when their epoch no longer matches.
    pub epoch: u64,
    /// Global model version the client started training from (async
    /// staleness = current version − start_version at report time).
    pub start_version: usize,
    /// Duration of the client's own current round (compute + upload),
    /// excluding device-queue waits — what adaptive profiling observes.
    pub service_ms: f64,
    pub reports: u32,
    pub dropouts: u32,
}

impl ClientState {
    pub fn new(device_class: usize, bandwidth_bytes_per_ms: f64) -> ClientState {
        ClientState {
            phase: ClientPhase::Offline,
            online: false,
            device_class,
            bandwidth_bytes_per_ms,
            avail_phase_ms: 0.0,
            epoch: 0,
            start_version: 0,
            service_ms: 0.0,
            reports: 0,
            dropouts: 0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.phase.is_busy()
    }

    /// Available → Selected. Bumps the epoch so any stale in-flight
    /// events from a previous selection are ignored.
    pub fn select(&mut self, version: usize) {
        debug_assert_eq!(self.phase, ClientPhase::Available);
        self.phase = ClientPhase::Selected;
        self.epoch += 1;
        self.start_version = version;
    }

    /// Selected → Training.
    pub fn begin_training(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Selected);
        self.phase = ClientPhase::Training;
    }

    /// Training → Uploading.
    pub fn begin_upload(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Training);
        self.phase = ClientPhase::Uploading;
    }

    /// Uploading → Reported.
    pub fn report(&mut self) {
        debug_assert_eq!(self.phase, ClientPhase::Uploading);
        self.phase = ClientPhase::Reported;
        self.reports += 1;
    }

    /// Any busy phase → Dropped.
    pub fn drop_out(&mut self) {
        debug_assert!(self.is_busy(), "drop_out from {:?}", self.phase);
        self.phase = ClientPhase::Dropped;
        self.dropouts += 1;
    }

    /// Terminal (or busy, at simulation teardown) → Available/Offline
    /// according to the availability trace. Returns true when the client
    /// re-enters the available pool.
    pub fn release(&mut self) -> bool {
        self.phase = if self.online {
            ClientPhase::Available
        } else {
            ClientPhase::Offline
        };
        self.online
    }
}

// -------------------------------------------------------- availability

/// Named, seeded availability trace generators. Resolved through the
/// component registry so configs select them by string name:
/// `"always-on"`, `"diurnal"`, `"diurnal(0.6)"`, `"flaky(1800000,600000)"`,
/// `"trace(devices.json)"`.
#[derive(Debug, Clone, PartialEq)]
pub enum AvailabilityModel {
    /// Every client is always online (the 100k-in-seconds default).
    AlwaysOn,
    /// Square-wave day/night cycle with per-client phase offsets.
    Diurnal { period_ms: f64, duty: f64 },
    /// Memoryless on/off churn with exponential dwell times.
    Flaky { mean_on_ms: f64, mean_off_ms: f64 },
    /// Replay of real per-device on/off intervals loaded from a JSON
    /// trace file (`"trace(path)"`). Each simulated client draws one
    /// trace row at population setup — a seed-deterministic *random*
    /// draw rather than `client % rows`, so device availability stays
    /// decorrelated from anything else derived from the client id (like
    /// `edges(n)` cluster assignment) — and replays that row's
    /// on-intervals cyclically with period `period_ms`.
    Trace {
        /// Source path, kept for `name()` round-tripping.
        path: String,
        /// Per-row sorted, merged on-intervals `(start_ms, end_ms)`
        /// within `[0, period_ms]`.
        rows: Arc<Vec<Vec<(f64, f64)>>>,
        period_ms: f64,
    },
}

/// One simulated day, the default diurnal period.
const DAY_MS: f64 = 86_400_000.0;

fn parse_args(spec: &str) -> Result<Vec<f64>> {
    let Some(inner) = spec
        .find('(')
        .map(|i| &spec[i + 1..])
        .and_then(|r| r.strip_suffix(')'))
    else {
        return Ok(Vec::new());
    };
    inner
        .split(',')
        .map(|a| {
            a.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("bad availability arg {a:?} in {spec:?}"))
            })
        })
        .collect()
}

impl AvailabilityModel {
    /// Parse a spec string (head selects the model, args tune it).
    pub fn parse(spec: &str) -> Result<AvailabilityModel> {
        let head = crate::registry::spec_head(spec);
        if head == "trace" {
            // The trace argument is a file path, not a number — handle
            // it before the numeric arg parser sees the spec.
            let path = crate::registry::spec_inner(spec)
                .filter(|p| !p.is_empty())
                .ok_or_else(|| {
                    Error::Config(format!(
                        "trace(file) needs a JSON device-trace path, got \
                         {spec:?}"
                    ))
                })?;
            return Self::load_trace(path);
        }
        let args = parse_args(spec)?;
        match head.as_str() {
            "always-on" | "always" | "on" => Ok(AvailabilityModel::AlwaysOn),
            "diurnal" => {
                let duty = args.first().copied().unwrap_or(0.5);
                let period_ms = args.get(1).copied().unwrap_or(DAY_MS);
                if !(duty > 0.0 && duty <= 1.0) || !(period_ms > 0.0) {
                    return Err(Error::Config(format!(
                        "diurnal needs duty in (0,1] and period > 0, got {spec:?}"
                    )));
                }
                if duty >= 1.0 {
                    // A 100% duty cycle never flips — same as always-on.
                    return Ok(AvailabilityModel::AlwaysOn);
                }
                Ok(AvailabilityModel::Diurnal { period_ms, duty })
            }
            "flaky" => {
                let mean_on_ms = args.first().copied().unwrap_or(1_800_000.0);
                let mean_off_ms = args.get(1).copied().unwrap_or(1_800_000.0);
                if !(mean_on_ms > 0.0 && mean_off_ms > 0.0) {
                    return Err(Error::Config(format!(
                        "flaky needs positive mean on/off ms, got {spec:?}"
                    )));
                }
                Ok(AvailabilityModel::Flaky { mean_on_ms, mean_off_ms })
            }
            other => Err(Error::Config(format!(
                "unknown availability model {other:?} \
                 (always-on | diurnal | flaky | trace(file))"
            ))),
        }
    }

    /// Load a device trace: a JSON object with a `"clients"` array of
    /// per-device on-interval lists (`[[start_ms, end_ms], ...]`) and an
    /// optional `"period_ms"` replay cycle (default: the latest interval
    /// end). Intervals are validated, sorted and merged per row; a trace
    /// whose on-window wraps the period boundary is rejected (start the
    /// cycle inside an off window instead).
    pub fn load_trace(path: &str) -> Result<AvailabilityModel> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("trace({path}): {e}")))?;
        let v = Json::parse(&text)?;
        let clients = v.get("clients").as_arr().ok_or_else(|| {
            Error::Config(format!(
                "trace({path}): expected a \"clients\" array of interval \
                 lists"
            ))
        })?;
        if clients.is_empty() {
            return Err(Error::Config(format!(
                "trace({path}): empty \"clients\" array"
            )));
        }
        let mut rows: Vec<Vec<(f64, f64)>> = Vec::with_capacity(clients.len());
        let mut max_end = 0.0f64;
        for (c, row) in clients.iter().enumerate() {
            let intervals = row.as_arr().ok_or_else(|| {
                Error::Config(format!(
                    "trace({path}): client {c} is not an interval list"
                ))
            })?;
            let mut parsed: Vec<(f64, f64)> = Vec::with_capacity(intervals.len());
            for iv in intervals {
                let pair = iv.as_arr().filter(|p| p.len() == 2);
                let (s, e) = match pair.map(|p| (p[0].as_f64(), p[1].as_f64()))
                {
                    Some((Some(s), Some(e))) => (s, e),
                    _ => {
                        return Err(Error::Config(format!(
                            "trace({path}): client {c} has a malformed \
                             interval (want [start_ms, end_ms])"
                        )))
                    }
                };
                if !(s >= 0.0 && e > s && e.is_finite()) {
                    return Err(Error::Config(format!(
                        "trace({path}): client {c} interval [{s}, {e}] must \
                         satisfy 0 ≤ start < end"
                    )));
                }
                parsed.push((s, e));
            }
            parsed.sort_by(|a, b| a.0.total_cmp(&b.0));
            // Merge touching/overlapping intervals so boundaries are
            // genuine toggles.
            let mut merged: Vec<(f64, f64)> = Vec::with_capacity(parsed.len());
            for (s, e) in parsed {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            if let Some(&(_, e)) = merged.last() {
                max_end = max_end.max(e);
            }
            rows.push(merged);
        }
        let period_ms = match v.get("period_ms").as_f64() {
            Some(p) => p,
            None => max_end,
        };
        if !(period_ms > 0.0 && period_ms.is_finite()) {
            return Err(Error::Config(format!(
                "trace({path}): needs a positive period_ms (or at least \
                 one on-interval to infer it from)"
            )));
        }
        for (c, row) in rows.iter().enumerate() {
            if let Some(&(_, e)) = row.last() {
                if e > period_ms {
                    return Err(Error::Config(format!(
                        "trace({path}): client {c} interval ends at {e} > \
                         period_ms {period_ms}"
                    )));
                }
            }
            if let (Some(&(s0, _)), Some(&(_, e1))) = (row.first(), row.last())
            {
                if s0 == 0.0 && e1 == period_ms {
                    return Err(Error::Config(format!(
                        "trace({path}): client {c}'s on-window wraps the \
                         period boundary; start the replay cycle inside an \
                         off window"
                    )));
                }
            }
        }
        Ok(AvailabilityModel::Trace {
            path: path.to_string(),
            rows: Arc::new(rows),
            period_ms,
        })
    }

    pub fn name(&self) -> String {
        match self {
            AvailabilityModel::AlwaysOn => "always-on".into(),
            AvailabilityModel::Diurnal { period_ms, duty } => {
                format!("diurnal({duty},{period_ms})")
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                format!("flaky({mean_on_ms},{mean_off_ms})")
            }
            AvailabilityModel::Trace { path, .. } => format!("trace({path})"),
        }
    }

    /// Per-client phase offset (diurnal traces), or the assigned trace
    /// row index (device-trace replay).
    pub fn sample_phase_ms(&self, rng: &mut Rng) -> f64 {
        match self {
            AvailabilityModel::Diurnal { period_ms, .. } => {
                rng.uniform() * period_ms
            }
            AvailabilityModel::Trace { rows, .. } => {
                rng.below(rows.len() as u64) as f64
            }
            _ => 0.0,
        }
    }

    /// Is the client online at t = 0?
    pub fn initial_online(&self, phase_ms: f64, rng: &mut Rng) -> bool {
        match self {
            AvailabilityModel::AlwaysOn => true,
            AvailabilityModel::Diurnal { period_ms, duty } => {
                (phase_ms % period_ms) < duty * period_ms
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                // Stationary distribution of the on/off chain.
                rng.uniform() < mean_on_ms / (mean_on_ms + mean_off_ms)
            }
            AvailabilityModel::Trace { rows, .. } => {
                trace_row(rows, phase_ms)
                    .first()
                    .is_some_and(|&(s, _)| s == 0.0)
            }
        }
    }

    /// Absolute time of the next on/off flip after `now_ms`
    /// (`f64::INFINITY` ⇒ never flips — the engine skips the event).
    pub fn next_toggle_ms(
        &self,
        online: bool,
        phase_ms: f64,
        now_ms: f64,
        rng: &mut Rng,
    ) -> f64 {
        match self {
            AvailabilityModel::AlwaysOn => f64::INFINITY,
            AvailabilityModel::Diurnal { period_ms, duty } => {
                let (period_ms, duty) = (*period_ms, *duty);
                let on_ms = duty * period_ms;
                let local = (now_ms + phase_ms) % period_ms;
                if online {
                    // Next flip at the end of the on-window. Toggles are
                    // only scheduled right after entering a window, so
                    // `local` is always strictly inside it.
                    now_ms + (on_ms - local).max(0.0)
                } else {
                    now_ms + (period_ms - local).max(0.0)
                }
            }
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                let mean = if online { *mean_on_ms } else { *mean_off_ms };
                let u = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE);
                now_ms + (-u.ln()) * mean
            }
            AvailabilityModel::Trace { rows, period_ms, .. } => {
                let period = *period_ms;
                let row = trace_row(rows, phase_ms);
                if row.is_empty() {
                    // A device that never reported online stays offline.
                    return f64::INFINITY;
                }
                let local = now_ms.rem_euclid(period);
                let cycle_base = now_ms - local;
                if online {
                    // Next end boundary strictly after `local` (wrap to
                    // the next cycle's first end if none remains).
                    match row.iter().map(|&(_, e)| e).find(|&e| e > local) {
                        Some(e) => cycle_base + e,
                        None => cycle_base + period + row[0].1,
                    }
                } else {
                    // Next start boundary strictly after `local`.
                    match row.iter().map(|&(s, _)| s).find(|&s| s > local) {
                        Some(s) => cycle_base + s,
                        None => cycle_base + period + row[0].0,
                    }
                }
            }
        }
    }
}

/// The trace row a client's phase encodes (clamped defensively; phases
/// are produced by [`AvailabilityModel::sample_phase_ms`]).
fn trace_row(rows: &[Vec<(f64, f64)>], phase_ms: f64) -> &[(f64, f64)] {
    let i = (phase_ms.max(0.0) as usize).min(rows.len().saturating_sub(1));
    &rows[i]
}

// --------------------------------------------------------------- pool

/// O(1) insert/remove/sample set of available client ids — the engine's
/// "available pool". Swap-remove keeps sampling O(k) regardless of
/// federation size (a 1M-client pool costs two `Vec<usize>`).
#[derive(Debug, Clone)]
pub struct Pool {
    members: Vec<usize>,
    /// Position of each client in `members` (`usize::MAX` ⇒ absent).
    pos: Vec<usize>,
}

impl Pool {
    pub fn new(num_clients: usize) -> Pool {
        Pool { members: Vec::new(), pos: vec![usize::MAX; num_clients] }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, client: usize) -> bool {
        self.pos[client] != usize::MAX
    }

    pub fn insert(&mut self, client: usize) {
        if self.contains(client) {
            return;
        }
        self.pos[client] = self.members.len();
        self.members.push(client);
    }

    pub fn remove(&mut self, client: usize) {
        let p = self.pos[client];
        if p == usize::MAX {
            return;
        }
        let last = self.members.len() - 1;
        self.members.swap(p, last);
        self.pos[self.members[p]] = p;
        self.members.pop();
        self.pos[client] = usize::MAX;
    }

    /// Draw up to `k` distinct clients uniformly, removing them from the
    /// pool (they are about to be Selected).
    pub fn sample(&mut self, k: usize, rng: &mut Rng) -> Vec<usize> {
        let k = k.min(self.members.len());
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = rng.below(self.members.len() as u64) as usize;
            let c = self.members[i];
            self.remove(c);
            out.push(c);
        }
        out
    }

    /// Membership in insertion/swap order. This order is load-bearing:
    /// [`Pool::sample`] indexes into it, so a checkpoint must persist it
    /// verbatim — it is *not* reconstructible from client phases alone
    /// (removals permute survivors via swap-remove).
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Rebuild a pool with the given member order over a population of
    /// `num_clients` (the checkpoint-restore constructor).
    pub fn from_members(num_clients: usize, members: Vec<usize>) -> Pool {
        let mut pos = vec![usize::MAX; num_clients];
        for (p, &c) in members.iter().enumerate() {
            pos[c] = p;
        }
        Pool { members, pos }
    }

    /// Extend the population to `num_clients` ids (elastic-membership
    /// joins). Existing membership is untouched; new ids start absent.
    pub fn grow(&mut self, num_clients: usize) {
        if num_clients > self.pos.len() {
            self.pos.resize(num_clients, usize::MAX);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_walks_the_full_machine() {
        let mut c = ClientState::new(2, 1000.0);
        c.online = true;
        assert!(c.release());
        assert_eq!(c.phase, ClientPhase::Available);
        c.select(3);
        assert_eq!(c.epoch, 1);
        assert_eq!(c.start_version, 3);
        c.begin_training();
        c.begin_upload();
        c.report();
        assert_eq!(c.phase, ClientPhase::Reported);
        assert_eq!(c.reports, 1);
        assert!(c.release());
        // Second selection bumps the epoch; dropout path.
        c.select(4);
        c.begin_training();
        c.drop_out();
        assert_eq!(c.phase, ClientPhase::Dropped);
        assert_eq!(c.dropouts, 1);
        c.online = false;
        assert!(!c.release());
        assert_eq!(c.phase, ClientPhase::Offline);
    }

    #[test]
    fn availability_specs_parse() {
        assert_eq!(
            AvailabilityModel::parse("always-on").unwrap(),
            AvailabilityModel::AlwaysOn
        );
        match AvailabilityModel::parse("diurnal(0.25)").unwrap() {
            AvailabilityModel::Diurnal { duty, .. } => {
                assert!((duty - 0.25).abs() < 1e-12)
            }
            other => panic!("{other:?}"),
        }
        match AvailabilityModel::parse("flaky(1000,2000)").unwrap() {
            AvailabilityModel::Flaky { mean_on_ms, mean_off_ms } => {
                assert_eq!(mean_on_ms, 1000.0);
                assert_eq!(mean_off_ms, 2000.0);
            }
            other => panic!("{other:?}"),
        }
        assert!(AvailabilityModel::parse("lunar").is_err());
        assert!(AvailabilityModel::parse("diurnal(2.0)").is_err());
    }

    #[test]
    fn always_on_never_toggles() {
        let m = AvailabilityModel::AlwaysOn;
        let mut rng = Rng::new(1);
        assert!(m.initial_online(0.0, &mut rng));
        assert!(m.next_toggle_ms(true, 0.0, 5.0, &mut rng).is_infinite());
    }

    #[test]
    fn diurnal_toggles_advance_and_alternate() {
        let m = AvailabilityModel::Diurnal { period_ms: 100.0, duty: 0.6 };
        let mut rng = Rng::new(2);
        // Phase 0: online in [0, 60), offline in [60, 100).
        assert!(m.initial_online(0.0, &mut rng));
        let t_off = m.next_toggle_ms(true, 0.0, 0.0, &mut rng);
        assert!((t_off - 60.0).abs() < 1e-6, "{t_off}");
        let t_on = m.next_toggle_ms(false, 0.0, t_off, &mut rng);
        assert!((t_on - 100.0).abs() < 1e-6, "{t_on}");
    }

    #[test]
    fn flaky_dwell_times_follow_means() {
        let m = AvailabilityModel::Flaky { mean_on_ms: 500.0, mean_off_ms: 50.0 };
        let mut rng = Rng::new(3);
        let n = 4000;
        let avg_on: f64 = (0..n)
            .map(|_| m.next_toggle_ms(true, 0.0, 0.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        let avg_off: f64 = (0..n)
            .map(|_| m.next_toggle_ms(false, 0.0, 0.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((avg_on - 500.0).abs() < 50.0, "{avg_on}");
        assert!((avg_off - 50.0).abs() < 5.0, "{avg_off}");
        // Stationary online fraction ≈ 500/550.
        let online = (0..n).filter(|_| m.initial_online(0.0, &mut rng)).count();
        let frac = online as f64 / n as f64;
        assert!((frac - 500.0 / 550.0).abs() < 0.05, "{frac}");
    }

    #[test]
    fn trace_model_replays_intervals_cyclically() {
        let path = std::env::temp_dir().join("easyfl_test_trace.json");
        std::fs::write(
            &path,
            r#"{"period_ms": 1000,
                "clients": [[[0, 300], [500, 800]], [[200, 400]], []]}"#,
        )
        .unwrap();
        let m = AvailabilityModel::load_trace(path.to_str().unwrap()).unwrap();
        let mut rng = Rng::new(1);
        // Row 0 starts online at t = 0 and toggles at its boundaries.
        assert!(m.initial_online(0.0, &mut rng));
        assert_eq!(m.next_toggle_ms(true, 0.0, 0.0, &mut rng), 300.0);
        assert_eq!(m.next_toggle_ms(false, 0.0, 300.0, &mut rng), 500.0);
        assert_eq!(m.next_toggle_ms(true, 0.0, 500.0, &mut rng), 800.0);
        // After the last interval the replay wraps into the next cycle.
        assert_eq!(m.next_toggle_ms(false, 0.0, 800.0, &mut rng), 1000.0);
        assert_eq!(m.next_toggle_ms(true, 0.0, 1000.0, &mut rng), 1300.0);
        // Row 1 starts offline; row 2 (no intervals) never comes online.
        assert!(!m.initial_online(1.0, &mut rng));
        assert_eq!(m.next_toggle_ms(false, 1.0, 0.0, &mut rng), 200.0);
        assert!(!m.initial_online(2.0, &mut rng));
        assert!(m.next_toggle_ms(false, 2.0, 0.0, &mut rng).is_infinite());
        // Phases are row indices within the trace.
        for _ in 0..50 {
            let p = m.sample_phase_ms(&mut rng);
            assert!((0.0..3.0).contains(&p), "{p}");
        }
        assert!(m.name().starts_with("trace("), "{}", m.name());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_parsing_rejects_malformed_files() {
        let dir = std::env::temp_dir();
        let bad = [
            ("easyfl_bad_trace1.json", r#"{"clients": []}"#),
            ("easyfl_bad_trace2.json", r#"{"clients": [[[300, 200]]]}"#),
            (
                "easyfl_bad_trace3.json",
                r#"{"period_ms": 100, "clients": [[[0, 200]]]}"#,
            ),
            // On-window wrapping the period boundary is ambiguous.
            (
                "easyfl_bad_trace4.json",
                r#"{"period_ms": 400, "clients": [[[0, 400]]]}"#,
            ),
            // No interval anywhere ⇒ no period to infer.
            ("easyfl_bad_trace5.json", r#"{"clients": [[]]}"#),
        ];
        for (name, content) in bad {
            let p = dir.join(name);
            std::fs::write(&p, content).unwrap();
            assert!(
                AvailabilityModel::load_trace(p.to_str().unwrap()).is_err(),
                "{content}"
            );
            let _ = std::fs::remove_file(&p);
        }
        assert!(AvailabilityModel::parse("trace(/no/such/trace.json)").is_err());
        assert!(AvailabilityModel::parse("trace()").is_err());
    }

    #[test]
    fn trace_merges_overlapping_intervals() {
        let path = std::env::temp_dir().join("easyfl_test_trace_merge.json");
        std::fs::write(
            &path,
            r#"{"period_ms": 1000, "clients": [[[100, 300], [250, 500], [500, 600]]]}"#,
        )
        .unwrap();
        let m = AvailabilityModel::load_trace(path.to_str().unwrap()).unwrap();
        let mut rng = Rng::new(2);
        // [100,300] ∪ [250,500] ∪ [500,600] merge to one [100,600] window.
        assert_eq!(m.next_toggle_ms(false, 0.0, 0.0, &mut rng), 100.0);
        assert_eq!(m.next_toggle_ms(true, 0.0, 100.0, &mut rng), 600.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pool_sample_is_distinct_and_removing() {
        let mut pool = Pool::new(100);
        for c in 0..100 {
            pool.insert(c);
        }
        let mut rng = Rng::new(4);
        let picked = pool.sample(30, &mut rng);
        assert_eq!(picked.len(), 30);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30, "samples must be distinct");
        assert_eq!(pool.len(), 70);
        for &c in &picked {
            assert!(!pool.contains(c));
            pool.insert(c);
        }
        assert_eq!(pool.len(), 100);
        // Over-asking returns everything.
        let all = pool.sample(1000, &mut rng);
        assert_eq!(all.len(), 100);
        assert!(pool.is_empty());
    }

    #[test]
    fn pool_remove_is_idempotent() {
        let mut pool = Pool::new(3);
        pool.insert(1);
        pool.remove(1);
        pool.remove(1);
        pool.remove(0);
        assert_eq!(pool.len(), 0);
        pool.insert(1);
        pool.insert(1);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn pool_round_trips_through_members_and_grows() {
        let mut pool = Pool::new(10);
        for c in [4, 1, 7, 2, 9] {
            pool.insert(c);
        }
        pool.remove(1); // Swap-remove permutes the survivors.
        let twin = Pool::from_members(10, pool.members().to_vec());
        assert_eq!(twin.members(), pool.members());
        // Identical member order ⇒ identical draws from the same stream.
        let mut a = pool.clone();
        let mut b = twin;
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        assert_eq!(a.sample(3, &mut ra), b.sample(3, &mut rb));
        // Growth admits new ids without disturbing existing members.
        a.grow(12);
        a.insert(11);
        assert!(a.contains(11));
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn phase_tags_round_trip() {
        for phase in [
            ClientPhase::Offline,
            ClientPhase::Available,
            ClientPhase::Selected,
            ClientPhase::Training,
            ClientPhase::Uploading,
            ClientPhase::Reported,
            ClientPhase::Dropped,
        ] {
            assert_eq!(ClientPhase::from_tag(phase.tag()), Some(phase));
        }
        assert_eq!(ClientPhase::from_tag(7), None);
    }
}
