//! Chaos plane: registry-injectable faults for crash-safety testing.
//!
//! A [`Fault`] is a deterministic, seed-reproducible failure injected
//! into a simulation from `Config.chaos`. Each fault is designed to be
//! paired with a recovery assertion: a run that is killed, partitioned,
//! or lossy must — after checkpoint/resume — reproduce the
//! uninterrupted run's trace digest bit-for-bit (see
//! `tests/chaos_recovery.rs`). An empty fault list burns zero RNG and
//! leaves every pre-existing digest untouched.

use crate::error::{Error, Result};

/// One injectable fault (registered under the `chaos` config list).
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// `kill_server_at_round(r)`: hard-stop the run once `r` rounds have
    /// aggregated — after that boundary's checkpoint is written, so the
    /// run is resumable. Models a server crash.
    KillServerAtRound { round: usize },
    /// `partition_edge(c)`: network-partition edge cluster `c` — its
    /// clients' reports never reach the cloud (hierarchical topologies;
    /// a no-op cluster id on flat runs is a config error at submit).
    PartitionEdge { cluster: usize },
    /// `drop_frames(f)`: each report is lost in transit with
    /// probability `f`, converting it into a dropout. Draws only from
    /// the dedicated chaos RNG stream.
    DropFrames { frac: f64 },
    /// `corrupt_checkpoint`: flip one payload byte of every checkpoint
    /// just after it is written — resuming from it must surface a typed
    /// integrity error, never a wrong-answer run.
    CorruptCheckpoint,
    /// `drop_midframe(f)`: each report's frame is cut mid-transfer with
    /// probability `f` — the bytes were partially shipped but the
    /// update never lands, converting the reporter into a dropout.
    /// The wire-level twin of `drop_frames` (the reactor's mid-frame
    /// cut, promoted from `tests/remote_loopback.rs` into the config
    /// plane). Draws only from the dedicated chaos RNG stream.
    DropMidframe { frac: f64 },
    /// `stall_frames(f, ms)`: each report's frame stalls partially
    /// written with probability `f` and completes `ms` later — the
    /// slow-trickle reactor fault. The report still lands (late); a
    /// stall past the round deadline turns the client into a genuine
    /// straggler.
    StallFrames { frac: f64, delay_ms: f64 },
}

fn parse_args(spec: &str) -> Result<Vec<f64>> {
    let Some(inner) = spec
        .find('(')
        .map(|i| &spec[i + 1..])
        .and_then(|r| r.strip_suffix(')'))
    else {
        return Ok(Vec::new());
    };
    inner
        .split(',')
        .map(|a| {
            a.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("bad chaos arg {a:?} in {spec:?}"))
            })
        })
        .collect()
}

fn index_arg(spec: &str, args: &[f64], what: &str) -> Result<usize> {
    match args.first().copied() {
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x.is_finite() => {
            Ok(x as usize)
        }
        _ => Err(Error::Config(format!(
            "{what} needs a non-negative integer argument, got {spec:?}"
        ))),
    }
}

impl Fault {
    /// Parse a fault spec string. Accepted heads are exactly the
    /// registered names — the registry resolves the head first.
    pub fn parse(spec: &str) -> Result<Fault> {
        let head = crate::registry::spec_head(spec);
        let args = parse_args(spec)?;
        match head.as_str() {
            "kill_server_at_round" => Ok(Fault::KillServerAtRound {
                round: index_arg(spec, &args, "kill_server_at_round")?,
            }),
            "partition_edge" => Ok(Fault::PartitionEdge {
                cluster: index_arg(spec, &args, "partition_edge")?,
            }),
            "drop_frames" => {
                let frac = args.first().copied().unwrap_or(f64::NAN);
                if !(0.0..=1.0).contains(&frac) {
                    return Err(Error::Config(format!(
                        "drop_frames needs a fraction in [0, 1], got {spec:?}"
                    )));
                }
                Ok(Fault::DropFrames { frac })
            }
            "corrupt_checkpoint" => Ok(Fault::CorruptCheckpoint),
            "drop_midframe" => {
                let frac = args.first().copied().unwrap_or(f64::NAN);
                if !(0.0..=1.0).contains(&frac) {
                    return Err(Error::Config(format!(
                        "drop_midframe needs a fraction in [0, 1], got \
                         {spec:?}"
                    )));
                }
                Ok(Fault::DropMidframe { frac })
            }
            "stall_frames" => {
                let frac = args.first().copied().unwrap_or(f64::NAN);
                let delay_ms = args.get(1).copied().unwrap_or(f64::NAN);
                if !(0.0..=1.0).contains(&frac)
                    || !(delay_ms > 0.0 && delay_ms.is_finite())
                {
                    return Err(Error::Config(format!(
                        "stall_frames needs (fraction in [0, 1], \
                         delay_ms > 0), got {spec:?}"
                    )));
                }
                Ok(Fault::StallFrames { frac, delay_ms })
            }
            other => Err(Error::Config(format!(
                "unknown fault {other:?} (kill_server_at_round(r) | \
                 partition_edge(c) | drop_frames(f) | corrupt_checkpoint \
                 | drop_midframe(f) | stall_frames(f,ms))"
            ))),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Fault::KillServerAtRound { round } => {
                format!("kill_server_at_round({round})")
            }
            Fault::PartitionEdge { cluster } => {
                format!("partition_edge({cluster})")
            }
            Fault::DropFrames { frac } => format!("drop_frames({frac})"),
            Fault::CorruptCheckpoint => "corrupt_checkpoint".into(),
            Fault::DropMidframe { frac } => format!("drop_midframe({frac})"),
            Fault::StallFrames { frac, delay_ms } => {
                format!("stall_frames({frac},{delay_ms})")
            }
        }
    }
}

/// Parse every spec in a config's `chaos` list.
pub fn parse_faults(specs: &[String]) -> Result<Vec<Fault>> {
    specs.iter().map(|s| Fault::parse(s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_round_trip() {
        for (spec, want) in [
            (
                "kill_server_at_round(10)",
                Fault::KillServerAtRound { round: 10 },
            ),
            ("partition_edge(2)", Fault::PartitionEdge { cluster: 2 }),
            ("drop_frames(0.05)", Fault::DropFrames { frac: 0.05 }),
            ("corrupt_checkpoint", Fault::CorruptCheckpoint),
            ("drop_midframe(0.02)", Fault::DropMidframe { frac: 0.02 }),
            (
                "stall_frames(0.1,2500)",
                Fault::StallFrames { frac: 0.1, delay_ms: 2500.0 },
            ),
        ] {
            let f = Fault::parse(spec).unwrap();
            assert_eq!(f, want, "{spec}");
            assert_eq!(Fault::parse(&f.name()).unwrap(), f);
        }
    }

    #[test]
    fn bad_specs_are_config_errors() {
        for spec in [
            "meteor_strike",
            "kill_server_at_round",
            "kill_server_at_round(-1)",
            "kill_server_at_round(1.5)",
            "partition_edge(x)",
            "drop_frames",
            "drop_frames(1.5)",
            "drop_frames(-0.1)",
            "drop_midframe",
            "drop_midframe(2)",
            "stall_frames(0.1)",
            "stall_frames(0.1,0)",
            "stall_frames(1.5,100)",
        ] {
            assert!(Fault::parse(spec).is_err(), "{spec}");
        }
    }

    #[test]
    fn fault_lists_parse_together() {
        let specs =
            vec!["drop_frames(0.1)".to_string(), "corrupt_checkpoint".into()];
        assert_eq!(parse_faults(&specs).unwrap().len(), 2);
        assert!(parse_faults(&["nope".to_string()]).is_err());
    }
}
