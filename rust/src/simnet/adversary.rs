//! Byzantine adversary models for simulated federations.
//!
//! A fraction of the population (`Config.sim.adversary_frac`, chosen
//! deterministically per seed) behaves Byzantine: instead of its honest
//! surrogate delta, each corrupted client reports what its
//! [`AdversaryModel`] fabricates. The three built-ins cover the classic
//! attack families the robust-aggregation literature benchmarks against:
//!
//! * `"sign-flip"` — report the negated honest delta (gradient-reversal
//!   / label-flip proxy). Same norm as an honest update, so norm
//!   clipping cannot catch it — only rank statistics do.
//! * `"scaled-noise(factor)"` — replace the delta with `factor`-scaled
//!   Gaussian noise (model-poisoning / garbage uploads). Huge norm, so
//!   `"norm_clip"` neutralizes it cheaply.
//! * `"zero-update"` — report a zero delta (free-riding). Dilutes rather
//!   than reverses progress; robust means shrug it off.
//!
//! Adversaries are registry-backed like availability and cost models:
//! configs select them by spec string, and custom attacks register under
//! new names via `ComponentRegistry::register_adversary`.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Named, seeded update-corruption strategies.
#[derive(Debug, Clone, PartialEq)]
pub enum AdversaryModel {
    /// Negate the honest delta.
    SignFlip,
    /// Replace the delta with `factor`-scaled Gaussian noise.
    ScaledNoise { factor: f64 },
    /// Report a zero delta (free-rider).
    ZeroUpdate,
}

impl AdversaryModel {
    /// Parse a spec string (head selects the model, args tune it). The
    /// accepted heads are exactly the registered names — the registry
    /// resolves the head before calling this, so an alias accepted only
    /// here would be unreachable from any config path.
    pub fn parse(spec: &str) -> Result<AdversaryModel> {
        let head = crate::registry::spec_head(spec);
        match head.as_str() {
            "sign-flip" => Ok(AdversaryModel::SignFlip),
            "scaled-noise" => {
                let factor = match spec
                    .find('(')
                    .map(|i| &spec[i + 1..])
                    .and_then(|r| r.strip_suffix(')'))
                {
                    Some(inner) => inner.trim().parse::<f64>().map_err(|_| {
                        Error::Config(format!(
                            "bad scaled-noise factor in {spec:?}"
                        ))
                    })?,
                    None => 10.0,
                };
                if !(factor > 0.0 && factor.is_finite()) {
                    return Err(Error::Config(format!(
                        "scaled-noise needs a positive finite factor, got \
                         {spec:?}"
                    )));
                }
                Ok(AdversaryModel::ScaledNoise { factor })
            }
            "zero-update" => Ok(AdversaryModel::ZeroUpdate),
            other => Err(Error::Config(format!(
                "unknown adversary model {other:?} (sign-flip | \
                 scaled-noise(factor) | zero-update)"
            ))),
        }
    }

    pub fn name(&self) -> String {
        match self {
            AdversaryModel::SignFlip => "sign-flip".into(),
            AdversaryModel::ScaledNoise { factor } => {
                format!("scaled-noise({factor})")
            }
            AdversaryModel::ZeroUpdate => "zero-update".into(),
        }
    }

    /// Corrupt one honest delta in place. Draws (if any) come from the
    /// caller's dedicated adversary RNG, so attacks are reproducible per
    /// seed and never perturb the simulation's main stream.
    pub fn corrupt(&self, delta: &mut [f32], rng: &mut Rng) {
        match self {
            AdversaryModel::SignFlip => {
                for v in delta.iter_mut() {
                    *v = -*v;
                }
            }
            AdversaryModel::ScaledNoise { factor } => {
                for v in delta.iter_mut() {
                    *v = (factor * rng.normal()) as f32;
                }
            }
            AdversaryModel::ZeroUpdate => {
                delta.iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversary_specs_parse() {
        assert_eq!(
            AdversaryModel::parse("sign-flip").unwrap(),
            AdversaryModel::SignFlip
        );
        assert_eq!(
            AdversaryModel::parse("zero-update").unwrap(),
            AdversaryModel::ZeroUpdate
        );
        match AdversaryModel::parse("scaled-noise(25)").unwrap() {
            AdversaryModel::ScaledNoise { factor } => {
                assert_eq!(factor, 25.0)
            }
            other => panic!("{other:?}"),
        }
        // Bare name gets the default factor.
        assert_eq!(
            AdversaryModel::parse("scaled-noise").unwrap(),
            AdversaryModel::ScaledNoise { factor: 10.0 }
        );
        assert!(AdversaryModel::parse("scaled-noise(-3)").is_err());
        assert!(AdversaryModel::parse("scaled-noise(lots)").is_err());
        assert!(AdversaryModel::parse("charm-offensive").is_err());
        // Only the registered heads parse — no unreachable aliases.
        assert!(AdversaryModel::parse("flip").is_err());
        assert!(AdversaryModel::parse("zero").is_err());
    }

    #[test]
    fn corruption_shapes_match_the_attack() {
        let mut rng = Rng::new(9);
        let mut d = vec![1.0f32, -2.0, 3.0];
        AdversaryModel::SignFlip.corrupt(&mut d, &mut rng);
        assert_eq!(d, vec![-1.0, 2.0, -3.0]);

        let mut d = vec![1.0f32; 3];
        AdversaryModel::ZeroUpdate.corrupt(&mut d, &mut rng);
        assert_eq!(d, vec![0.0; 3]);

        let mut d = vec![1.0f32; 64];
        AdversaryModel::ScaledNoise { factor: 50.0 }.corrupt(&mut d, &mut rng);
        let norm: f64 =
            d.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(norm > 100.0, "noise must dwarf an honest delta: {norm}");
    }

    #[test]
    fn corruption_is_deterministic_per_seed() {
        let run = || {
            let mut rng = Rng::new(1234);
            let mut d = vec![0.5f32; 16];
            AdversaryModel::ScaledNoise { factor: 10.0 }
                .corrupt(&mut d, &mut rng);
            d
        };
        assert_eq!(run(), run());
    }
}
