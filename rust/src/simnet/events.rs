//! Discrete-event queue over a virtual timeline.
//!
//! The heart of SimNet: a binary min-heap of timestamped events. Popping
//! an event advances the virtual clock to its timestamp — no thread ever
//! sleeps, so a 100k-client federation simulates in seconds. Ties are
//! broken by insertion sequence, which (together with the single seeded
//! [`crate::util::rng::Rng`] threaded through the engines) makes every
//! run bit-for-bit reproducible: the queue folds each popped event into a
//! running digest that determinism tests compare across runs.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::error::{Error, Result};

/// What happens when an event fires.
///
/// `epoch` fields carry the client's selection epoch at scheduling time;
/// the engines ignore events whose epoch no longer matches (e.g. a report
/// from a client that was already dropped at the round deadline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A client comes online (enters the available pool when idle).
    Online { client: usize },
    /// A client goes offline (leaves the available pool when idle).
    Offline { client: usize },
    /// A synchronous round begins.
    RoundStart { round: usize },
    /// A selected client finishes training + upload and reports.
    Report { client: usize, epoch: u64 },
    /// A selected client drops out mid-round.
    Dropout { client: usize, epoch: u64 },
    /// The synchronous round deadline fires.
    Deadline { round: usize },
}

impl EventKind {
    /// Stable small tag for the trace digest.
    fn tag(&self) -> u64 {
        match self {
            EventKind::Online { .. } => 1,
            EventKind::Offline { .. } => 2,
            EventKind::RoundStart { .. } => 3,
            EventKind::Report { .. } => 4,
            EventKind::Dropout { .. } => 5,
            EventKind::Deadline { .. } => 6,
        }
    }

    /// Payload folded into the trace digest alongside the tag.
    fn payload(&self) -> (u64, u64) {
        match *self {
            EventKind::Online { client } | EventKind::Offline { client } => {
                (client as u64, 0)
            }
            EventKind::RoundStart { round } | EventKind::Deadline { round } => {
                (round as u64, 0)
            }
            EventKind::Report { client, epoch }
            | EventKind::Dropout { client, epoch } => (client as u64, epoch),
        }
    }

    /// Inverse of [`EventKind::tag`]/[`EventKind::payload`]: rebuild a
    /// kind from its digest triple when a checkpointed queue is restored.
    /// An unknown tag means the checkpoint bytes are bad, not a bug here.
    fn from_parts(tag: u64, a: u64, b: u64) -> Option<EventKind> {
        Some(match tag {
            1 => EventKind::Online { client: a as usize },
            2 => EventKind::Offline { client: a as usize },
            3 => EventKind::RoundStart { round: a as usize },
            4 => EventKind::Report { client: a as usize, epoch: b },
            5 => EventKind::Dropout { client: a as usize, epoch: b },
            6 => EventKind::Deadline { round: a as usize },
            _ => return None,
        })
    }
}

/// Full serialized queue state: clock, counters, the running digest, and
/// every pending event as `(time bits, seq, kind tag, payload a, payload
/// b)` sorted by `(time, seq)` so the snapshot is canonical regardless of
/// the heap's internal layout. [`EventQueue::restore`] rebuilds a queue
/// that pops the identical event sequence and continues the identical
/// digest — the property the crash-safe resume tests assert bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueSnapshot {
    pub now_ms_bits: u64,
    pub next_seq: u64,
    pub processed: u64,
    pub digest: u64,
    pub events: Vec<(u64, u64, u64, u64, u64)>,
}

/// A timestamped event. Total order: (time, insertion sequence).
#[derive(Debug, Clone)]
pub struct Event {
    /// Virtual milliseconds since simulation start.
    pub time_ms: f64,
    /// Insertion sequence — unique per queue, breaks same-time ties FIFO.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.time_ms.to_bits() == other.time_ms.to_bits()
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time_ms
            .total_cmp(&other.time_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with a monotone virtual clock and trace digest.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    next_seq: u64,
    now_ms: f64,
    processed: u64,
    digest: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Current virtual time (timestamp of the last popped event).
    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Schedule `kind` at absolute virtual time `time_ms`. Non-finite or
    /// past times are clamped to "now" so the clock stays monotone.
    pub fn push(&mut self, time_ms: f64, kind: EventKind) {
        let time_ms = if time_ms.is_finite() {
            time_ms.max(self.now_ms)
        } else {
            // Infinity means "never" — callers should skip the push, but
            // clamping keeps the queue well-behaved if one slips through.
            return;
        };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(std::cmp::Reverse(Event { time_ms, seq, kind }));
    }

    /// Pop the earliest event and advance the virtual clock to it.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?.0;
        self.now_ms = self.now_ms.max(ev.time_ms);
        self.processed += 1;
        // FNV-1a-style fold of (time bits, kind, payload) — cheap, stable,
        // and sensitive to ordering: equal digests ⇒ equal event traces.
        let (a, b) = ev.kind.payload();
        for word in [ev.time_ms.to_bits(), ev.kind.tag(), a, b] {
            self.digest ^= word;
            self.digest = self.digest.wrapping_mul(0x0000_0100_0000_01B3);
        }
        Some(ev)
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time_ms)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events popped so far (the "events" throughput metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Order-sensitive digest of every event popped so far.
    pub fn trace_digest(&self) -> u64 {
        self.digest
    }

    /// Serialize the complete queue state (see [`QueueSnapshot`]).
    pub fn snapshot(&self) -> QueueSnapshot {
        let mut events: Vec<(u64, u64, u64, u64, u64)> = self
            .heap
            .iter()
            .map(|e| {
                let ev = &e.0;
                let (a, b) = ev.kind.payload();
                (ev.time_ms.to_bits(), ev.seq, ev.kind.tag(), a, b)
            })
            .collect();
        events.sort_unstable_by(|x, y| {
            f64::from_bits(x.0)
                .total_cmp(&f64::from_bits(y.0))
                .then(x.1.cmp(&y.1))
        });
        QueueSnapshot {
            now_ms_bits: self.now_ms.to_bits(),
            next_seq: self.next_seq,
            processed: self.processed,
            digest: self.digest,
            events,
        }
    }

    /// Rebuild a queue from a [`QueueSnapshot`]. Events are re-inserted
    /// verbatim (times and sequence numbers unclamped, unlike
    /// [`EventQueue::push`]) so the restored heap pops the exact sequence
    /// the original would have. A snapshot carrying an unknown event tag
    /// is an [`Error::Integrity`].
    pub fn restore(snap: &QueueSnapshot) -> Result<EventQueue> {
        let mut heap = BinaryHeap::with_capacity(snap.events.len());
        for &(time_bits, seq, tag, a, b) in &snap.events {
            let kind = EventKind::from_parts(tag, a, b).ok_or_else(|| {
                Error::Integrity(format!(
                    "checkpointed event queue has unknown event tag {tag}"
                ))
            })?;
            heap.push(std::cmp::Reverse(Event {
                time_ms: f64::from_bits(time_bits),
                seq,
                kind,
            }));
        }
        Ok(EventQueue {
            heap,
            next_seq: snap.next_seq,
            now_ms: f64::from_bits(snap.now_ms_bits),
            processed: snap.processed,
            digest: snap.digest,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Deadline { round: 0 });
        q.push(1.0, EventKind::Online { client: 1 });
        q.push(1.0, EventKind::Online { client: 2 });
        q.push(3.0, EventKind::Offline { client: 1 });
        let order: Vec<EventKind> =
            std::iter::from_fn(|| q.pop()).map(|e| e.kind).collect();
        assert_eq!(
            order,
            vec![
                EventKind::Online { client: 1 },
                EventKind::Online { client: 2 },
                EventKind::Offline { client: 1 },
                EventKind::Deadline { round: 0 },
            ]
        );
        assert_eq!(q.processed(), 4);
        assert_eq!(q.now_ms(), 5.0);
    }

    #[test]
    fn clock_is_monotone_even_for_past_pushes() {
        let mut q = EventQueue::new();
        q.push(10.0, EventKind::RoundStart { round: 0 });
        q.pop();
        // Scheduling "in the past" clamps to now.
        q.push(3.0, EventKind::RoundStart { round: 1 });
        let ev = q.pop().unwrap();
        assert_eq!(ev.time_ms, 10.0);
        assert_eq!(q.now_ms(), 10.0);
    }

    #[test]
    fn infinite_times_are_never_scheduled() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, EventKind::Online { client: 0 });
        assert!(q.is_empty());
    }

    #[test]
    fn snapshot_restore_pops_identically_and_continues_the_digest() {
        let mut q = EventQueue::new();
        q.push(4.0, EventKind::Deadline { round: 1 });
        q.push(1.0, EventKind::Online { client: 3 });
        q.push(2.5, EventKind::Report { client: 7, epoch: 9 });
        q.push(2.5, EventKind::Dropout { client: 8, epoch: 2 });
        // Pop one so now/processed/digest are mid-stream.
        q.pop().unwrap();

        let snap = q.snapshot();
        let mut twin = EventQueue::restore(&snap).unwrap();
        assert_eq!(twin.now_ms(), q.now_ms());
        assert_eq!(twin.processed(), q.processed());
        assert_eq!(twin.trace_digest(), q.trace_digest());

        // Identical remaining pops, identical final digest, and pushes
        // after the restore keep the FIFO tie-break aligned (next_seq
        // round-trips too).
        q.push(3.0, EventKind::RoundStart { round: 2 });
        twin.push(3.0, EventKind::RoundStart { round: 2 });
        loop {
            match (q.pop(), twin.pop()) {
                (None, None) => break,
                (a, b) => {
                    let (a, b) = (a.unwrap(), b.unwrap());
                    assert_eq!(a.kind, b.kind);
                    assert_eq!(a.seq, b.seq);
                    assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits());
                }
            }
        }
        assert_eq!(q.trace_digest(), twin.trace_digest());
    }

    #[test]
    fn restore_rejects_unknown_event_tags() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Online { client: 0 });
        let mut snap = q.snapshot();
        snap.events[0].2 = 99;
        match EventQueue::restore(&snap) {
            Err(Error::Integrity(_)) => {}
            other => panic!("expected Error::Integrity, got {other:?}"),
        }
    }

    #[test]
    fn digest_is_deterministic_and_order_sensitive() {
        let run = |flip: bool| {
            let mut q = EventQueue::new();
            let (a, b) = if flip { (2, 1) } else { (1, 2) };
            q.push(1.0, EventKind::Online { client: a });
            q.push(1.0, EventKind::Online { client: b });
            while q.pop().is_some() {}
            q.trace_digest()
        };
        assert_eq!(run(false), run(false));
        assert_ne!(run(false), run(true));
    }
}
