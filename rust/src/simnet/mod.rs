//! SimNet — discrete-event federation simulator (100k+ clients).
//!
//! The original heterogeneity simulation (paper §V-A) *really sleeps*
//! proportionally to device speed ratios, which caps experiments at a
//! few hundred clients on one blocking timeline. SimNet replaces the
//! timeline with a binary-heap event queue over a virtual clock:
//!
//! * [`events`] — the event queue (virtual time, FIFO ties, trace digest);
//! * [`client_state`] — per-client lifecycle machine (offline ⇄ available
//!   → selected → training → uploading → reported/dropped) driven by
//!   seeded [`AvailabilityModel`] traces and dropout probabilities, plus
//!   the O(1) available [`Pool`];
//! * [`cost`] — compute/upload cost model composing the existing
//!   [`crate::simulation::DeviceCatalog`] speed ratios with per-client
//!   uplink bandwidth (`upload = model_bytes / bandwidth`);
//! * [`surrogate`] — trace-driven loss/accuracy curves keyed by
//!   partition label skew, so training costs nothing;
//! * [`adversary`] — Byzantine client models (sign-flip, scaled-noise,
//!   zero-update) corrupting a configurable, seed-deterministic fraction
//!   of the population's surrogate deltas, reduced through the *real*
//!   registered aggregators so robustness is measured, not assumed;
//! * [`rounds`] — the round engines: synchronous deadline rounds with
//!   over-selection, async FedBuff with staleness-discounted
//!   aggregation (both reuse the scheduler
//!   [`crate::scheduler::Strategy`] trait unchanged), and — behind
//!   `sim.engine = "gossip"` — serverless P2P gossip rounds over a
//!   [`crate::gossip::PeerGraph`] (`bytes_to_cloud == 0`, consensus
//!   distance in [`SimReport`]);
//! * [`churn`] — elastic membership: seed-deterministic between-round
//!   join/leave models extending the lifecycle machine (`"none"` burns
//!   zero RNG, keeping pre-existing digests bit-identical);
//! * [`chaos`] — fault-injection plane (server kill, edge partition,
//!   frame drops, mid-frame cuts, stalled frames, checkpoint
//!   corruption) for crash-safety testing.
//!
//! A 100k-client, 200-round scenario simulates in seconds and is
//! bit-for-bit reproducible per seed. Low-code as everything else:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.num_clients = 100_000;
//! cfg.clients_per_round = 100;
//! cfg.rounds = 200;
//! cfg.sim.dropout = 0.1;
//! let report = easyfl::simnet::simulate(&cfg).unwrap();
//! println!("makespan {:.1} h, participation {:.0}%",
//!          report.makespan_ms / 3.6e6, report.participation * 100.0);
//! ```

pub mod adversary;
pub mod chaos;
pub mod churn;
pub mod client_state;
pub mod cost;
pub mod events;
pub mod rounds;
pub mod surrogate;

pub use adversary::AdversaryModel;
pub use chaos::Fault;
pub use churn::ChurnModel;
pub use client_state::{AvailabilityModel, ClientPhase, ClientState, Pool};
pub use cost::CostModel;
pub use events::{Event, EventKind, EventQueue};
pub use rounds::{SimNet, SimReport};
pub use surrogate::SurrogateModel;

use std::sync::Arc;

use crate::config::Config;
use crate::error::Result;
use crate::registry::ComponentRegistry;

/// Run one simulation described entirely by its config.
pub fn simulate(cfg: &Config) -> Result<SimReport> {
    SimNet::from_config(cfg)?.run()
}

/// Install the built-in availability and cost models into a registry
/// (called by [`ComponentRegistry::with_builtins`]).
pub(crate) fn register_builtins(reg: &mut ComponentRegistry) {
    for name in ["always-on", "diurnal", "flaky", "trace"] {
        reg.register_availability(name, Arc::new(AvailabilityModel::parse));
    }
    reg.register_cost_model(
        "mobile-wan",
        Arc::new(|cfg| Ok(CostModel::mobile_wan().tuned(cfg))),
    );
    reg.register_cost_model("ideal", Arc::new(|cfg| Ok(CostModel::ideal().tuned(cfg))));
    reg.register_cost_model(
        "datacenter",
        Arc::new(|cfg| Ok(CostModel::datacenter().tuned(cfg))),
    );
    for name in ["sign-flip", "scaled-noise", "zero-update"] {
        reg.register_adversary(name, Arc::new(AdversaryModel::parse));
    }
    for name in ["none", "grow", "shrink", "flux"] {
        reg.register_churn(name, Arc::new(ChurnModel::parse));
    }
    for name in [
        "kill_server_at_round",
        "partition_edge",
        "drop_frames",
        "corrupt_checkpoint",
        "drop_midframe",
        "stall_frames",
    ] {
        reg.register_fault(name, Arc::new(Fault::parse));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_sim_models_resolve_through_the_registry() {
        let reg = ComponentRegistry::with_builtins();
        assert_eq!(
            reg.availability("always-on").unwrap(),
            AvailabilityModel::AlwaysOn
        );
        assert!(matches!(
            reg.availability("diurnal(0.4)").unwrap(),
            AvailabilityModel::Diurnal { .. }
        ));
        let cfg = Config::default();
        for name in ["mobile-wan", "ideal", "datacenter"] {
            assert_eq!(reg.cost_model(name, &cfg).unwrap().name, name);
        }
        assert!(reg.availability("never").is_err());
        assert!(reg.cost_model("free-lunch", &cfg).is_err());
    }
}
