//! Client cost model: virtual compute + upload times.
//!
//! Composes the existing [`DeviceCatalog`] speed ratios (paper §V-A) with
//! a [`NetworkModel`] and per-client uplink bandwidth:
//!
//! ```text
//! round time = base_compute · speed_ratio(device) · jitter
//!            + rtt/2 + model_bytes / bandwidth + net jitter
//! ```
//!
//! Cost models are registered under string names in the component
//! registry ("mobile-wan", "ideal", "datacenter"), so a config selects
//! one the same low-code way it selects an algorithm.

use crate::config::Config;
use crate::simulation::{DeviceCatalog, DeviceClass, NetworkModel};
use crate::util::rng::Rng;

/// Named cost model for one federation.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub name: String,
    /// Device tiers + sampling weights (compute heterogeneity).
    pub catalog: DeviceCatalog,
    /// Link latency/jitter on the upload path.
    pub network: NetworkModel,
    /// Local-training time of one round on the fastest tier, in ms.
    pub base_compute_ms: f64,
    /// Multiplicative log-normal compute jitter σ (0 ⇒ deterministic).
    pub compute_jitter: f64,
    /// Serialized model update size in bytes.
    pub model_bytes: usize,
    /// Uplink bandwidth range in bytes/ms, sampled log-uniformly per
    /// client. `INFINITY` ⇒ uploads cost only latency.
    pub bandwidth_lo: f64,
    pub bandwidth_hi: f64,
    /// Edge→cloud backhaul bandwidth in bytes/ms for hierarchical
    /// topologies (`INFINITY` ⇒ the partial hop costs only latency).
    /// Flat timelines never read it, so pre-hierarchy trace digests are
    /// untouched.
    pub edge_bandwidth: f64,
    /// Cloud-side ingest rate in bytes/ms for hierarchical fan-in: the
    /// serialization cost of absorbing the edges' partials at the cloud.
    /// `INFINITY` (every built-in preset) ⇒ free, so existing trace
    /// digests are untouched; tune via `sim.cloud_ingest_bytes_per_ms`.
    pub cloud_ingest_bytes_per_ms: f64,
}

impl CostModel {
    /// Mobile federation over WAN links — the paper's target scenario.
    /// 2–100 Mbit/s uplinks, AI-Benchmark device spread, 5 s base compute.
    pub fn mobile_wan() -> CostModel {
        CostModel {
            name: "mobile-wan".into(),
            catalog: DeviceCatalog::ai_benchmark(),
            network: NetworkModel::mobile(),
            base_compute_ms: 5_000.0,
            compute_jitter: 0.1,
            model_bytes: 1_600_000,
            bandwidth_lo: 250.0,     // 2 Mbit/s
            bandwidth_hi: 12_500.0,  // 100 Mbit/s
            edge_bandwidth: 125_000.0, // 1 Gbit/s metro backhaul
            cloud_ingest_bytes_per_ms: f64::INFINITY,
        }
    }

    /// No network cost, no jitter — isolates scheduling effects.
    pub fn ideal() -> CostModel {
        CostModel {
            name: "ideal".into(),
            catalog: DeviceCatalog::ai_benchmark(),
            network: NetworkModel::ideal(),
            base_compute_ms: 5_000.0,
            compute_jitter: 0.0,
            model_bytes: 1_600_000,
            bandwidth_lo: f64::INFINITY,
            bandwidth_hi: f64::INFINITY,
            edge_bandwidth: f64::INFINITY,
            cloud_ingest_bytes_per_ms: f64::INFINITY,
        }
    }

    /// Homogeneous cross-silo cluster: one device tier, 10 Gbit links.
    pub fn datacenter() -> CostModel {
        CostModel {
            name: "datacenter".into(),
            catalog: DeviceCatalog::new(vec![DeviceClass {
                name: "server",
                speed_ratio: 1.0,
                weight: 1.0,
            }]),
            network: NetworkModel {
                rtt_ms: 1.0,
                bytes_per_ms: 1_250_000.0,
                jitter_ms: 0.1,
            },
            base_compute_ms: 500.0,
            compute_jitter: 0.02,
            model_bytes: 1_600_000,
            bandwidth_lo: 1_250_000.0,
            bandwidth_hi: 1_250_000.0,
            edge_bandwidth: 1_250_000.0, // 10 Gbit rack uplink
            cloud_ingest_bytes_per_ms: f64::INFINITY,
        }
    }

    /// Apply `cfg.sim` overrides (base compute, model bytes) on top of a
    /// named model — this is how registry builders tune their output.
    pub fn tuned(mut self, cfg: &Config) -> CostModel {
        if cfg.sim.base_compute_ms > 0.0 {
            self.base_compute_ms = cfg.sim.base_compute_ms;
        }
        if cfg.sim.model_bytes > 0 {
            self.model_bytes = cfg.sim.model_bytes;
        }
        if cfg.sim.edge_bandwidth > 0.0 {
            self.edge_bandwidth = cfg.sim.edge_bandwidth;
        }
        if cfg.sim.cloud_ingest_bytes_per_ms > 0.0 {
            self.cloud_ingest_bytes_per_ms = cfg.sim.cloud_ingest_bytes_per_ms;
        }
        self
    }

    /// Sample a device tier for one client.
    pub fn sample_device(&self, rng: &mut Rng) -> usize {
        self.catalog.sample(rng)
    }

    /// Sample a per-client uplink bandwidth (bytes/ms), log-uniform in
    /// `[bandwidth_lo, bandwidth_hi]`.
    pub fn sample_bandwidth(&self, rng: &mut Rng) -> f64 {
        if !self.bandwidth_hi.is_finite() {
            return f64::INFINITY;
        }
        if self.bandwidth_hi <= self.bandwidth_lo {
            return self.bandwidth_lo;
        }
        let (lo, hi) = (self.bandwidth_lo.ln(), self.bandwidth_hi.ln());
        (lo + rng.uniform() * (hi - lo)).exp()
    }

    /// Virtual local-training time for one round on `device`.
    pub fn compute_ms(&self, device: usize, rng: &mut Rng) -> f64 {
        let base = self.base_compute_ms * self.catalog.ratio(device);
        if self.compute_jitter <= 0.0 {
            return base.max(1.0);
        }
        (base * (self.compute_jitter * rng.normal()).exp()).max(1.0)
    }

    /// Virtual upload time of one model update over `bandwidth` bytes/ms.
    pub fn upload_ms(&self, bandwidth: f64, rng: &mut Rng) -> f64 {
        self.upload_bytes_ms(self.model_bytes, bandwidth, rng)
    }

    /// Virtual upload time of `bytes` over `bandwidth` bytes/ms — the
    /// costing primitive for codec-compressed uplinks, whose wire size
    /// differs from the dense `model_bytes`. Exactly one RNG draw,
    /// identical to [`CostModel::upload_ms`] when `bytes ==
    /// model_bytes`, so unencoded trace digests are untouched.
    pub fn upload_bytes_ms(
        &self,
        bytes: usize,
        bandwidth: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.network.delay_with_bandwidth_ms(bytes, bandwidth, rng)
    }

    /// Deterministic cloud-side serialization time for absorbing `bytes`
    /// of fan-in (no RNG draw; 0 with the built-in presets' infinite
    /// ingest rate, keeping existing digests bit-identical).
    pub fn cloud_ingest_ms(&self, bytes: usize) -> f64 {
        if self.cloud_ingest_bytes_per_ms.is_finite() {
            bytes as f64 / self.cloud_ingest_bytes_per_ms
        } else {
            0.0
        }
    }

    /// Virtual time for the edge tier to push its dense partial to the
    /// cloud (hierarchical topologies only): half an RTT plus the
    /// partial's transfer over the backhaul. Edges push in parallel, so
    /// one hop is added per aggregation regardless of edge count.
    /// Deterministic — no RNG draw, so flat trace digests are invariant
    /// to every hierarchy knob.
    pub fn edge_hop_ms(&self) -> f64 {
        let transfer = if self.edge_bandwidth.is_finite() {
            self.model_bytes as f64 / self.edge_bandwidth
        } else {
            0.0
        };
        self.network.rtt_ms / 2.0 + transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn slower_devices_cost_more_compute() {
        let cm = CostModel::ideal(); // jitter-free
        let mut rng = Rng::new(1);
        let fast = cm.compute_ms(0, &mut rng);
        let slow = cm.compute_ms(cm.catalog.len() - 1, &mut rng);
        assert!(slow > 3.0 * fast, "slow={slow} fast={fast}");
        assert_eq!(fast, cm.base_compute_ms);
    }

    #[test]
    fn upload_scales_inversely_with_bandwidth() {
        let cm = CostModel::mobile_wan();
        let mut rng = Rng::new(2);
        let slow_link = cm.upload_ms(250.0, &mut rng);
        let fast_link = cm.upload_ms(12_500.0, &mut rng);
        // 1.6 MB at 250 B/ms ≈ 6400 ms of transfer alone.
        assert!(slow_link > 6_000.0, "{slow_link}");
        assert!(fast_link < slow_link / 4.0, "{fast_link} vs {slow_link}");
    }

    #[test]
    fn ideal_uploads_cost_nothing() {
        let cm = CostModel::ideal();
        let mut rng = Rng::new(3);
        let bw = cm.sample_bandwidth(&mut rng);
        assert!(bw.is_infinite());
        assert_eq!(cm.upload_ms(bw, &mut rng), 0.0);
    }

    #[test]
    fn bandwidth_samples_stay_in_range() {
        let cm = CostModel::mobile_wan();
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let bw = cm.sample_bandwidth(&mut rng);
            assert!(
                (cm.bandwidth_lo..=cm.bandwidth_hi).contains(&bw),
                "{bw}"
            );
        }
    }

    #[test]
    fn edge_hop_composes_backhaul_transfer_and_latency() {
        // 1.6 MB over the 1 Gbit backhaul = 12.8 ms, plus rtt/2.
        let hop = CostModel::mobile_wan().edge_hop_ms();
        assert!(hop > 12.8 && hop < 60.0, "{hop}");
        // Tuning the backhaul down makes the hop dominate.
        let mut cfg = Config::default();
        cfg.sim.edge_bandwidth = 1_600.0;
        let tuned = CostModel::mobile_wan().tuned(&cfg);
        assert!(tuned.edge_hop_ms() > 1_000.0, "{}", tuned.edge_hop_ms());
        // An infinite backhaul costs only latency (0 for ideal).
        assert_eq!(CostModel::ideal().edge_hop_ms(), 0.0);
    }

    #[test]
    fn upload_bytes_scales_with_the_encoded_size() {
        let cm = CostModel::datacenter(); // tight jitter
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        // bytes == model_bytes reproduces upload_ms draw-for-draw.
        let a = cm.upload_ms(1_250_000.0, &mut r1);
        let b = cm.upload_bytes_ms(cm.model_bytes, 1_250_000.0, &mut r2);
        assert_eq!(a, b);
        // A 16x smaller payload transfers ~16x faster (minus latency).
        let mut r3 = Rng::new(11);
        let small = cm.upload_bytes_ms(cm.model_bytes / 16, 1_250.0, &mut r3);
        let mut r4 = Rng::new(11);
        let full = cm.upload_bytes_ms(cm.model_bytes, 1_250.0, &mut r4);
        assert!(small < full / 8.0, "{small} vs {full}");
    }

    #[test]
    fn cloud_ingest_defaults_free_and_tunes_finite() {
        let cm = CostModel::mobile_wan();
        assert_eq!(cm.cloud_ingest_ms(1_600_000), 0.0, "presets are free");
        let mut cfg = Config::default();
        cfg.sim.cloud_ingest_bytes_per_ms = 1_000.0;
        let tuned = CostModel::mobile_wan().tuned(&cfg);
        assert_eq!(tuned.cloud_ingest_ms(5_000), 5.0);
        // Zero keeps the preset default (infinite ⇒ free).
        let kept = CostModel::mobile_wan().tuned(&Config::default());
        assert!(kept.cloud_ingest_bytes_per_ms.is_infinite());
    }

    #[test]
    fn config_overrides_tune_named_models() {
        let mut cfg = Config::default();
        cfg.sim.base_compute_ms = 123.0;
        cfg.sim.model_bytes = 42;
        let cm = CostModel::mobile_wan().tuned(&cfg);
        assert_eq!(cm.base_compute_ms, 123.0);
        assert_eq!(cm.model_bytes, 42);
        // Zero means "keep the model's default".
        let cm2 = CostModel::mobile_wan().tuned(&Config::default());
        assert_eq!(cm2.base_compute_ms, 5_000.0);
    }
}
