//! Network-condition model for the remote path (paper §V-A, §VII).
//!
//! The paper simulates networking conditions (latency) "with an isolated
//! environment provided by containerization"; our process-container
//! deployment injects the same delays in the transport layer instead.
//! The model is latency + bandwidth: `delay = rtt/2 + bytes / bandwidth`.

use crate::util::rng::Rng;

/// Per-link network model.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Round-trip latency in ms.
    pub rtt_ms: f64,
    /// Bandwidth in bytes/ms (e.g. 12_500 = 100 Mbit/s).
    pub bytes_per_ms: f64,
    /// Latency jitter σ (ms), sampled per message.
    pub jitter_ms: f64,
}

impl NetworkModel {
    /// An ideal link: no injected delay.
    pub fn ideal() -> NetworkModel {
        NetworkModel { rtt_ms: 0.0, bytes_per_ms: f64::INFINITY, jitter_ms: 0.0 }
    }

    /// Typical WAN edge link: 40 ms RTT, 50 Mbit/s, 5 ms jitter.
    pub fn wan() -> NetworkModel {
        NetworkModel { rtt_ms: 40.0, bytes_per_ms: 6_250.0, jitter_ms: 5.0 }
    }

    /// Mobile uplink (LTE-ish): 60 ms RTT, 20 Mbit/s shared medium,
    /// 10 ms jitter. SimNet's cost models override the bandwidth
    /// per-client; this profile supplies latency and jitter.
    pub fn mobile() -> NetworkModel {
        NetworkModel { rtt_ms: 60.0, bytes_per_ms: 2_500.0, jitter_ms: 10.0 }
    }

    /// One-way delivery delay for a message of `bytes`.
    pub fn delay_ms(&self, bytes: usize, rng: &mut Rng) -> f64 {
        self.delay_with_bandwidth_ms(bytes, self.bytes_per_ms, rng)
    }

    /// One-way delay with an explicit link bandwidth (bytes/ms) in place
    /// of the model's own — SimNet samples bandwidth per client.
    pub fn delay_with_bandwidth_ms(
        &self,
        bytes: usize,
        bytes_per_ms: f64,
        rng: &mut Rng,
    ) -> f64 {
        let transfer = if bytes_per_ms.is_finite() && bytes_per_ms > 0.0 {
            bytes as f64 / bytes_per_ms
        } else {
            0.0
        };
        let jitter = if self.jitter_ms > 0.0 {
            (rng.normal() * self.jitter_ms).abs()
        } else {
            0.0
        };
        self.rtt_ms / 2.0 + transfer + jitter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_zero() {
        let mut rng = Rng::new(1);
        assert_eq!(NetworkModel::ideal().delay_ms(1 << 20, &mut rng), 0.0);
    }

    #[test]
    fn wan_delay_scales_with_size() {
        let mut rng = Rng::new(2);
        let nm = NetworkModel::wan();
        let small = nm.delay_ms(1_000, &mut rng);
        let big = nm.delay_ms(10_000_000, &mut rng);
        assert!(big > small + 1_000.0, "big={big} small={small}");
        assert!(small >= 20.0); // at least half the RTT
    }

    #[test]
    fn explicit_bandwidth_overrides_the_link() {
        let mut rng = Rng::new(3);
        let nm = NetworkModel { rtt_ms: 10.0, bytes_per_ms: 1e9, jitter_ms: 0.0 };
        // 1 MB at 100 bytes/ms = 10_000 ms of transfer + 5 ms latency.
        let d = nm.delay_with_bandwidth_ms(1_000_000, 100.0, &mut rng);
        assert!((d - 10_005.0).abs() < 1e-6, "{d}");
        // Infinite bandwidth leaves only latency.
        let d0 = nm.delay_with_bandwidth_ms(1_000_000, f64::INFINITY, &mut rng);
        assert_eq!(d0, 5.0);
    }
}
