//! Network-condition model for the remote path (paper §V-A, §VII).
//!
//! The paper simulates networking conditions (latency) "with an isolated
//! environment provided by containerization"; our process-container
//! deployment injects the same delays in the transport layer instead.
//! The model is latency + bandwidth: `delay = rtt/2 + bytes / bandwidth`.

use crate::util::rng::Rng;

/// Per-link network model.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Round-trip latency in ms.
    pub rtt_ms: f64,
    /// Bandwidth in bytes/ms (e.g. 12_500 = 100 Mbit/s).
    pub bytes_per_ms: f64,
    /// Latency jitter σ (ms), sampled per message.
    pub jitter_ms: f64,
}

impl NetworkModel {
    /// An ideal link: no injected delay.
    pub fn ideal() -> NetworkModel {
        NetworkModel { rtt_ms: 0.0, bytes_per_ms: f64::INFINITY, jitter_ms: 0.0 }
    }

    /// Typical WAN edge link: 40 ms RTT, 50 Mbit/s, 5 ms jitter.
    pub fn wan() -> NetworkModel {
        NetworkModel { rtt_ms: 40.0, bytes_per_ms: 6_250.0, jitter_ms: 5.0 }
    }

    /// One-way delivery delay for a message of `bytes`.
    pub fn delay_ms(&self, bytes: usize, rng: &mut Rng) -> f64 {
        let transfer = if self.bytes_per_ms.is_finite() {
            bytes as f64 / self.bytes_per_ms
        } else {
            0.0
        };
        let jitter = if self.jitter_ms > 0.0 {
            (rng.normal() * self.jitter_ms).abs()
        } else {
            0.0
        };
        self.rtt_ms / 2.0 + transfer + jitter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_zero() {
        let mut rng = Rng::new(1);
        assert_eq!(NetworkModel::ideal().delay_ms(1 << 20, &mut rng), 0.0);
    }

    #[test]
    fn wan_delay_scales_with_size() {
        let mut rng = Rng::new(2);
        let nm = NetworkModel::wan();
        let small = nm.delay_ms(1_000, &mut rng);
        let big = nm.delay_ms(10_000_000, &mut rng);
        assert!(big > small + 1_000.0, "big={big} small={small}");
        assert!(small >= 20.0); // at least half the RTT
    }
}
