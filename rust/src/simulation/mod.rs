//! Simulation manager (paper §V-A): system heterogeneity.
//!
//! System heterogeneity is simulated "in a lightweight and realistic
//! manner": each client is assigned a mobile-device class with a training
//! speed ratio derived from AI-Benchmark-style measurements; after real
//! compute finishes, the client waits proportionally to its ratio before
//! uploading — exactly the paper's straggler model. Network conditions add
//! latency on the remote path.

pub mod devices;
pub mod network;

pub use devices::{DeviceCatalog, DeviceClass};
pub use network::NetworkModel;

use crate::config::Config;
use crate::util::rng::Rng;

/// Per-client simulation state the coordinator consults each round.
#[derive(Debug, Clone)]
pub struct HeterogeneityPlan {
    /// Device class index per client (empty ⇒ no system heterogeneity).
    pub device_of_client: Vec<usize>,
    pub catalog: DeviceCatalog,
    pub enabled: bool,
}

impl HeterogeneityPlan {
    /// Assign device classes to all clients per the config. When system
    /// heterogeneity is off no sampling happens at all — a disabled plan
    /// must not consume randomness, so toggling the flag can never shift
    /// unrelated seeded draws elsewhere in the run.
    pub fn from_config(cfg: &Config, num_clients: usize) -> HeterogeneityPlan {
        let catalog = DeviceCatalog::ai_benchmark();
        let device_of_client = if cfg.system_heterogeneity {
            let mut rng = Rng::new(cfg.seed ^ 0x5157_4E55);
            (0..num_clients).map(|_| catalog.sample(&mut rng)).collect()
        } else {
            Vec::new()
        };
        HeterogeneityPlan {
            device_of_client,
            catalog,
            enabled: cfg.system_heterogeneity,
        }
    }

    /// Speed ratio for a client (1.0 = fastest class or disabled).
    pub fn speed_ratio(&self, client: usize) -> f64 {
        if !self.enabled {
            return 1.0;
        }
        self.catalog.ratio(self.device_of_client[client])
    }

    /// Straggler wait to inject after `compute_ms` of real training.
    ///
    /// Total simulated time = compute · ratio, so the wait is
    /// compute · (ratio − 1).
    pub fn wait_ms(&self, client: usize, compute_ms: f64) -> f64 {
        (self.speed_ratio(client) - 1.0).max(0.0) * compute_ms
    }

    /// Device class name for tracking ("uniform" when disabled).
    pub fn device_name(&self, client: usize) -> &'static str {
        if !self.enabled || self.device_of_client.is_empty() {
            return "uniform";
        }
        self.catalog.name(self.device_of_client[client])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn disabled_plan_is_homogeneous() {
        let cfg = Config { system_heterogeneity: false, ..Config::default() };
        let plan = HeterogeneityPlan::from_config(&cfg, 10);
        assert!((0..10).all(|c| plan.speed_ratio(c) == 1.0));
        assert_eq!(plan.wait_ms(3, 100.0), 0.0);
        assert_eq!(plan.device_name(3), "uniform");
    }

    #[test]
    fn disabled_plan_skips_sampling_and_is_seed_stable() {
        // Regression: a disabled plan used to sample device classes
        // anyway, advancing its RNG and coupling unrelated seeds. With
        // heterogeneity off, the assignment must be empty and identical
        // across *different* seeds.
        let mk = |seed| {
            let cfg = Config {
                system_heterogeneity: false,
                seed,
                ..Config::default()
            };
            HeterogeneityPlan::from_config(&cfg, 100)
        };
        let a = mk(1);
        let b = mk(999);
        assert!(a.device_of_client.is_empty());
        assert_eq!(a.device_of_client, b.device_of_client);
        assert!((0..100).all(|c| a.speed_ratio(c) == b.speed_ratio(c)));
    }

    #[test]
    fn enabled_plan_creates_stragglers() {
        let cfg = Config {
            system_heterogeneity: true,
            seed: 7,
            ..Config::default()
        };
        let plan = HeterogeneityPlan::from_config(&cfg, 200);
        let ratios: Vec<f64> = (0..200).map(|c| plan.speed_ratio(c)).collect();
        let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        assert_eq!(min, 1.0, "fastest class is the unit");
        assert!(max >= 3.0, "must include slow devices, max={max}");
        // Wait scales with compute and ratio.
        let c_slow = (0..200).max_by(|&a, &b| {
            plan.speed_ratio(a).partial_cmp(&plan.speed_ratio(b)).unwrap()
        }).unwrap();
        assert!(plan.wait_ms(c_slow, 100.0) > 100.0);
    }

    #[test]
    fn assignment_is_deterministic_per_seed() {
        let cfg = Config { system_heterogeneity: true, seed: 3, ..Config::default() };
        let a = HeterogeneityPlan::from_config(&cfg, 50);
        let b = HeterogeneityPlan::from_config(&cfg, 50);
        assert_eq!(a.device_of_client, b.device_of_client);
    }
}
