//! Simulation manager (paper §V-A): system heterogeneity.
//!
//! System heterogeneity is simulated "in a lightweight and realistic
//! manner": each client is assigned a mobile-device class with a training
//! speed ratio derived from AI-Benchmark-style measurements; after real
//! compute finishes, the client waits proportionally to its ratio before
//! uploading — exactly the paper's straggler model. Network conditions add
//! latency on the remote path.

pub mod devices;
pub mod network;

pub use devices::{DeviceCatalog, DeviceClass};
pub use network::NetworkModel;

use crate::config::Config;
use crate::util::rng::Rng;

/// Per-client simulation state the coordinator consults each round.
#[derive(Debug, Clone)]
pub struct HeterogeneityPlan {
    /// Device class index per client (empty ⇒ no system heterogeneity).
    pub device_of_client: Vec<usize>,
    pub catalog: DeviceCatalog,
    pub enabled: bool,
}

impl HeterogeneityPlan {
    /// Assign device classes to all clients per the config.
    pub fn from_config(cfg: &Config, num_clients: usize) -> HeterogeneityPlan {
        let catalog = DeviceCatalog::ai_benchmark();
        let mut rng = Rng::new(cfg.seed ^ 0x5157_4E55);
        let device_of_client = (0..num_clients)
            .map(|_| catalog.sample(&mut rng))
            .collect();
        HeterogeneityPlan {
            device_of_client,
            catalog,
            enabled: cfg.system_heterogeneity,
        }
    }

    /// Speed ratio for a client (1.0 = fastest class or disabled).
    pub fn speed_ratio(&self, client: usize) -> f64 {
        if !self.enabled {
            return 1.0;
        }
        self.catalog.ratio(self.device_of_client[client])
    }

    /// Straggler wait to inject after `compute_ms` of real training.
    ///
    /// Total simulated time = compute · ratio, so the wait is
    /// compute · (ratio − 1).
    pub fn wait_ms(&self, client: usize, compute_ms: f64) -> f64 {
        (self.speed_ratio(client) - 1.0).max(0.0) * compute_ms
    }

    /// Device class name for tracking.
    pub fn device_name(&self, client: usize) -> &'static str {
        self.catalog.name(self.device_of_client[client])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn disabled_plan_is_homogeneous() {
        let cfg = Config { system_heterogeneity: false, ..Config::default() };
        let plan = HeterogeneityPlan::from_config(&cfg, 10);
        assert!((0..10).all(|c| plan.speed_ratio(c) == 1.0));
        assert_eq!(plan.wait_ms(3, 100.0), 0.0);
    }

    #[test]
    fn enabled_plan_creates_stragglers() {
        let cfg = Config {
            system_heterogeneity: true,
            seed: 7,
            ..Config::default()
        };
        let plan = HeterogeneityPlan::from_config(&cfg, 200);
        let ratios: Vec<f64> = (0..200).map(|c| plan.speed_ratio(c)).collect();
        let min = ratios.iter().cloned().fold(f64::MAX, f64::min);
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        assert_eq!(min, 1.0, "fastest class is the unit");
        assert!(max >= 3.0, "must include slow devices, max={max}");
        // Wait scales with compute and ratio.
        let c_slow = (0..200).max_by(|&a, &b| {
            plan.speed_ratio(a).partial_cmp(&plan.speed_ratio(b)).unwrap()
        }).unwrap();
        assert!(plan.wait_ms(c_slow, 100.0) > 100.0);
    }

    #[test]
    fn assignment_is_deterministic_per_seed() {
        let cfg = Config { system_heterogeneity: true, seed: 3, ..Config::default() };
        let a = HeterogeneityPlan::from_config(&cfg, 50);
        let b = HeterogeneityPlan::from_config(&cfg, 50);
        assert_eq!(a.device_of_client, b.device_of_client);
    }
}
