//! Mobile-device speed catalog (AI-Benchmark-style, paper §V-A).
//!
//! The paper derives speed ratios of mobile SoCs from AI-Benchmark
//! (Ignatov et al., ECCV'18). The catalog below mirrors the *spread* of
//! float-training scores across device tiers — flagship ≈ 1×, mid-tier
//! 1.5–2.5×, entry 4–6× slower — with market-share-shaped sampling
//! weights. Exact per-SoC numbers are irrelevant to the experiments; the
//! straggler spread is what Fig 6(b) exercises.

use crate::util::rng::Rng;

/// One device tier.
#[derive(Debug, Clone)]
pub struct DeviceClass {
    pub name: &'static str,
    /// Training time ratio relative to the fastest tier.
    pub speed_ratio: f64,
    /// Sampling weight (population share).
    pub weight: f64,
}

/// A weighted catalog of device tiers.
#[derive(Debug, Clone)]
pub struct DeviceCatalog {
    classes: Vec<DeviceClass>,
    cumulative: Vec<f64>,
}

impl DeviceCatalog {
    pub fn new(classes: Vec<DeviceClass>) -> DeviceCatalog {
        assert!(!classes.is_empty());
        let total: f64 = classes.iter().map(|c| c.weight).sum();
        let mut acc = 0.0;
        let cumulative = classes
            .iter()
            .map(|c| {
                acc += c.weight / total;
                acc
            })
            .collect();
        DeviceCatalog { classes, cumulative }
    }

    /// The default AI-Benchmark-shaped catalog.
    pub fn ai_benchmark() -> DeviceCatalog {
        DeviceCatalog::new(vec![
            DeviceClass { name: "flagship-npu", speed_ratio: 1.0, weight: 0.15 },
            DeviceClass { name: "flagship", speed_ratio: 1.3, weight: 0.20 },
            DeviceClass { name: "upper-mid", speed_ratio: 1.8, weight: 0.25 },
            DeviceClass { name: "mid", speed_ratio: 2.5, weight: 0.20 },
            DeviceClass { name: "entry", speed_ratio: 4.0, weight: 0.15 },
            DeviceClass { name: "legacy", speed_ratio: 6.0, weight: 0.05 },
        ])
    }

    /// Sample a device class index by population weight.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.classes.len() - 1)
    }

    pub fn ratio(&self, class: usize) -> f64 {
        self.classes[class].speed_ratio
    }

    pub fn name(&self, class: usize) -> &'static str {
        self.classes[class].name
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_follows_weights() {
        let cat = DeviceCatalog::ai_benchmark();
        let mut rng = Rng::new(11);
        let mut counts = vec![0usize; cat.len()];
        let n = 50_000;
        for _ in 0..n {
            counts[cat.sample(&mut rng)] += 1;
        }
        // flagship-npu ≈ 15%, legacy ≈ 5%.
        let share0 = counts[0] as f64 / n as f64;
        let share5 = counts[5] as f64 / n as f64;
        assert!((share0 - 0.15).abs() < 0.01, "{share0}");
        assert!((share5 - 0.05).abs() < 0.01, "{share5}");
    }

    #[test]
    fn ratios_monotone_from_flagship_to_legacy() {
        let cat = DeviceCatalog::ai_benchmark();
        for i in 1..cat.len() {
            assert!(cat.ratio(i) > cat.ratio(i - 1));
        }
        assert_eq!(cat.ratio(0), 1.0);
    }
}
