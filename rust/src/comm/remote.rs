//! Remote training (paper §VII): client service + remote coordinator.
//!
//! `start_server` / `start_client` (Table II) land here. The client
//! service wraps the same [`crate::flow::ClientFlow`] stages the local
//! pool runs — the training flow is decoupled from the communication
//! channel, so switching local ↔ remote changes nothing else.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::aggregate::AggContext;
use crate::comm::protocol::Message;
use crate::comm::reactor::{self, MetricsServer};
use crate::comm::registry::Registor;
use crate::comm::rpc::{Handler, RpcServer};
use crate::config::Config;
use crate::coordinator::ClientFlowFactory;
use crate::data::registry::DataSource;
use crate::data::FedDataset;
use crate::error::{Error, Result};
use crate::flow::{run_client_round, ModelPayload, ServerFlow, TrainTask};
use crate::hierarchy::{HierPlane, Topology};
use crate::model::ParamVec;
use crate::obs::{Histogram, Telemetry};
use crate::runtime::Engine;
use crate::tracking::{ClientMetrics, RoundMetrics, Tracker};
use crate::util::clock::{RealClock, Stopwatch};
use crate::util::rng::Rng;

// ---------------------------------------------------------------- client

type Job = (Message, Sender<Message>);

/// A client node: RPC front, single engine-owning worker behind a queue.
pub struct ClientService {
    rpc: RpcServer,
    _registor: Option<Registor>,
}

impl ClientService {
    /// Start serving. `bind` may use port 0; if `registry` is given, a
    /// registor announces `client-<index>` with the bound address.
    pub fn start(
        cfg: &Config,
        client_index: usize,
        bind: &str,
        registry: Option<&str>,
        flow_factory: ClientFlowFactory,
    ) -> Result<ClientService> {
        let mut cfg = cfg.clone();
        cfg.model = cfg.resolved_model();
        let data: Arc<dyn DataSource> = Arc::new(FedDataset::from_config(&cfg)?);
        let (tx, rx) = channel::<Job>();

        // The engine-owning worker (PjRtClient is !Send, so it lives here).
        std::thread::Builder::new()
            .name(format!("easyfl-client-{client_index}"))
            .spawn(move || {
                let engine = Engine::new(&cfg.artifacts_dir);
                let mut flow = flow_factory();
                while let Ok((msg, reply)) = rx.recv() {
                    let out = match &engine {
                        Err(e) => Message::Err { msg: format!("engine: {e}") },
                        Ok(engine) => {
                            handle_client_msg(engine, flow.as_mut(), &cfg, data.as_ref(), msg)
                        }
                    };
                    let _ = reply.send(out);
                }
            })
            .map_err(|e| Error::Comm(format!("spawn client worker: {e}")))?;

        let tx = Arc::new(std::sync::Mutex::new(tx));
        let handler: Arc<dyn Handler> = Arc::new(move |msg: Message| {
            if matches!(msg, Message::Ping) {
                return Message::Pong;
            }
            let (rtx, rrx) = channel();
            if tx.lock().unwrap().send((msg, rtx)).is_err() {
                return Message::Err { msg: "client worker dead".into() };
            }
            rrx.recv()
                .unwrap_or(Message::Err { msg: "client worker dropped".into() })
        });
        let rpc = RpcServer::serve(bind, handler)?;
        let registor = match registry {
            Some(reg) => Some(Registor::start(
                reg,
                &format!("client-{client_index}"),
                rpc.addr(),
                Duration::from_secs(2),
            )?),
            None => None,
        };
        Ok(ClientService { rpc, _registor: registor })
    }

    pub fn addr(&self) -> &str {
        self.rpc.addr()
    }
}

fn handle_client_msg(
    engine: &Engine,
    flow: &mut dyn crate::flow::ClientFlow,
    cfg: &Config,
    data: &dyn DataSource,
    msg: Message,
) -> Message {
    match msg {
        Message::TrainRequest {
            round,
            client_index,
            model,
            lr,
            local_epochs,
            batch_size,
            data_amount,
            seed,
            params,
        } => {
            let run = || -> Result<Message> {
                let sw = Stopwatch::start();
                let local = Arc::new(
                    data.client_data(client_index as usize, data_amount as f64)?,
                );
                let task = TrainTask {
                    client: client_index as usize,
                    round: round as usize,
                    model,
                    payload: ModelPayload {
                        params: Arc::new(params),
                        wire_bytes: 0,
                        round: round as usize,
                    },
                    data: local,
                    lr,
                    local_epochs: local_epochs as usize,
                    batch_size: batch_size as usize,
                    seed,
                };
                let (update, stats) = run_client_round(flow, engine, &task)?;
                Ok(Message::TrainReply {
                    round,
                    client_index,
                    num_samples: stats.num_samples as u32,
                    sum_loss: stats.sum_loss,
                    correct: stats.correct,
                    compute_ms: sw.elapsed_ms(),
                    update,
                })
            };
            run().unwrap_or_else(|e| Message::Err { msg: e.to_string() })
        }
        Message::EvalRequest { model, params } => {
            let run = || -> Result<Message> {
                let local = data.test_data(cfg.test_samples)?;
                let mut sum_loss = 0.0;
                let mut correct = 0.0;
                let mut n = 0.0f64;
                for b in local.batches(cfg.batch_size) {
                    let (l, c) = engine.eval_step(&model, &params, &b)?;
                    sum_loss += l;
                    correct += c;
                    n += b.mask.iter().sum::<f32>() as f64;
                }
                Ok(Message::EvalReply {
                    sum_loss,
                    correct,
                    num_samples: n as u32,
                })
            };
            run().unwrap_or_else(|e| Message::Err { msg: e.to_string() })
        }
        other => Message::Err { msg: format!("client: unsupported {other:?}") },
    }
}

// ---------------------------------------------------------------- server

/// Bound on the gather queue between the ingest (reactor or receiver
/// threads) and the aggregating consumer. Deep enough to ride out decode
/// hiccups, small enough that a stalled aggregator parks the ingest
/// within a few hundred frames instead of buffering the cohort.
const INGEST_QUEUE_CAP: usize = 512;

/// The production-phase coordinator: discovers clients via the registry
/// and drives scatter/gather rounds over RPC.
pub struct RemoteCoordinator {
    pub cfg: Config,
    engine: Engine,
    flow: Box<dyn ServerFlow>,
    tracker: Arc<Tracker>,
    params: Arc<ParamVec>,
    rng: Rng,
    /// (client_index, addr) discovered from the registry.
    clients: Vec<(usize, String)>,
    /// Aggregation-tree shape: non-flat deployments shard the ingest by
    /// edge — each reply is tagged with its cluster id and reduced on
    /// that edge's aggregator before the cloud fold.
    topology: Topology,
    test_batches: Vec<crate::runtime::Batch>,
    /// Ingest observability: per-reply arrival latency is the histogram
    /// the paper's Fig 8 deadline analysis wants, not the round average.
    tel: Telemetry,
    /// Live `/metrics` endpoint (see [`RemoteCoordinator::serve_metrics`]).
    metrics_server: Option<MetricsServer>,
}

impl RemoteCoordinator {
    pub fn new(
        cfg: Config,
        flow: Box<dyn ServerFlow>,
        tracker: Arc<Tracker>,
    ) -> Result<RemoteCoordinator> {
        let mut cfg = cfg;
        cfg.model = cfg.resolved_model();
        cfg.validate()?;
        let engine = Engine::new(&cfg.artifacts_dir)?;
        let params = Arc::new(engine.init_params(&cfg.model)?);
        let topology =
            crate::registry::with_global(|r| r.topology(&cfg.topology))?;
        let data = FedDataset::from_config(&cfg)?;
        let test_batches = data.materialize_test(cfg.test_samples).batches(cfg.batch_size);
        let rng = Rng::new(cfg.seed ^ 0x5E17_EC70);
        let tel = Telemetry::from_config(&cfg, Arc::new(RealClock::default()))?;
        tracker.set_telemetry(tel.clone());
        Ok(RemoteCoordinator {
            cfg,
            engine,
            flow,
            tracker,
            params,
            rng,
            clients: Vec::new(),
            topology,
            test_batches,
            tel,
            metrics_server: None,
        })
    }

    /// Serve the live metrics snapshot at `bind` (port 0 allowed):
    /// a [`Message::MetricsRequest`] over the framed RPC protocol gets
    /// the current [`crate::obs::MetricsRegistry`] snapshot as JSON —
    /// mid-run visibility, complementing the end-of-run `metrics_out`
    /// file. With telemetry off the endpoint serves `null`. Returns the
    /// bound address; the endpoint lives until the coordinator drops.
    pub fn serve_metrics(&mut self, bind: &str) -> Result<String> {
        let server = MetricsServer::serve(bind, self.tel.clone())?;
        let addr = server.addr().to_string();
        self.metrics_server = Some(server);
        Ok(addr)
    }

    /// Query the registry; returns the number of live clients.
    pub fn discover(&mut self, registry_addr: &str) -> Result<usize> {
        let entries = crate::comm::registry::discover(registry_addr)?;
        self.clients = entries
            .iter()
            .filter_map(|(id, addr)| {
                id.strip_prefix("client-")
                    .and_then(|n| n.parse().ok())
                    .map(|idx| (idx, addr.clone()))
            })
            .collect();
        self.clients.sort();
        Ok(self.clients.len())
    }

    /// Use an explicit address list (no registry).
    pub fn set_clients(&mut self, clients: Vec<(usize, String)>) {
        self.clients = clients;
    }

    pub fn params(&self) -> &ParamVec {
        &self.params
    }

    pub fn tracker(&self) -> Arc<Tracker> {
        self.tracker.clone()
    }

    /// One remote round. Returns the round metrics (distribution latency
    /// included — the Fig 8 measurement).
    pub fn run_round(&mut self, round: usize) -> Result<RoundMetrics> {
        if self.clients.is_empty() {
            return Err(Error::Comm("no clients discovered".into()));
        }
        let k = self.cfg.clients_per_round.min(self.clients.len());
        let picked = self.rng.choose_indices(self.clients.len(), k);
        let cohort: Vec<(usize, String)> = picked
            .iter()
            .map(|&i| self.clients[i].clone())
            .collect();
        let _round_span = self
            .tel
            .span_with("remote.round", || vec![("round", round.to_string())]);

        // Scatter (distribution stage): connect + send to every client on
        // a fixed worker pool — the paper's §VIII-E multi-threaded
        // distribution without a thread per client.
        let scatter_span = self
            .tel
            .span_with("remote.scatter", || vec![("cohort", cohort.len().to_string())]);
        let sw_dist = Stopwatch::start();
        let tasks: Vec<(usize, String, Message)> = cohort
            .iter()
            .map(|(client_index, addr)| {
                let msg = Message::TrainRequest {
                    round: round as u32,
                    client_index: *client_index as u32,
                    model: self.cfg.model.clone(),
                    lr: self.cfg.lr as f32,
                    local_epochs: self.cfg.local_epochs as u32,
                    batch_size: self.cfg.batch_size as u32,
                    data_amount: self.cfg.data_amount as f32,
                    seed: self.cfg.seed
                        ^ ((round as u64) << 32)
                        ^ *client_index as u64,
                    // The wire needs an owned copy per connection; the
                    // shared Arc is untouched.
                    params: (*self.params).clone(),
                };
                (*client_index, addr.clone(), msg)
            })
            .collect();
        let mut conns = Vec::with_capacity(cohort.len());
        for (client_index, result) in
            reactor::scatter(tasks, reactor::default_workers())
        {
            conns.push((client_index, result?));
        }
        let distribution_ms = sw_dist.elapsed_ms();
        self.tel.observe_ms("remote.distribution_ms", distribution_ms);
        drop(scatter_span);
        let downlink = self.params.len() * 4 * cohort.len();

        // Gather: all pending replies multiplexed on the nonblocking
        // reactor (`Config.ingest = "reactor"`, the default) or on the
        // legacy thread-per-connection pool (`"threads"`, kept as the
        // equivalence baseline). Either way each reply streams through a
        // *bounded* queue into the round's accumulator the moment it
        // arrives — the server never buffers the cohort's updates, and a
        // stalled aggregator parks the ingest instead of growing a queue.
        let gather_span = self.tel.span("remote.gather");
        let sw_round = Stopwatch::start();
        let ingest = match self.cfg.ingest.as_str() {
            "threads" => reactor::gather_threads(conns, INGEST_QUEUE_CAP),
            _ => reactor::gather_reactor(
                conns,
                reactor::default_workers(),
                INGEST_QUEUE_CAP,
            ),
        };
        let ctx = AggContext::from_config(self.params.clone(), &self.cfg)
            .expect_updates(cohort.len())
            .telemetry(self.tel.clone());
        let cohort_ids: Vec<usize> = cohort.iter().map(|(i, _)| *i).collect();
        let mut plane = HierPlane::from_flow(
            self.flow.as_mut(),
            &self.engine,
            &self.cfg.model,
            &self.topology,
            ctx,
            &cohort_ids,
        )?;
        let mut uplink = 0usize;
        let mut clients_m = Vec::new();
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut total_n = 0.0;
        // Always-on arrival histogram: the p99 is what the §VIII-E
        // deadline discussion actually needs, and it is too cheap to gate.
        let mut arrivals = Histogram::default();
        for _ in 0..cohort.len() {
            let (idx, reply) = ingest
                .recv()
                .ok_or_else(|| Error::Comm("ingest queue closed".into()))?;
            let arrival_ms = sw_round.elapsed_ms();
            arrivals.record_ms(arrival_ms);
            self.tel.observe_ms("remote.ingest_ms", arrival_ms);
            // Per-client span, thinned by `Config.trace_sample` (keyed on
            // the client id — a pure hash, so sampling never perturbs
            // run determinism). Metrics above stay unconditional.
            let _client_span = self
                .tel
                .span_sampled_with("remote.ingest_client", idx as u64, || {
                    vec![("client", idx.to_string())]
                });
            match reply? {
                Message::TrainReply {
                    num_samples: n,
                    sum_loss,
                    correct,
                    compute_ms,
                    update,
                    ..
                } => {
                    uplink += update.wire_bytes();
                    let sw_decode = Stopwatch::start();
                    let decoded = self.flow.decode_update(&update)?;
                    self.tel.observe_ms("codec.decode_ms", sw_decode.elapsed_ms());
                    plane.add(idx, decoded.as_ref(), n as f64)?;
                    total_loss += sum_loss;
                    total_correct += correct;
                    total_n += n as f64;
                    // Hierarchical deployments tag each reply with its
                    // shard: the edge it was reduced on.
                    let device = if self.topology.is_flat() {
                        "remote".to_string()
                    } else {
                        format!("edge-{}", self.topology.cluster_of(idx))
                    };
                    clients_m.push(ClientMetrics {
                        client: idx,
                        num_samples: n as usize,
                        train_loss: sum_loss / (n as f64).max(1.0),
                        train_accuracy: correct / (n as f64).max(1.0),
                        compute_ms,
                        wait_ms: 0.0,
                        round_ms: compute_ms,
                        upload_bytes: 0,
                        device,
                    });
                }
                Message::Err { msg } => {
                    return Err(Error::Comm(format!("client {idx}: {msg}")))
                }
                other => {
                    return Err(Error::Comm(format!("client {idx}: bad reply {other:?}")))
                }
            }
        }
        // The queue bound held for the whole round; surface the high
        // water mark so operators can size `INGEST_QUEUE_CAP` pressure.
        self.tel.counter("remote.ingest_queue_hwm", ingest.max_depth() as u64);
        drop(ingest); // joins the reactor / receiver threads
        let round_ms = sw_round.elapsed_ms();
        drop(gather_span);

        let agg_span = self.tel.span("remote.aggregate");
        let sw_agg = Stopwatch::start();
        let (new_params, hier) = plane.finish()?;
        self.tel.observe_ms("remote.aggregate_ms", sw_agg.elapsed_ms());
        drop(agg_span);
        if !new_params.is_finite() {
            return Err(Error::Runtime("remote round diverged".into()));
        }
        self.params = Arc::new(new_params);

        let (test_loss, test_accuracy) = if self.cfg.eval_every > 0
            && (round + 1) % self.cfg.eval_every == 0
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        let (client_ms_p50, client_ms_p95, client_ms_p99) = arrivals.quantiles_ms();
        let metrics = RoundMetrics {
            round,
            train_loss: total_loss / total_n.max(1.0),
            train_accuracy: total_correct / total_n.max(1.0),
            test_loss,
            test_accuracy,
            round_ms,
            distribution_ms,
            comm_bytes: downlink + uplink,
            bytes_to_cloud: if hier.tiered {
                hier.bytes_to_cloud
            } else {
                uplink
            },
            // Remote rounds wait for every reply: full participation.
            selected: clients_m.len(),
            reported: clients_m.len(),
            clients: clients_m,
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
            ..RoundMetrics::default()
        };
        self.tracker.record_round(metrics.clone());
        Ok(metrics)
    }

    /// Train all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for round in 0..self.cfg.rounds {
            self.run_round(round)?;
        }
        self.tel.flush()?;
        Ok(())
    }

    /// Evaluate the global model on the server-side IID test split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let mut sum_loss = 0.0;
        let mut correct = 0.0;
        let mut n = 0.0;
        for b in &self.test_batches {
            let (l, c) = self.engine.eval_step(&self.cfg.model, &self.params, b)?;
            sum_loss += l;
            correct += c;
            n += b.mask.iter().sum::<f32>() as f64;
        }
        Ok((sum_loss / n.max(1.0), correct / n.max(1.0)))
    }
}
