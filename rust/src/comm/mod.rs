//! Remote communication (paper §VII, Fig 4a): the three-tier
//! Protocol / RPC / Handler stack that supports the production phase.
//!
//! gRPC + protobuf are substituted by a hand-rolled length-prefixed binary
//! protocol over TCP with thread-per-connection servers (DESIGN.md
//! substitution #4) — same architecture, zero external dependencies.
//! Training flow and communication are decoupled exactly as in §V-B: the
//! remote path reuses [`crate::client::execute_client_round`] verbatim.

pub mod protocol;
pub mod registry;
pub mod remote;
pub mod rpc;

pub use protocol::Message;
pub use registry::{Registor, Registry};
pub use remote::{ClientService, RemoteCoordinator};
pub use rpc::{call, RpcServer};
