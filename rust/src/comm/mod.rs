//! Remote communication (paper §VII, Fig 4a): the three-tier
//! Protocol / RPC / Handler stack that supports the production phase.
//!
//! gRPC + protobuf are substituted by a hand-rolled length-prefixed binary
//! protocol over TCP (DESIGN.md substitution #4) — same architecture,
//! zero external dependencies. Service processes stay
//! thread-per-connection; the coordinator's high-fan-in ingest runs on
//! the nonblocking [`reactor`] with bounded backpressure. Training flow
//! and communication are decoupled exactly as in §V-B: the remote path
//! reuses [`crate::client::execute_client_round`] verbatim.

pub mod protocol;
pub mod reactor;
pub mod registry;
pub mod remote;
pub mod rpc;

pub use protocol::Message;
pub use reactor::MetricsServer;
pub use registry::{Registor, Registry};
pub use remote::{ClientService, RemoteCoordinator};
pub use rpc::{call, RpcServer};
