//! Service discovery (paper §VII, Fig 4b): registry + registor.
//!
//! The **registry** is the etcd/Kubernetes-Service stand-in: a TTL'd
//! key-value store of client addresses served over the platform RPC. The
//! **registor** is the docker-gen/Pod stand-in: a sidecar on each client
//! that registers the client's address and heartbeats to keep the lease
//! alive — clients never need to know their own deployment environment.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::comm::protocol::Message;
use crate::comm::rpc::{self, Handler, RpcServer};
use crate::error::{Error, Result};
use crate::util::clock::{Clock, RealClock};

/// TTL'd address store.
///
/// Time is read through the [`Clock`] abstraction: production registries
/// run on the wall clock, tests inject a
/// [`crate::util::clock::VirtualClock`] so lease-expiry behavior is
/// exercised instantly and deterministically instead of with real sleeps.
pub struct Registry {
    /// id → (addr, expiry in clock-ms).
    entries: Mutex<HashMap<String, (String, f64)>>,
    ttl_ms: f64,
    clock: Arc<dyn Clock>,
}

impl Registry {
    pub fn new(ttl: Duration) -> Registry {
        Registry::with_clock(ttl, Arc::new(RealClock::new(1.0)))
    }

    /// A registry reading time from an injected clock.
    pub fn with_clock(ttl: Duration, clock: Arc<dyn Clock>) -> Registry {
        Registry {
            entries: Mutex::new(HashMap::new()),
            ttl_ms: ttl.as_secs_f64() * 1000.0,
            clock,
        }
    }

    /// Default 10 s lease, matching heartbeat every 2 s.
    pub fn with_default_ttl() -> Registry {
        Registry::new(Duration::from_secs(10))
    }

    /// Start a registry service (ephemeral port with `"127.0.0.1:0"`).
    pub fn serve(addr: &str, ttl: Duration) -> Result<RpcServer> {
        let registry = Arc::new(Registry::new(ttl));
        RpcServer::serve(addr, registry)
    }

    /// Live (non-expired) entries, sorted by id.
    pub fn live(&self) -> Vec<(String, String)> {
        let now = self.clock.now_ms();
        let mut out: Vec<(String, String)> = self
            .entries
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, (_, exp))| *exp > now)
            .map(|(id, (addr, _))| (id.clone(), addr.clone()))
            .collect();
        out.sort();
        out
    }

    fn register(&self, id: String, addr: String) {
        self.entries
            .lock()
            .unwrap()
            .insert(id, (addr, self.clock.now_ms() + self.ttl_ms));
    }

    fn deregister(&self, id: &str) {
        self.entries.lock().unwrap().remove(id);
    }

    /// Drop expired leases (called opportunistically).
    pub fn sweep(&self) {
        let now = self.clock.now_ms();
        self.entries.lock().unwrap().retain(|_, (_, exp)| *exp > now);
    }
}

impl Handler for Registry {
    fn handle(&self, msg: Message) -> Message {
        match msg {
            Message::Register { id, addr } => {
                self.register(id, addr);
                Message::Ok
            }
            Message::Deregister { id } => {
                self.deregister(&id);
                Message::Ok
            }
            Message::ListClients => {
                self.sweep();
                Message::ClientList { entries: self.live() }
            }
            Message::Ping => Message::Pong,
            _ => Message::Err { msg: "registry: unsupported message".into() },
        }
    }
}

/// Heartbeating registration sidecar.
pub struct Registor {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    registry_addr: String,
    id: String,
}

impl Registor {
    /// Register `id @ service_addr` with the registry and keep the lease
    /// alive every `interval`.
    pub fn start(
        registry_addr: &str,
        id: &str,
        service_addr: &str,
        interval: Duration,
    ) -> Result<Registor> {
        // First registration is synchronous so callers can rely on
        // visibility once `start` returns.
        let reply = rpc::call(
            registry_addr,
            &Message::Register { id: id.into(), addr: service_addr.into() },
        )?;
        if reply != Message::Ok {
            return Err(Error::Comm(format!("registry rejected: {reply:?}")));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let (reg_addr, id2, svc) = (
            registry_addr.to_string(),
            id.to_string(),
            service_addr.to_string(),
        );
        let handle = std::thread::Builder::new()
            .name(format!("easyfl-registor-{id}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(interval);
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    let _ = rpc::call(
                        &reg_addr,
                        &Message::Register { id: id2.clone(), addr: svc.clone() },
                    );
                }
            })
            .map_err(|e| Error::Comm(format!("spawn registor: {e}")))?;
        Ok(Registor {
            stop,
            handle: Some(handle),
            registry_addr: registry_addr.to_string(),
            id: id.to_string(),
        })
    }
}

impl Drop for Registor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Join the heartbeat thread FIRST: an in-flight heartbeat racing
        // the Deregister could otherwise re-register the lease after it.
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let _ = rpc::call(
            &self.registry_addr,
            &Message::Deregister { id: self.id.clone() },
        );
    }
}

/// Query a registry for live clients (the server's discovery call).
pub fn discover(registry_addr: &str) -> Result<Vec<(String, String)>> {
    match rpc::call(registry_addr, &Message::ListClients)? {
        Message::ClientList { entries } => Ok(entries),
        other => Err(Error::Comm(format!("bad registry reply: {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    #[test]
    fn register_list_deregister() {
        let server =
            Registry::serve("127.0.0.1:0", Duration::from_secs(5)).unwrap();
        let addr = server.addr().to_string();
        rpc::call(&addr, &Message::Register { id: "c1".into(), addr: "a:1".into() })
            .unwrap();
        rpc::call(&addr, &Message::Register { id: "c2".into(), addr: "a:2".into() })
            .unwrap();
        let live = discover(&addr).unwrap();
        assert_eq!(live.len(), 2);
        assert_eq!(live[0], ("c1".into(), "a:1".into()));
        rpc::call(&addr, &Message::Deregister { id: "c1".into() }).unwrap();
        assert_eq!(discover(&addr).unwrap().len(), 1);
    }

    #[test]
    fn leases_expire_without_heartbeat() {
        // Virtual clock: lease expiry is exercised instantly and
        // deterministically — no real sleeps, nothing to flake.
        let clock = Arc::new(VirtualClock::new());
        let registry = Arc::new(Registry::with_clock(
            Duration::from_millis(50),
            clock.clone(),
        ));
        let server = RpcServer::serve("127.0.0.1:0", registry).unwrap();
        let addr = server.addr().to_string();
        rpc::call(&addr, &Message::Register { id: "x".into(), addr: "a:1".into() })
            .unwrap();
        assert_eq!(discover(&addr).unwrap().len(), 1);
        clock.wait_ms(49.0);
        assert_eq!(discover(&addr).unwrap().len(), 1, "live just before TTL");
        clock.wait_ms(2.0);
        assert_eq!(discover(&addr).unwrap().len(), 0, "expired past TTL");
    }

    #[test]
    fn registor_keeps_lease_alive_and_cleans_up() {
        // Registry time is virtual; the registor's heartbeats are real.
        // Expiring the lease on the virtual clock proves the next
        // heartbeat re-registers it — without waiting out real TTLs.
        let clock = Arc::new(VirtualClock::new());
        let registry = Arc::new(Registry::with_clock(
            Duration::from_millis(50),
            clock.clone(),
        ));
        let server = RpcServer::serve("127.0.0.1:0", registry).unwrap();
        let addr = server.addr().to_string();
        let registor = Registor::start(
            &addr,
            "cli-7",
            "10.0.0.7:4000",
            Duration::from_millis(10),
        )
        .unwrap();
        assert_eq!(
            discover(&addr).unwrap(),
            vec![("cli-7".into(), "10.0.0.7:4000".into())]
        );
        // Kill the lease on the virtual clock...
        clock.wait_ms(60.0);
        // ...and wait (bounded) for a heartbeat to renew it.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            if discover(&addr).unwrap().len() == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "heartbeat never renewed the expired lease"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(registor);
        // Deregistered on drop.
        assert_eq!(discover(&addr).unwrap().len(), 0);
    }
}
