//! The RPC tier: framed request/response over TCP.
//!
//! Servers are thread-per-connection (std::net; no tokio offline) with a
//! shared [`Handler`]. Clients use one-shot `call` or a persistent
//! [`Connection`] for request pipelining (the remote coordinator keeps one
//! connection per client service).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};

use super::protocol::Message;

/// Maximum frame size (guards against corrupt length prefixes): 256 MiB.
pub(crate) const MAX_FRAME: u32 = 256 << 20;

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, msg: &Message) -> Result<()> {
    let body = msg.encode();
    if body.len() as u64 > MAX_FRAME as u64 {
        return Err(Error::Comm(format!("frame too large: {}", body.len())));
    }
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(&body)?;
    stream.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(Error::Comm(format!("oversized frame: {len}")));
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body)?;
    Message::decode(&body)
}

/// Request handler shared across connection threads.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, msg: Message) -> Message;
}

impl<F> Handler for F
where
    F: Fn(Message) -> Message + Send + Sync + 'static,
{
    fn handle(&self, msg: Message) -> Message {
        self(msg)
    }
}

/// A running RPC server; stops (and joins) on drop.
pub struct RpcServer {
    addr: String,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve.
    pub fn serve(addr: &str, handler: Arc<dyn Handler>) -> Result<RpcServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Comm(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        // Accept loop polls with a timeout so `stop` is honored promptly.
        listener.set_nonblocking(true)?;
        let accept_handle = std::thread::Builder::new()
            .name(format!("easyfl-rpc-{}", local.port()))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = handler.clone();
                            let _ = std::thread::Builder::new()
                                .name("easyfl-rpc-conn".into())
                                .spawn(move ||

                                    serve_connection(stream, handler));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| Error::Comm(format!("spawn accept loop: {e}")))?;
        Ok(RpcServer {
            addr: local.to_string(),
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request shutdown (also done on drop).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_connection(mut stream: TcpStream, handler: Arc<dyn Handler>) {
    stream.set_nodelay(true).ok();
    loop {
        match read_frame(&mut stream) {
            Ok(msg) => {
                let reply = handler.handle(msg);
                if write_frame(&mut stream, &reply).is_err() {
                    break;
                }
            }
            Err(_) => break, // peer closed or protocol error
        }
    }
}

/// Persistent client connection (request/response pipelined serially).
pub struct Connection {
    stream: TcpStream,
}

impl Connection {
    pub fn connect(addr: &str) -> Result<Connection> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Comm(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(Connection { stream })
    }

    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Connection> {
        let sock_addr: std::net::SocketAddr = addr
            .parse()
            .map_err(|e| Error::Comm(format!("bad addr {addr}: {e}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .map_err(|e| Error::Comm(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(Connection { stream })
    }

    /// One request/response exchange.
    pub fn call(&mut self, msg: &Message) -> Result<Message> {
        write_frame(&mut self.stream, msg)?;
        read_frame(&mut self.stream)
    }

    /// Send without waiting (scatter phase of scatter/gather rounds;
    /// Fig 8 measures exactly this half).
    pub fn send(&mut self, msg: &Message) -> Result<()> {
        write_frame(&mut self.stream, msg)
    }

    /// Receive the pending response (gather phase).
    pub fn recv(&mut self) -> Result<Message> {
        read_frame(&mut self.stream)
    }

    /// Surrender the underlying socket (the nonblocking reactor in
    /// [`crate::comm::reactor`] multiplexes raw streams).
    pub(crate) fn into_stream(self) -> TcpStream {
        self.stream
    }
}

/// One-shot convenience call (connect → request → response → close).
pub fn call(addr: &str, msg: &Message) -> Result<Message> {
    Connection::connect(addr)?.call(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_server_roundtrip() {
        let server = RpcServer::serve(
            "127.0.0.1:0",
            Arc::new(|msg: Message| match msg {
                Message::Ping => Message::Pong,
                other => other,
            }),
        )
        .unwrap();
        let addr = server.addr().to_string();
        assert_eq!(call(&addr, &Message::Ping).unwrap(), Message::Pong);
        // Persistent connection handles multiple calls.
        let mut conn = Connection::connect(&addr).unwrap();
        for i in 0..5 {
            let m = Message::Err { msg: format!("e{i}") };
            assert_eq!(conn.call(&m).unwrap(), m);
        }
    }

    #[test]
    fn concurrent_clients() {
        let server = RpcServer::serve(
            "127.0.0.1:0",
            Arc::new(|_| Message::Ok),
        )
        .unwrap();
        let addr = server.addr().to_string();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        assert_eq!(call(&addr, &Message::Ping).unwrap(), Message::Ok);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn large_frame_roundtrip() {
        let server = RpcServer::serve(
            "127.0.0.1:0",
            Arc::new(|msg: Message| msg),
        )
        .unwrap();
        let params = crate::model::ParamVec(vec![0.5; 300_000]); // 1.2 MB
        let msg = Message::EvalRequest { model: "mlp".into(), params };
        let got = call(server.addr(), &msg).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn shutdown_stops_accepting() {
        let server =
            RpcServer::serve("127.0.0.1:0", Arc::new(|_| Message::Ok)).unwrap();
        let addr = server.addr().to_string();
        drop(server);
        std::thread::sleep(Duration::from_millis(30));
        // New connections must fail (or at least not answer).
        let r = Connection::connect_timeout(&addr, Duration::from_millis(100))
            .and_then(|mut c| c.call(&Message::Ping));
        assert!(r.is_err());
    }
}
