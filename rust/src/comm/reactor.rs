//! Nonblocking ingest reactor: the coordinator's high-throughput path.
//!
//! The original remote round spawned one OS thread per client for
//! scatter *and* gather — at 10k concurrent uploaders that is 10k
//! stacks, 10k scheduler entries, and an unbounded pile of decoded
//! replies waiting for the aggregator. This module replaces both halves
//! with fixed-size machinery:
//!
//! * [`scatter`] — a worker pool (not per-client threads) connects and
//!   pushes the round's `TrainRequest`s out.
//! * [`gather_reactor`] — every reply socket is set nonblocking and
//!   multiplexed on a fixed pool of poll loops
//!   (`TcpStream::set_nonblocking` + incremental frame reassembly — no
//!   tokio, no epoll binding, nothing outside std). Completed frames are
//!   decoded and handed to the consumer through a **bounded** MPSC
//!   queue.
//! * [`bounded`] — the backpressure primitive: when the queue is full,
//!   senders *park* (condvar wait) instead of dropping or buffering
//!   without bound, so a slow aggregator throttles ingest all the way
//!   back into the kernel's TCP windows.
//! * [`gather_threads`] — the legacy thread-per-connection baseline,
//!   kept behind `Config.ingest = "threads"` as the equivalence oracle
//!   and the benchmark baseline (`examples/ingest_bench.rs`).
//! * [`MetricsServer`] — a live `/metrics` endpoint: the same poll loop,
//!   one thread, serving [`crate::obs::Telemetry::metrics_snapshot`] as
//!   JSON to any [`Message::MetricsRequest`].

use std::collections::VecDeque;
use std::io::{self, Read};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::obs::Telemetry;
use crate::util::json::Json;

use super::protocol::Message;
use super::rpc::{write_frame, Connection, MAX_FRAME};

/// Sleep between poll sweeps that made no progress (same cadence as the
/// RPC accept loop).
const POLL_IDLE: Duration = Duration::from_millis(1);

// ------------------------------------------------------ bounded queue

struct QueueState<T> {
    items: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
    /// High-water mark of `items.len()`, read by the backpressure tests:
    /// the bound is enforced under the same lock, so this can never
    /// exceed the capacity.
    max_depth: usize,
}

struct QueueShared<T> {
    state: Mutex<QueueState<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

/// Producer half of a [`bounded`] channel. Cloneable; `send` parks while
/// the queue is at capacity.
pub struct BoundedSender<T> {
    shared: Arc<QueueShared<T>>,
}

/// Consumer half of a [`bounded`] channel.
pub struct BoundedReceiver<T> {
    shared: Arc<QueueShared<T>>,
}

/// A bounded MPSC channel whose senders block (park on a condvar) when
/// the queue holds `cap` items — backpressure, never drops. `cap` is
/// clamped to at least 1.
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    let shared = Arc::new(QueueShared {
        state: Mutex::new(QueueState {
            items: VecDeque::new(),
            senders: 1,
            receiver_alive: true,
            max_depth: 0,
        }),
        cap: cap.max(1),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (
        BoundedSender { shared: shared.clone() },
        BoundedReceiver { shared },
    )
}

impl<T> BoundedSender<T> {
    /// Enqueue one item, parking until space frees up. Returns the item
    /// back if the receiver is gone.
    pub fn send(&self, item: T) -> std::result::Result<(), T> {
        let mut state = self.shared.state.lock().unwrap();
        while state.receiver_alive && state.items.len() >= self.shared.cap {
            state = self.shared.not_full.wait(state).unwrap();
        }
        if !state.receiver_alive {
            return Err(item);
        }
        state.items.push_back(item);
        state.max_depth = state.max_depth.max(state.items.len());
        drop(state);
        self.shared.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        BoundedSender { shared: self.shared.clone() }
    }
}

impl<T> Drop for BoundedSender<T> {
    fn drop(&mut self) {
        let mut state = self.shared.state.lock().unwrap();
        state.senders -= 1;
        let last = state.senders == 0;
        drop(state);
        if last {
            // Wake a receiver blocked on an empty queue so it can see EOF.
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> BoundedReceiver<T> {
    /// Dequeue the next item, blocking while the queue is empty and any
    /// sender is alive. `None` once every sender is gone and the queue
    /// has drained.
    pub fn recv(&self) -> Option<T> {
        let mut state = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = state.items.pop_front() {
                drop(state);
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if state.senders == 0 {
                return None;
            }
            state = self.shared.not_empty.wait(state).unwrap();
        }
    }

    /// Deepest the queue has ever been (≤ the construction capacity —
    /// the property the backpressure tests pin down).
    pub fn max_depth(&self) -> usize {
        self.shared.state.lock().unwrap().max_depth
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().receiver_alive = false;
        // Parked senders must fail out, not wait forever.
        self.shared.not_full.notify_all();
    }
}

// -------------------------------------------------------- scatter pool

/// Default worker count for scatter/gather pools: the machine's
/// parallelism, capped at 8 (same policy as the aggregation plane).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Connect to every `(client_index, addr)` and push its message, on a
/// fixed pool of `workers` threads instead of one thread per client.
/// Results come back per client (arbitrary order); the open connections
/// are what the gather half reads the replies from.
pub fn scatter(
    tasks: Vec<(usize, String, Message)>,
    workers: usize,
) -> Vec<(usize, Result<Connection>)> {
    let workers = workers.max(1).min(tasks.len().max(1));
    let mut shards: Vec<Vec<(usize, String, Message)>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (i, task) in tasks.into_iter().enumerate() {
        shards[i % workers].push(task);
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = shards
            .into_iter()
            .map(|shard| {
                s.spawn(move || {
                    shard
                        .into_iter()
                        .map(|(idx, addr, msg)| {
                            let res = Connection::connect(&addr)
                                .and_then(|mut c| c.send(&msg).map(|()| c));
                            (idx, res)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("scatter worker panicked"))
            .collect()
    })
}

// ---------------------------------------------------- frame reassembly

/// Per-connection incremental frame parser: survives `WouldBlock` at any
/// byte boundary, so one poll-loop thread can interleave thousands of
/// partially-arrived frames.
struct PendingConn {
    idx: usize,
    stream: TcpStream,
    len_buf: [u8; 4],
    len_read: usize,
    body: Vec<u8>,
    body_read: usize,
}

enum Poll {
    /// Frame incomplete; `progress` reports whether any bytes landed.
    Pending { progress: bool },
    /// One full frame (or a terminal error) — the connection is done.
    Ready(Box<Result<Message>>),
}

impl PendingConn {
    fn new(idx: usize, stream: TcpStream) -> PendingConn {
        PendingConn {
            idx,
            stream,
            len_buf: [0; 4],
            len_read: 0,
            body: Vec::new(),
            body_read: 0,
        }
    }

    /// Reset to await another frame on the same socket (the metrics
    /// endpoint serves many requests per connection).
    fn reset(&mut self) {
        self.len_read = 0;
        self.body = Vec::new();
        self.body_read = 0;
    }

    fn poll(&mut self) -> Poll {
        let mut progress = false;
        loop {
            if self.len_read < 4 {
                match self.stream.read(&mut self.len_buf[self.len_read..]) {
                    Ok(0) => {
                        return Poll::Ready(Box::new(Err(Error::Comm(
                            format!(
                                "client {}: connection closed mid-frame",
                                self.idx
                            ),
                        ))))
                    }
                    Ok(n) => {
                        self.len_read += n;
                        progress = true;
                        if self.len_read == 4 {
                            let len = u32::from_le_bytes(self.len_buf);
                            if len > MAX_FRAME {
                                return Poll::Ready(Box::new(Err(
                                    Error::Comm(format!(
                                        "oversized frame: {len}"
                                    )),
                                )));
                            }
                            self.body = vec![0u8; len as usize];
                            self.body_read = 0;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        return Poll::Pending { progress }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                        continue
                    }
                    Err(e) => return Poll::Ready(Box::new(Err(e.into()))),
                }
            } else if self.body_read < self.body.len() {
                match self.stream.read(&mut self.body[self.body_read..]) {
                    Ok(0) => {
                        return Poll::Ready(Box::new(Err(Error::Comm(
                            format!(
                                "client {}: connection closed mid-frame",
                                self.idx
                            ),
                        ))))
                    }
                    Ok(n) => {
                        self.body_read += n;
                        progress = true;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        return Poll::Pending { progress }
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                        continue
                    }
                    Err(e) => return Poll::Ready(Box::new(Err(e.into()))),
                }
            } else {
                return Poll::Ready(Box::new(Message::decode(&self.body)));
            }
        }
    }
}

// ------------------------------------------------------- gather plane

/// A running gather: replies stream out of [`Ingest::recv`] as
/// `(client_index, decoded message)`. Reader threads are joined on drop.
pub struct Ingest {
    rx: BoundedReceiver<(usize, Result<Message>)>,
    handles: Vec<JoinHandle<()>>,
}

impl Ingest {
    /// Next reply, in arrival order. `None` when every connection has
    /// delivered (or failed).
    pub fn recv(&self) -> Option<(usize, Result<Message>)> {
        self.rx.recv()
    }

    /// High-water mark of the backpressure queue.
    pub fn max_depth(&self) -> usize {
        self.rx.max_depth()
    }
}

impl Drop for Ingest {
    fn drop(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Gather one reply per connection on a fixed pool of nonblocking poll
/// loops. Connections are sharded round-robin across `workers` threads;
/// each thread sweeps its shard, reassembling frames incrementally, and
/// pushes completed replies into a queue of capacity `queue_cap`. When
/// the consumer stalls, reader threads park in `send` — backpressure,
/// not drops — and unread bytes stay in the kernel TCP windows.
pub fn gather_reactor(
    conns: Vec<(usize, Connection)>,
    workers: usize,
    queue_cap: usize,
) -> Ingest {
    let workers = workers.max(1).min(conns.len().max(1));
    let (tx, rx) = bounded(queue_cap);
    let mut shards: Vec<Vec<PendingConn>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (i, (idx, conn)) in conns.into_iter().enumerate() {
        shards[i % workers].push(PendingConn::new(idx, conn.into_stream()));
    }
    let handles = shards
        .into_iter()
        .filter(|shard| !shard.is_empty())
        .enumerate()
        .map(|(w, shard)| {
            let tx = tx.clone();
            std::thread::Builder::new()
                .name(format!("easyfl-reactor-{w}"))
                .spawn(move || reactor_worker(shard, tx))
                .expect("spawn reactor worker")
        })
        .collect();
    drop(tx);
    Ingest { rx, handles }
}

fn reactor_worker(
    mut shard: Vec<PendingConn>,
    tx: BoundedSender<(usize, Result<Message>)>,
) {
    for conn in &shard {
        conn.stream.set_nonblocking(true).ok();
    }
    while !shard.is_empty() {
        let mut progress = false;
        let mut i = 0;
        while i < shard.len() {
            match shard[i].poll() {
                Poll::Pending { progress: p } => {
                    progress |= p;
                    i += 1;
                }
                Poll::Ready(res) => {
                    progress = true;
                    let conn = shard.swap_remove(i);
                    if tx.send((conn.idx, *res)).is_err() {
                        return; // consumer gone: abandon the round
                    }
                }
            }
        }
        if !progress {
            std::thread::sleep(POLL_IDLE);
        }
    }
}

/// The legacy gather: one blocking reader thread per connection, feeding
/// the same bounded queue. Selected by `Config.ingest = "threads"`; the
/// benchmark baseline the reactor is gated against.
pub fn gather_threads(
    conns: Vec<(usize, Connection)>,
    queue_cap: usize,
) -> Ingest {
    let (tx, rx) = bounded(queue_cap);
    let handles = conns
        .into_iter()
        .map(|(idx, mut conn)| {
            let tx = tx.clone();
            std::thread::Builder::new()
                .name("easyfl-gather".into())
                .spawn(move || {
                    let res = conn.recv();
                    let _ = tx.send((idx, res));
                })
                .expect("spawn gather thread")
        })
        .collect();
    drop(tx);
    Ingest { rx, handles }
}

// ---------------------------------------------------- metrics endpoint

/// Live `/metrics` endpoint: one reactor-style poll thread accepting
/// connections and answering [`Message::MetricsRequest`] with the
/// current [`Telemetry::metrics_snapshot`] as JSON. The end-of-run
/// `metrics_out` file is unchanged — this serves the *same* registry
/// mid-run, so an operator can watch `remote.ingest_ms` move while a
/// round is still gathering.
pub struct MetricsServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 for ephemeral) and serve `tel`'s snapshot.
    pub fn serve(addr: &str, tel: Telemetry) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Comm(format!("bind {addr}: {e}")))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name(format!("easyfl-metrics-{}", local.port()))
            .spawn(move || metrics_loop(listener, tel, stop2))
            .map_err(|e| Error::Comm(format!("spawn metrics loop: {e}")))?;
        Ok(MetricsServer {
            addr: local.to_string(),
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request shutdown (also done on drop).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn metrics_loop(listener: TcpListener, tel: Telemetry, stop: Arc<AtomicBool>) {
    let mut conns: Vec<PendingConn> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        let mut progress = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    conns.push(PendingConn::new(conns.len(), stream));
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => return,
            }
        }
        let mut i = 0;
        while i < conns.len() {
            match conns[i].poll() {
                Poll::Pending { progress: p } => {
                    progress |= p;
                    i += 1;
                }
                Poll::Ready(res) => {
                    progress = true;
                    match *res {
                        Ok(msg) => {
                            let reply = match msg {
                                Message::MetricsRequest => {
                                    Message::MetricsReply {
                                        json: tel
                                            .metrics_snapshot()
                                            .to_string(),
                                    }
                                }
                                Message::Ping => Message::Pong,
                                _ => Message::Err {
                                    msg: "metrics endpoint: only \
                                          MetricsRequest/Ping served"
                                        .into(),
                                },
                            };
                            // Replies are small; write blocking so a
                            // slow reader cannot corrupt frame state.
                            let conn = &mut conns[i];
                            conn.stream.set_nonblocking(false).ok();
                            let ok =
                                write_frame(&mut conn.stream, &reply).is_ok();
                            conn.stream.set_nonblocking(true).ok();
                            if ok {
                                conn.reset();
                                i += 1;
                            } else {
                                conns.swap_remove(i);
                            }
                        }
                        Err(_) => {
                            conns.swap_remove(i); // peer closed or junk
                        }
                    }
                }
            }
        }
        if !progress {
            std::thread::sleep(POLL_IDLE);
        }
    }
}

/// Fetch and parse a [`MetricsServer`]'s snapshot (the client half the
/// CLI and tests use).
pub fn fetch_metrics(addr: &str) -> Result<Json> {
    match super::rpc::call(addr, &Message::MetricsRequest)? {
        Message::MetricsReply { json } => Json::parse(&json),
        Message::Err { msg } => Err(Error::Comm(msg)),
        other => {
            Err(Error::Comm(format!("unexpected metrics reply: {other:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::rpc::RpcServer;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn bounded_queue_is_fifo_and_reports_eof() {
        let (tx, rx) = bounded(4);
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        drop(tx);
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_once_the_receiver_is_gone() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        drop(rx);
        assert_eq!(tx.send(2), Err(2));
    }

    #[test]
    fn prop_queue_depth_never_exceeds_the_bound() {
        const CAP: usize = 7;
        const SENDERS: usize = 8;
        const PER_SENDER: usize = 200;
        let (tx, rx) = bounded::<usize>(CAP);
        let producers: Vec<_> = (0..SENDERS)
            .map(|s| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for i in 0..PER_SENDER {
                        tx.send(s * PER_SENDER + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let mut got = Vec::new();
        while let Some(v) = rx.recv() {
            // An occasionally-slow consumer keeps the queue saturated.
            if got.len() % 64 == 0 {
                std::thread::yield_now();
            }
            got.push(v);
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(got.len(), SENDERS * PER_SENDER, "no drops");
        got.sort_unstable();
        assert!(got.iter().enumerate().all(|(i, &v)| i == v), "no dupes");
        assert!(
            rx.max_depth() <= CAP,
            "depth {} exceeded bound {CAP}",
            rx.max_depth()
        );
    }

    #[test]
    fn stalled_consumer_parks_senders_instead_of_dropping() {
        let (tx, rx) = bounded(1);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..3 {
                tx.send(i).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        // With capacity 1 and nothing consumed, the producer lands the
        // first item and parks in the second send.
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(sent.load(Ordering::SeqCst), 1, "producer not parked");
        let mut got = Vec::new();
        while let Some(v) = rx.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, vec![0, 1, 2], "every parked item arrived in order");
        assert_eq!(rx.max_depth(), 1);
    }

    /// Open `n` echo connections with a distinct pending reply on each.
    fn pending_replies(addr: &str, n: usize) -> Vec<(usize, Connection)> {
        (0..n)
            .map(|i| {
                let mut conn = Connection::connect(addr).unwrap();
                conn.send(&Message::Err { msg: format!("reply-{i}") })
                    .unwrap();
                (i, conn)
            })
            .collect()
    }

    fn drain_sorted(ingest: Ingest) -> Vec<(usize, Vec<u8>)> {
        let mut out = Vec::new();
        while let Some((idx, res)) = ingest.recv() {
            out.push((idx, res.unwrap().encode()));
        }
        out.sort_by_key(|(idx, _)| *idx);
        out
    }

    #[test]
    fn reactor_gather_is_byte_identical_to_thread_per_connection() {
        let server =
            RpcServer::serve("127.0.0.1:0", Arc::new(|msg: Message| msg))
                .unwrap();
        let addr = server.addr().to_string();
        const N: usize = 32;
        let via_reactor =
            drain_sorted(gather_reactor(pending_replies(&addr, N), 3, 8));
        let via_threads =
            drain_sorted(gather_threads(pending_replies(&addr, N), 8));
        assert_eq!(via_reactor.len(), N);
        assert_eq!(via_reactor, via_threads);
        for (i, (idx, bytes)) in via_reactor.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(
                Message::decode(bytes).unwrap(),
                Message::Err { msg: format!("reply-{i}") }
            );
        }
    }

    #[test]
    fn reactor_backpressure_bounds_the_queue_under_a_slow_consumer() {
        let server =
            RpcServer::serve("127.0.0.1:0", Arc::new(|msg: Message| msg))
                .unwrap();
        let addr = server.addr().to_string();
        const N: usize = 24;
        const CAP: usize = 2;
        let ingest = gather_reactor(pending_replies(&addr, N), 4, CAP);
        let mut seen = 0;
        while let Some((_, res)) = ingest.recv() {
            res.unwrap();
            seen += 1;
            std::thread::sleep(Duration::from_millis(2)); // stall
        }
        assert_eq!(seen, N, "backpressure must not drop replies");
        assert!(ingest.max_depth() <= CAP);
    }

    #[test]
    fn reactor_surfaces_connection_errors_per_client() {
        let server =
            RpcServer::serve("127.0.0.1:0", Arc::new(|msg: Message| msg))
                .unwrap();
        let addr = server.addr().to_string();
        drop(server); // replies will never come; sockets close
        std::thread::sleep(Duration::from_millis(30));
        let conns: Vec<(usize, Connection)> =
            match Connection::connect(&addr) {
                Ok(conn) => vec![(7, conn)],
                Err(_) => return, // connect refused outright: fine too
            };
        let ingest = gather_reactor(conns, 1, 4);
        if let Some((idx, res)) = ingest.recv() {
            assert_eq!(idx, 7);
            assert!(res.is_err());
        }
        assert!(ingest.recv().is_none());
    }

    #[test]
    fn metrics_endpoint_serves_the_live_snapshot() {
        let clock = Arc::new(crate::util::clock::VirtualClock::new());
        let tel =
            Telemetry::new(clock, Arc::new(crate::obs::NullSink), None);
        tel.counter("remote.rounds", 3);
        tel.observe_ms("remote.ingest_ms", 12.0);
        let server = MetricsServer::serve("127.0.0.1:0", tel.clone()).unwrap();
        let snap = fetch_metrics(server.addr()).unwrap();
        assert_eq!(
            snap.get("counters").get("remote.rounds").as_usize(),
            Some(3)
        );
        // The endpoint is live: a later bump shows in the next fetch,
        // over a fresh connection against the same poll loop.
        tel.counter("remote.rounds", 2);
        let snap = fetch_metrics(server.addr()).unwrap();
        assert_eq!(
            snap.get("counters").get("remote.rounds").as_usize(),
            Some(5)
        );
        // Non-metrics requests get a typed refusal, and Ping pongs.
        let reply = crate::comm::rpc::call(
            server.addr(),
            &Message::TrackQuery { task_id: "t".into() },
        )
        .unwrap();
        assert!(matches!(reply, Message::Err { .. }));
        assert_eq!(
            crate::comm::rpc::call(server.addr(), &Message::Ping).unwrap(),
            Message::Pong
        );
    }
}
