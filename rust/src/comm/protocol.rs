//! The Protocol tier: message types and their binary wire encoding.
//!
//! Frames are `u32 length ‖ u8 tag ‖ fields…`, all little-endian, encoded
//! with `util::bytes` (no serde offline). Parameter vectors ride as raw
//! f32 blocks — a 242k-param model is one ~1 MB memcpy, no per-element
//! overhead.

use crate::codec::{CodecKind, EncodedUpdate, QuantizedValues};
use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;
use crate::util::bytes::{Reader, Writer};

/// Every message the platform sends between processes.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    // ---- generic
    Ok,
    Err { msg: String },
    Ping,
    Pong,

    // ---- service discovery (Fig 4b)
    /// registor → registry: announce a client service.
    Register { id: String, addr: String },
    /// registor → registry: remove a client service.
    Deregister { id: String },
    /// server → registry: list live clients.
    ListClients,
    /// registry → server.
    ClientList { entries: Vec<(String, String)> },

    // ---- remote training (Fig 4a)
    /// server → client: run one local round.
    TrainRequest {
        round: u32,
        client_index: u32,
        model: String,
        lr: f32,
        local_epochs: u32,
        batch_size: u32,
        data_amount: f32,
        seed: u64,
        params: ParamVec,
    },
    /// client → server.
    TrainReply {
        round: u32,
        client_index: u32,
        num_samples: u32,
        sum_loss: f64,
        correct: f64,
        compute_ms: f64,
        update: Update,
    },
    /// server → client: evaluate params on the client's local data.
    EvalRequest { model: String, params: ParamVec },
    /// client → server.
    EvalReply { sum_loss: f64, correct: f64, num_samples: u32 },

    // ---- remote tracking (§V-C)
    /// any → tracking service: one round's metrics as JSON text.
    TrackRound { task_id: String, json: String },
    /// query the tracking service for a task's JSON.
    TrackQuery { task_id: String },
    TrackDump { json: String },

    // ---- live observability (see [`crate::comm::reactor::MetricsServer`])
    /// any → coordinator metrics endpoint: request the live
    /// counter/histogram snapshot.
    MetricsRequest,
    /// metrics endpoint → caller: the snapshot as JSON text.
    MetricsReply { json: String },
}

const T_OK: u8 = 0;
const T_ERR: u8 = 1;
const T_PING: u8 = 2;
const T_PONG: u8 = 3;
const T_REGISTER: u8 = 10;
const T_DEREGISTER: u8 = 11;
const T_LIST: u8 = 12;
const T_CLIENTLIST: u8 = 13;
const T_TRAINREQ: u8 = 20;
const T_TRAINREP: u8 = 21;
const T_EVALREQ: u8 = 22;
const T_EVALREP: u8 = 23;
const T_TRACKROUND: u8 = 30;
const T_TRACKQUERY: u8 = 31;
const T_TRACKDUMP: u8 = 32;
const T_METRICSREQ: u8 = 40;
const T_METRICSREP: u8 = 41;

const U_DENSE: u8 = 0;
const U_SPARSE: u8 = 1;
const U_MASKED: u8 = 2;
const U_ENCODED: u8 = 3;

const V_F32: u8 = 0;
const V_F16: u8 = 1;
const V_I8: u8 = 2;

fn write_update(w: &mut Writer, u: &Update) {
    match u {
        Update::Dense(p) => {
            w.u8(U_DENSE);
            w.f32s(p);
        }
        Update::SparseTernary { len, indices, signs, magnitude } => {
            w.u8(U_SPARSE);
            w.u32(*len as u32);
            w.u32(indices.len() as u32);
            for i in indices {
                w.u32(*i);
            }
            // Bit-packed signs.
            let mut bits = vec![0u8; signs.len().div_ceil(8)];
            for (i, &s) in signs.iter().enumerate() {
                if s {
                    bits[i / 8] |= 1 << (i % 8);
                }
            }
            w.bytes(&bits);
            w.f32(*magnitude);
        }
        Update::Masked { xor_key, inner } => {
            w.u8(U_MASKED);
            w.u64(*xor_key);
            write_update(w, inner);
        }
        Update::Encoded(e) => {
            w.u8(U_ENCODED);
            w.u8(e.kind.tag());
            w.u32(e.len as u32);
            w.u32(e.indices.len() as u32);
            for i in &e.indices {
                w.u32(*i);
            }
            match &e.values {
                QuantizedValues::F32(v) => {
                    w.u8(V_F32);
                    w.f32s(v);
                }
                QuantizedValues::F16(v) => {
                    w.u8(V_F16);
                    let mut raw = Vec::with_capacity(v.len() * 2);
                    for x in v {
                        raw.extend_from_slice(&x.to_le_bytes());
                    }
                    w.bytes(&raw);
                }
                QuantizedValues::I8 { quanta, scales } => {
                    w.u8(V_I8);
                    let raw: Vec<u8> =
                        quanta.iter().map(|q| *q as u8).collect();
                    w.bytes(&raw);
                    w.f32s(scales);
                }
            }
            w.u32(e.encoded_len as u32);
            w.u64(e.content_hash);
        }
    }
}

fn read_update(r: &mut Reader) -> Result<Update> {
    match r.u8()? {
        U_DENSE => Ok(Update::Dense(ParamVec(r.f32s()?))),
        U_SPARSE => {
            let len = r.u32()? as usize;
            let k = r.u32()? as usize;
            let mut indices = Vec::with_capacity(k);
            for _ in 0..k {
                indices.push(r.u32()?);
            }
            let bits = r.bytes()?;
            let signs = (0..k)
                .map(|i| bits[i / 8] & (1 << (i % 8)) != 0)
                .collect();
            let magnitude = r.f32()?;
            Ok(Update::SparseTernary { len, indices, signs, magnitude })
        }
        U_MASKED => {
            let xor_key = r.u64()?;
            let inner = Box::new(read_update(r)?);
            Ok(Update::Masked { xor_key, inner })
        }
        U_ENCODED => {
            let kind = r.u8()?;
            let kind = CodecKind::from_tag(kind).ok_or_else(|| {
                Error::Comm(format!("unknown codec kind tag {kind}"))
            })?;
            let len = r.u32()? as usize;
            let k = r.u32()? as usize;
            let mut indices = Vec::with_capacity(k);
            for _ in 0..k {
                indices.push(r.u32()?);
            }
            let values = match r.u8()? {
                V_F32 => QuantizedValues::F32(r.f32s()?),
                V_F16 => {
                    let raw = r.bytes()?;
                    if raw.len() % 2 != 0 {
                        return Err(Error::Comm(
                            "odd f16 payload length".into(),
                        ));
                    }
                    QuantizedValues::F16(
                        raw.chunks_exact(2)
                            .map(|c| u16::from_le_bytes([c[0], c[1]]))
                            .collect(),
                    )
                }
                V_I8 => {
                    let raw = r.bytes()?;
                    QuantizedValues::I8 {
                        quanta: raw.iter().map(|b| *b as i8).collect(),
                        scales: r.f32s()?,
                    }
                }
                t => {
                    return Err(Error::Comm(format!(
                        "unknown quantized-values tag {t}"
                    )))
                }
            };
            let encoded_len = r.u32()? as usize;
            let content_hash = r.u64()?;
            let e = EncodedUpdate {
                kind,
                len,
                indices,
                values,
                encoded_len,
                content_hash,
            };
            // Integrity-check straight off the wire: a flipped bit in
            // transit surfaces here, not deep inside the aggregator.
            e.verify()?;
            Ok(Update::Encoded(e))
        }
        t => Err(Error::Comm(format!("unknown update tag {t}"))),
    }
}

impl Message {
    /// Encode to a frame body (no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        match self {
            Message::Ok => w.u8(T_OK),
            Message::Err { msg } => {
                w.u8(T_ERR);
                w.str(msg);
            }
            Message::Ping => w.u8(T_PING),
            Message::Pong => w.u8(T_PONG),
            Message::Register { id, addr } => {
                w.u8(T_REGISTER);
                w.str(id);
                w.str(addr);
            }
            Message::Deregister { id } => {
                w.u8(T_DEREGISTER);
                w.str(id);
            }
            Message::ListClients => w.u8(T_LIST),
            Message::ClientList { entries } => {
                w.u8(T_CLIENTLIST);
                w.u32(entries.len() as u32);
                for (id, addr) in entries {
                    w.str(id);
                    w.str(addr);
                }
            }
            Message::TrainRequest {
                round,
                client_index,
                model,
                lr,
                local_epochs,
                batch_size,
                data_amount,
                seed,
                params,
            } => {
                w.u8(T_TRAINREQ);
                w.u32(*round);
                w.u32(*client_index);
                w.str(model);
                w.f32(*lr);
                w.u32(*local_epochs);
                w.u32(*batch_size);
                w.f32(*data_amount);
                w.u64(*seed);
                w.f32s(params);
            }
            Message::TrainReply {
                round,
                client_index,
                num_samples,
                sum_loss,
                correct,
                compute_ms,
                update,
            } => {
                w.u8(T_TRAINREP);
                w.u32(*round);
                w.u32(*client_index);
                w.u32(*num_samples);
                w.f64(*sum_loss);
                w.f64(*correct);
                w.f64(*compute_ms);
                write_update(&mut w, update);
            }
            Message::EvalRequest { model, params } => {
                w.u8(T_EVALREQ);
                w.str(model);
                w.f32s(params);
            }
            Message::EvalReply { sum_loss, correct, num_samples } => {
                w.u8(T_EVALREP);
                w.f64(*sum_loss);
                w.f64(*correct);
                w.u32(*num_samples);
            }
            Message::TrackRound { task_id, json } => {
                w.u8(T_TRACKROUND);
                w.str(task_id);
                w.str(json);
            }
            Message::TrackQuery { task_id } => {
                w.u8(T_TRACKQUERY);
                w.str(task_id);
            }
            Message::TrackDump { json } => {
                w.u8(T_TRACKDUMP);
                w.str(json);
            }
            Message::MetricsRequest => w.u8(T_METRICSREQ),
            Message::MetricsReply { json } => {
                w.u8(T_METRICSREP);
                w.str(json);
            }
        }
        w.finish()
    }

    /// Decode from a frame body.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            T_OK => Message::Ok,
            T_ERR => Message::Err { msg: r.str()? },
            T_PING => Message::Ping,
            T_PONG => Message::Pong,
            T_REGISTER => Message::Register { id: r.str()?, addr: r.str()? },
            T_DEREGISTER => Message::Deregister { id: r.str()? },
            T_LIST => Message::ListClients,
            T_CLIENTLIST => {
                let n = r.u32()? as usize;
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push((r.str()?, r.str()?));
                }
                Message::ClientList { entries }
            }
            T_TRAINREQ => Message::TrainRequest {
                round: r.u32()?,
                client_index: r.u32()?,
                model: r.str()?,
                lr: r.f32()?,
                local_epochs: r.u32()?,
                batch_size: r.u32()?,
                data_amount: r.f32()?,
                seed: r.u64()?,
                params: ParamVec(r.f32s()?),
            },
            T_TRAINREP => Message::TrainReply {
                round: r.u32()?,
                client_index: r.u32()?,
                num_samples: r.u32()?,
                sum_loss: r.f64()?,
                correct: r.f64()?,
                compute_ms: r.f64()?,
                update: read_update(&mut r)?,
            },
            T_EVALREQ => Message::EvalRequest {
                model: r.str()?,
                params: ParamVec(r.f32s()?),
            },
            T_EVALREP => Message::EvalReply {
                sum_loss: r.f64()?,
                correct: r.f64()?,
                num_samples: r.u32()?,
            },
            T_TRACKROUND => Message::TrackRound {
                task_id: r.str()?,
                json: r.str()?,
            },
            T_TRACKQUERY => Message::TrackQuery { task_id: r.str()? },
            T_TRACKDUMP => Message::TrackDump { json: r.str()? },
            T_METRICSREQ => Message::MetricsRequest,
            T_METRICSREP => Message::MetricsReply { json: r.str()? },
            t => return Err(Error::Comm(format!("unknown message tag {t}"))),
        };
        if r.remaining() != 0 {
            return Err(Error::Comm(format!(
                "{} trailing bytes in frame",
                r.remaining()
            )));
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn roundtrip(m: &Message) {
        let enc = m.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(&dec, m);
    }

    #[test]
    fn simple_messages_roundtrip() {
        roundtrip(&Message::Ok);
        roundtrip(&Message::Err { msg: "boom ✗".into() });
        roundtrip(&Message::Ping);
        roundtrip(&Message::Pong);
        roundtrip(&Message::Register {
            id: "c1".into(),
            addr: "127.0.0.1:4001".into(),
        });
        roundtrip(&Message::Deregister { id: "c1".into() });
        roundtrip(&Message::ListClients);
        roundtrip(&Message::ClientList {
            entries: vec![("a".into(), "x:1".into()), ("b".into(), "y:2".into())],
        });
        roundtrip(&Message::TrackRound {
            task_id: "t".into(),
            json: "{\"round\":1}".into(),
        });
    }

    #[test]
    fn train_messages_roundtrip() {
        roundtrip(&Message::TrainRequest {
            round: 3,
            client_index: 17,
            model: "mlp".into(),
            lr: 0.05,
            local_epochs: 2,
            batch_size: 32,
            data_amount: 0.5,
            seed: 0xDEAD_BEEF_CAFE,
            params: ParamVec(vec![1.0, -2.0, 3.5]),
        });
        roundtrip(&Message::TrainReply {
            round: 3,
            client_index: 17,
            num_samples: 100,
            sum_loss: 12.25,
            correct: 88.0,
            compute_ms: 123.456,
            update: Update::SparseTernary {
                len: 10,
                indices: vec![1, 5, 9],
                signs: vec![true, false, true],
                magnitude: 0.75,
            },
        });
        roundtrip(&Message::EvalReply {
            sum_loss: 1.0,
            correct: 2.0,
            num_samples: 3,
        });
    }

    #[test]
    fn masked_update_roundtrips() {
        roundtrip(&Message::TrainReply {
            round: 0,
            client_index: 0,
            num_samples: 1,
            sum_loss: 0.0,
            correct: 0.0,
            compute_ms: 0.0,
            update: Update::Masked {
                xor_key: 42,
                inner: Box::new(Update::Dense(ParamVec(vec![7.0]))),
            },
        });
    }

    #[test]
    fn encoded_updates_roundtrip_for_every_codec_kind() {
        // Build genuine codec outputs (hash and quantization included)
        // rather than hand-rolled structs, so the wire arms are tested
        // against exactly what clients upload.
        let mut rng = Rng::new(61);
        let global = ParamVec(
            (0..96).map(|_| rng.normal() as f32).collect::<Vec<_>>(),
        );
        let new = ParamVec(
            global
                .iter()
                .map(|g| g + rng.normal() as f32 * 0.1)
                .collect::<Vec<_>>(),
        );
        for spec in ["top_k(0.2)", "top_k_f16(0.2)", "top_k_i8(0.2)"] {
            let update = crate::codec::parse(spec)
                .unwrap()
                .encode(new.clone(), &global)
                .unwrap();
            assert!(matches!(update, Update::Encoded(_)), "{spec}");
            roundtrip(&Message::TrainReply {
                round: 9,
                client_index: 4,
                num_samples: 64,
                sum_loss: 3.5,
                correct: 41.0,
                compute_ms: 17.25,
                update,
            });
        }
    }

    #[test]
    fn decode_rejects_a_corrupted_encoded_payload() {
        // Flip one value byte inside an encoded frame: the integrity
        // hash must catch it at decode time, before the aggregator.
        let global = ParamVec::zeros(8);
        let new = ParamVec(vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0]);
        let update = crate::codec::parse("top_k(0.5)")
            .unwrap()
            .encode(new, &global)
            .unwrap();
        let msg = Message::TrainReply {
            round: 0,
            client_index: 0,
            num_samples: 1,
            sum_loss: 0.0,
            correct: 0.0,
            compute_ms: 0.0,
            update,
        };
        let mut enc = msg.encode();
        let n = enc.len();
        enc[n - 16] ^= 0x40; // inside the f32 values, ahead of the hash
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn metrics_messages_roundtrip() {
        roundtrip(&Message::MetricsRequest);
        roundtrip(&Message::MetricsReply {
            json: "{\"counters\":{\"rounds\":3}}".into(),
        });
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_tags() {
        let mut enc = Message::Ok.encode();
        enc.push(0xFF);
        assert!(Message::decode(&enc).is_err());
        assert!(Message::decode(&[200]).is_err());
        assert!(Message::decode(&[]).is_err());
    }

    #[test]
    fn prop_random_sparse_updates_roundtrip() {
        prop::check("sparse-roundtrip", 99, 40, |rng: &mut Rng| {
            let len = 1 + rng.below(1000) as usize;
            let k = 1 + rng.below(len as u64) as usize;
            let indices: Vec<u32> =
                (0..k).map(|_| rng.below(len as u64) as u32).collect();
            let signs: Vec<bool> = (0..k).map(|_| rng.uniform() < 0.5).collect();
            let m = Message::TrainReply {
                round: rng.below(1000) as u32,
                client_index: rng.below(4000) as u32,
                num_samples: rng.below(10_000) as u32,
                sum_loss: rng.normal(),
                correct: rng.uniform() * 100.0,
                compute_ms: rng.uniform() * 1e4,
                update: Update::SparseTernary {
                    len,
                    indices,
                    signs,
                    magnitude: rng.normal() as f32,
                },
            };
            let dec = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            crate::prop_assert!(dec == m, "mismatch after roundtrip");
            Ok(())
        });
    }
}
