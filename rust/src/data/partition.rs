//! Statistical-heterogeneity partitioners (paper §V-A).
//!
//! Produces per-client label distributions and sample counts for:
//! * IID — uniform classes, equal sizes;
//! * Realistic — per-writer Dirichlet(1) label skew + log-normal sizes
//!   (the natural heterogeneity of FEMNIST/Shakespeare);
//! * Dirichlet(α) — the Dir(α) class-proportion split of Wang et al.;
//! * ByClass(n) — each client holds exactly n classes (Zhao et al.).
//!
//! `unbalanced` layers log-normal sample counts on top of any of them
//! (the paper's "unbalanced data simulated by Dir(0.5)" uses the same
//! spread; we use log-normal σ=1 which produces the Fig 6(a) 4× fastest/
//! slowest ratio).

use crate::config::{DatasetKind, Partition};
use crate::data::ClientSpec;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::synth;

/// σ of the log-normal sample-count distribution for unbalanced data.
const UNBALANCE_SIGMA: f64 = 1.0;
/// Minimum samples any client holds.
const MIN_SAMPLES: usize = 8;

/// Build the client specs for a federation.
pub fn build_clients(
    kind: DatasetKind,
    num_clients: usize,
    partition: Partition,
    unbalanced: bool,
    max_samples: usize,
    rng: &mut Rng,
) -> Result<Vec<ClientSpec>> {
    if num_clients == 0 {
        return Err(Error::Config("num_clients must be > 0".into()));
    }
    let (num_classes, _, _) = synth::shape_of(kind);
    let mean = synth::natural_mean_samples(kind, num_clients);

    // Sample counts first (so unbalance is independent of label skew).
    let sizes = client_sizes(num_clients, mean, unbalanced || matches!(partition, Partition::Realistic), rng);

    let mut clients = Vec::with_capacity(num_clients);
    for (index, mut num_samples) in sizes.into_iter().enumerate() {
        if max_samples > 0 {
            num_samples = num_samples.min(max_samples);
        }
        let class_probs = match partition {
            Partition::Iid => vec![1.0 / num_classes as f64; num_classes],
            Partition::Realistic => rng.dirichlet(1.0, num_classes),
            Partition::Dirichlet(alpha) => rng.dirichlet(alpha, num_classes),
            Partition::ByClass(n) => {
                let n = n.min(num_classes);
                let picked = rng.choose_indices(num_classes, n);
                let mut probs = vec![0.0; num_classes];
                for &c in &picked {
                    probs[c] = 1.0 / n as f64;
                }
                probs
            }
        };
        clients.push(ClientSpec {
            index,
            num_samples,
            class_probs,
            style_seed: rng.next_u64(),
        });
    }
    Ok(clients)
}

fn client_sizes(
    num_clients: usize,
    mean: usize,
    unbalanced: bool,
    rng: &mut Rng,
) -> Vec<usize> {
    if !unbalanced {
        return vec![mean; num_clients];
    }
    // Log-normal with E[X] = mean: mu = ln(mean) - sigma^2/2.
    let mu = (mean as f64).ln() - UNBALANCE_SIGMA * UNBALANCE_SIGMA / 2.0;
    (0..num_clients)
        .map(|_| {
            let v = rng.log_normal(mu, UNBALANCE_SIGMA).round() as usize;
            v.clamp(MIN_SAMPLES, mean * 8)
        })
        .collect()
}

/// Degree of label-skew across the federation: average total-variation
/// distance between each client's label distribution and uniform.
/// 0 = IID; →1 = single-class clients. Used by tests and Table IV benches.
pub fn label_skew(clients: &[ClientSpec]) -> f64 {
    if clients.is_empty() {
        return 0.0;
    }
    let k = clients[0].class_probs.len() as f64;
    let uniform = 1.0 / k;
    clients
        .iter()
        .map(|c| {
            0.5 * c
                .class_probs
                .iter()
                .map(|p| (p - uniform).abs())
                .sum::<f64>()
        })
        .sum::<f64>()
        / clients.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk(p: Partition, unbalanced: bool) -> Vec<ClientSpec> {
        let mut rng = Rng::new(9);
        build_clients(DatasetKind::Cifar10, 50, p, unbalanced, 0, &mut rng).unwrap()
    }

    #[test]
    fn iid_is_uniform_and_equal() {
        let cs = mk(Partition::Iid, false);
        assert_eq!(cs.len(), 50);
        let sizes: Vec<usize> = cs.iter().map(|c| c.num_samples).collect();
        assert!(sizes.windows(2).all(|w| w[0] == w[1]));
        assert!(label_skew(&cs) < 1e-12);
    }

    #[test]
    fn byclass_holds_exactly_n_classes() {
        let cs = mk(Partition::ByClass(2), false);
        for c in &cs {
            let held = c.class_probs.iter().filter(|&&p| p > 0.0).count();
            assert_eq!(held, 2);
            assert!((c.class_probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn skew_ordering_matches_paper_table4() {
        // IID < dir(0.5) < class(3) < class(2): the Table IV degradation
        // order follows partition skew.
        let iid = label_skew(&mk(Partition::Iid, false));
        let dir = label_skew(&mk(Partition::Dirichlet(0.5), false));
        let c3 = label_skew(&mk(Partition::ByClass(3), false));
        let c2 = label_skew(&mk(Partition::ByClass(2), false));
        assert!(iid < dir && dir < c3 && c3 < c2, "{iid} {dir} {c3} {c2}");
    }

    #[test]
    fn unbalanced_sizes_have_spread() {
        let cs = mk(Partition::Iid, true);
        let min = cs.iter().map(|c| c.num_samples).min().unwrap();
        let max = cs.iter().map(|c| c.num_samples).max().unwrap();
        assert!(max as f64 / min as f64 > 3.0, "spread {min}..{max}");
    }

    #[test]
    fn prop_probs_always_normalized_and_sizes_positive() {
        prop::check("partition-normalized", 77, 40, |rng| {
            let n = 1 + rng.below(40) as usize;
            let part = match rng.below(4) {
                0 => Partition::Iid,
                1 => Partition::Realistic,
                2 => Partition::Dirichlet(0.1 + rng.uniform() * 5.0),
                _ => Partition::ByClass(1 + rng.below(10) as usize),
            };
            let cs = build_clients(
                DatasetKind::Cifar10,
                n,
                part,
                rng.uniform() < 0.5,
                0,
                rng,
            )
            .map_err(|e| e.to_string())?;
            crate::prop_assert!(cs.len() == n, "wrong client count");
            for c in &cs {
                let sum: f64 = c.class_probs.iter().sum();
                crate::prop_assert!(
                    (sum - 1.0).abs() < 1e-6,
                    "probs sum {sum} for {part:?}"
                );
                crate::prop_assert!(c.num_samples >= 1, "empty client");
                crate::prop_assert!(
                    c.class_probs.iter().all(|&p| p >= 0.0),
                    "negative prob"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn max_samples_caps() {
        let mut rng = Rng::new(1);
        let cs = build_clients(
            DatasetKind::Femnist,
            30,
            Partition::Realistic,
            true,
            64,
            &mut rng,
        )
        .unwrap();
        assert!(cs.iter().all(|c| c.num_samples <= 64));
    }
}
