//! Data manager (paper §V-A, Table III): federated datasets, statistical
//! heterogeneity simulation, and the dataset registry.
//!
//! Real FEMNIST / Shakespeare / CIFAR-10 are substituted by synthetic
//! generators with the same statistical *structure* (client counts,
//! class-conditional features, per-writer styles, label-skew partitions) —
//! DESIGN.md substitution #2. Samples are materialized **on demand** from
//! deterministic per-client seeds, so a 3550-client federation costs
//! kilobytes until a client is actually selected.

pub mod partition;
pub mod registry;
pub mod synth;

use std::sync::Arc;

use crate::config::{Config, DatasetKind, Partition};
use crate::error::{Error, Result};
use crate::model::InputDtype;
use crate::runtime::{Batch, Features};
use crate::util::rng::Rng;

/// Per-client metadata; features materialize lazily from `style_seed`.
#[derive(Debug, Clone)]
pub struct ClientSpec {
    pub index: usize,
    /// Natural (pre-`data_amount`) sample count.
    pub num_samples: usize,
    /// Label distribution this client draws from (statistical het.).
    pub class_probs: Vec<f64>,
    /// Seed for the client's writer style and sample stream.
    pub style_seed: u64,
}

/// A materialized local dataset (one client, or the global test split).
#[derive(Debug, Clone)]
pub struct LocalData {
    pub x: Features,
    pub y: Vec<i32>,
    pub num_samples: usize,
    /// Per-sample feature length.
    pub input_len: usize,
}

impl LocalData {
    /// Cut fixed-size batches with wrap-around padding + 0/1 masks.
    ///
    /// Every sample appears exactly once with mask 1; padding repeats
    /// earlier samples with mask 0 so it affects neither loss nor counts.
    pub fn batches(&self, batch_size: usize) -> Vec<Batch> {
        assert!(batch_size > 0);
        let n = self.num_samples;
        if n == 0 {
            return Vec::new();
        }
        let num_batches = n.div_ceil(batch_size);
        let mut out = Vec::with_capacity(num_batches);
        for b in 0..num_batches {
            let mut y = Vec::with_capacity(batch_size);
            let mut mask = Vec::with_capacity(batch_size);
            let mut idx = Vec::with_capacity(batch_size);
            for j in 0..batch_size {
                let i = b * batch_size + j;
                if i < n {
                    idx.push(i);
                    y.push(self.y[i]);
                    mask.push(1.0);
                } else {
                    let wrap = i % n;
                    idx.push(wrap);
                    y.push(self.y[wrap]);
                    mask.push(0.0);
                }
            }
            let x = match &self.x {
                Features::F32(v) => Features::F32(gather(v, &idx, self.input_len)),
                Features::I32(v) => Features::I32(gather(v, &idx, self.input_len)),
            };
            out.push(Batch { x, y, mask });
        }
        out
    }
}

fn gather<T: Copy>(v: &[T], idx: &[usize], stride: usize) -> Vec<T> {
    let mut out = Vec::with_capacity(idx.len() * stride);
    for &i in idx {
        out.extend_from_slice(&v[i * stride..(i + 1) * stride]);
    }
    out
}

/// A federated dataset: client specs + deterministic generators.
#[derive(Debug, Clone)]
pub struct FedDataset {
    pub kind: DatasetKind,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: InputDtype,
    pub clients: Vec<ClientSpec>,
    /// Base seed; all materialization derives from it.
    pub seed: u64,
    /// Class prototype vectors (image datasets) — see synth.rs.
    prototypes: Vec<Vec<f32>>,
}

impl FedDataset {
    /// Build the federation per the config's partition settings.
    pub fn from_config(cfg: &Config) -> Result<FedDataset> {
        let kind = cfg.dataset;
        let num_clients = if cfg.num_clients > 0 {
            cfg.num_clients
        } else {
            synth::natural_clients(kind)
        };
        if cfg.clients_per_round > num_clients {
            return Err(Error::Config(format!(
                "clients_per_round {} > clients {num_clients}",
                cfg.clients_per_round
            )));
        }
        let mut rng = Rng::new(cfg.seed ^ 0xDA7A_5EED);
        let clients = partition::build_clients(
            kind,
            num_clients,
            cfg.partition,
            cfg.unbalanced,
            cfg.max_samples,
            &mut rng,
        )?;
        let (num_classes, input_shape, input_dtype) = synth::shape_of(kind);
        let prototypes =
            synth::class_prototypes(kind, cfg.seed, num_classes, &input_shape);
        Ok(FedDataset {
            kind,
            num_classes,
            input_shape,
            input_dtype,
            clients,
            seed: cfg.seed,
            prototypes,
        })
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn total_samples(&self) -> usize {
        self.clients.iter().map(|c| c.num_samples).sum()
    }

    /// Materialize a client's local data. `data_amount ∈ (0,1]` scales the
    /// sample count (Fig 7b/c sweeps).
    pub fn materialize_client(&self, index: usize, data_amount: f64) -> Result<LocalData> {
        let spec = self.clients.get(index).ok_or_else(|| {
            Error::Config(format!("client {index} out of range"))
        })?;
        let n = ((spec.num_samples as f64 * data_amount).round() as usize).max(1);
        Ok(self.materialize(spec.style_seed, n, &spec.class_probs, 0.35))
    }

    /// Materialize an IID test split drawn from the global distribution.
    pub fn materialize_test(&self, n: usize) -> LocalData {
        let probs = vec![1.0 / self.num_classes as f64; self.num_classes];
        // Style strength 0 → test data has no writer-specific skew.
        self.materialize(self.seed ^ 0x7E57_DA7A, n, &probs, 0.0)
    }

    fn materialize(
        &self,
        seed: u64,
        n: usize,
        class_probs: &[f64],
        style_strength: f32,
    ) -> LocalData {
        let mut rng = Rng::new(seed);
        let input_len: usize = self.input_shape.iter().product();
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            y.push(sample_class(&mut rng, class_probs) as i32);
        }
        let x = synth::materialize_features(
            self.kind,
            &self.prototypes,
            &y,
            input_len,
            style_strength,
            &mut rng,
        );
        LocalData { x, y, num_samples: n, input_len }
    }
}

/// Self-register the built-in synthetic datasets and the four partition
/// schemes into the component registry. Each dataset builder forces its
/// own [`DatasetKind`] so `Config::data_source = Some("cifar10")` works
/// regardless of what `Config::dataset` says.
pub(crate) fn register_builtins(reg: &mut crate::registry::ComponentRegistry) {
    for kind in [
        DatasetKind::Femnist,
        DatasetKind::Shakespeare,
        DatasetKind::Cifar10,
    ] {
        reg.register_dataset(
            kind.name(),
            Arc::new(move |cfg: &Config| {
                let mut c = cfg.clone();
                c.dataset = kind;
                Ok(Arc::new(FedDataset::from_config(&c)?)
                    as Arc<dyn registry::DataSource>)
            }),
        );
    }
    // Partition specs all share Partition::parse; registering each head
    // separately gives unknown-name errors a precise catalog.
    for name in ["iid", "realistic", "dir", "class"] {
        reg.register_partition(name, Arc::new(Partition::parse));
    }
}

fn sample_class(rng: &mut Rng, probs: &[f64]) -> usize {
    let u = rng.uniform();
    let mut acc = 0.0;
    for (i, p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config {
            dataset: DatasetKind::Cifar10,
            num_clients: 20,
            clients_per_round: 5,
            partition: Partition::Iid,
            max_samples: 2000,
            ..Config::default()
        }
    }

    #[test]
    fn builds_federation_deterministically() {
        let a = FedDataset::from_config(&cfg()).unwrap();
        let b = FedDataset::from_config(&cfg()).unwrap();
        assert_eq!(a.num_clients(), 20);
        assert_eq!(a.clients[3].style_seed, b.clients[3].style_seed);
        assert_eq!(a.total_samples(), b.total_samples());
    }

    #[test]
    fn materialization_is_deterministic_and_shaped() {
        let ds = FedDataset::from_config(&cfg()).unwrap();
        let a = ds.materialize_client(2, 1.0).unwrap();
        let b = ds.materialize_client(2, 1.0).unwrap();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x, b.x);
        assert_eq!(a.x.len(), a.num_samples * a.input_len);
        assert!(a.y.iter().all(|&c| (c as usize) < ds.num_classes));
    }

    #[test]
    fn data_amount_scales_samples() {
        let ds = FedDataset::from_config(&cfg()).unwrap();
        let full = ds.materialize_client(0, 1.0).unwrap();
        let half = ds.materialize_client(0, 0.5).unwrap();
        assert!(half.num_samples <= full.num_samples / 2 + 1);
        assert!(half.num_samples >= 1);
    }

    #[test]
    fn batches_cover_every_sample_once_with_mask() {
        let ds = FedDataset::from_config(&cfg()).unwrap();
        let data = ds.materialize_client(1, 1.0).unwrap();
        let batches = data.batches(32);
        let total_mask: f32 = batches.iter().flat_map(|b| &b.mask).sum();
        assert_eq!(total_mask as usize, data.num_samples);
        for b in &batches {
            assert_eq!(b.y.len(), 32);
            assert_eq!(b.mask.len(), 32);
            assert_eq!(b.x.len(), 32 * data.input_len);
        }
    }

    #[test]
    fn test_split_is_class_balanced() {
        let ds = FedDataset::from_config(&cfg()).unwrap();
        let t = ds.materialize_test(2000);
        let mut counts = vec![0usize; ds.num_classes];
        for &c in &t.y {
            counts[c as usize] += 1;
        }
        for c in counts {
            assert!(c > 100, "class count {c} too skewed for IID test split");
        }
    }

    #[test]
    fn charcnn_features_are_i32_tokens() {
        let mut c = cfg();
        c.dataset = DatasetKind::Shakespeare;
        c.partition = Partition::Realistic;
        let ds = FedDataset::from_config(&c).unwrap();
        let d = ds.materialize_client(0, 1.0).unwrap();
        match &d.x {
            Features::I32(v) => {
                assert!(v.iter().all(|&t| (0..64).contains(&t)));
            }
            _ => panic!("shakespeare must be i32 tokens"),
        }
    }
}
