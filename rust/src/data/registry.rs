//! Dataset registry behind the `register_dataset` API (paper §IV-B).
//!
//! Users plug custom federated datasets into the platform without touching
//! the training flow: anything implementing [`DataSource`] can be
//! registered under a name and selected by config. The built-in synthetic
//! datasets are pre-registered.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::data::LocalData;
use crate::error::{Error, Result};

/// A pluggable federated data source.
pub trait DataSource: Send + Sync {
    /// Number of clients in the federation.
    fn num_clients(&self) -> usize;
    /// Materialize one client's local training data.
    fn client_data(&self, index: usize, data_amount: f64) -> Result<LocalData>;
    /// Materialize the global test split.
    fn test_data(&self, n: usize) -> Result<LocalData>;
    /// Natural sample count of a client (scheduling hints).
    fn client_samples(&self, index: usize) -> usize;
}

/// Adapter: [`crate::data::FedDataset`] as a [`DataSource`].
impl DataSource for crate::data::FedDataset {
    fn num_clients(&self) -> usize {
        self.clients.len()
    }

    fn client_data(&self, index: usize, data_amount: f64) -> Result<LocalData> {
        self.materialize_client(index, data_amount)
    }

    fn test_data(&self, n: usize) -> Result<LocalData> {
        Ok(self.materialize_test(n))
    }

    fn client_samples(&self, index: usize) -> usize {
        self.clients.get(index).map(|c| c.num_samples).unwrap_or(0)
    }
}

/// Name → data source registry.
#[derive(Default)]
pub struct DataRegistry {
    sources: BTreeMap<String, Arc<dyn DataSource>>,
}

impl DataRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a source under `name`.
    pub fn register(&mut self, name: &str, source: Arc<dyn DataSource>) {
        self.sources.insert(name.to_string(), source);
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn DataSource>> {
        self.sources.get(name).cloned().ok_or_else(|| {
            Error::Registry(format!(
                "no dataset {name:?} registered (have: {:?})",
                self.sources.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn names(&self) -> Vec<String> {
        self.sources.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, DatasetKind, Partition};
    use crate::data::FedDataset;

    #[test]
    fn register_and_lookup() {
        let cfg = Config {
            dataset: DatasetKind::Cifar10,
            num_clients: 5,
            clients_per_round: 2,
            partition: Partition::Iid,
            max_samples: 100,
            ..Config::default()
        };
        let ds = Arc::new(FedDataset::from_config(&cfg).unwrap());
        let mut reg = DataRegistry::new();
        reg.register("custom", ds.clone());
        let got = reg.get("custom").unwrap();
        assert_eq!(got.num_clients(), 5);
        assert!(got.client_samples(0) > 0);
        assert!(reg.get("nope").is_err());
        assert_eq!(reg.names(), vec!["custom"]);
    }
}
