//! The pluggable data-source contract behind `register_dataset`
//! (paper §IV-B).
//!
//! Users plug custom federated datasets into the platform without
//! touching the training flow: anything implementing [`DataSource`] can
//! go straight onto a session (`SessionBuilder::dataset`) or be
//! registered under a name in the component registry
//! ([`crate::registry::ComponentRegistry::register_dataset`]) and
//! selected by `Config::data_source`. The built-in synthetic datasets
//! are pre-registered there.

use crate::data::LocalData;
use crate::error::Result;

/// A pluggable federated data source.
pub trait DataSource: Send + Sync {
    /// Number of clients in the federation.
    fn num_clients(&self) -> usize;
    /// Materialize one client's local training data.
    fn client_data(&self, index: usize, data_amount: f64) -> Result<LocalData>;
    /// Materialize the global test split.
    fn test_data(&self, n: usize) -> Result<LocalData>;
    /// Natural sample count of a client (scheduling hints).
    fn client_samples(&self, index: usize) -> usize;
}

/// Adapter: [`crate::data::FedDataset`] as a [`DataSource`].
impl DataSource for crate::data::FedDataset {
    fn num_clients(&self) -> usize {
        self.clients.len()
    }

    fn client_data(&self, index: usize, data_amount: f64) -> Result<LocalData> {
        self.materialize_client(index, data_amount)
    }

    fn test_data(&self, n: usize) -> Result<LocalData> {
        Ok(self.materialize_test(n))
    }

    fn client_samples(&self, index: usize) -> usize {
        self.clients.get(index).map(|c| c.num_samples).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::config::{Config, DatasetKind, Partition};
    use crate::data::FedDataset;

    #[test]
    fn fed_dataset_adapts_as_data_source() {
        let cfg = Config {
            dataset: DatasetKind::Cifar10,
            num_clients: 5,
            clients_per_round: 2,
            partition: Partition::Iid,
            max_samples: 100,
            ..Config::default()
        };
        let ds: Arc<dyn DataSource> =
            Arc::new(FedDataset::from_config(&cfg).unwrap());
        assert_eq!(ds.num_clients(), 5);
        assert!(ds.client_samples(0) > 0);
        assert!(ds.client_data(0, 1.0).unwrap().num_samples > 0);
        assert_eq!(ds.test_data(32).unwrap().num_samples, 32);
    }
}
