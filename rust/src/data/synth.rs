//! Synthetic dataset generators (DESIGN.md substitution #2).
//!
//! Image datasets (FEMNIST / CIFAR-10 stand-ins) are class-conditional
//! Gaussians: each class has a deterministic prototype vector; a sample is
//! `prototype + writer_style·s + noise`. Writer styles give the realistic
//! partitions feature skew on top of label skew, like real federated
//! handwriting data.
//!
//! The Shakespeare stand-in emits 80-token windows from a deterministic
//! order-1 Markov chain; the label (next character) correlates strongly
//! with the window's last token, so the task is learnable while label
//! skew (`class_probs`) carries the heterogeneity.

use crate::config::DatasetKind;
use crate::model::InputDtype;
use crate::runtime::Features;
use crate::util::rng::Rng;

/// Character vocabulary of the Shakespeare stand-in (matches L2 model).
pub const CHAR_VOCAB: usize = 64;
/// Window length (matches L2 model).
pub const CHAR_SEQ: usize = 80;
/// Probability that the label equals the window's final token.
const LABEL_COUPLING: f64 = 0.9;
/// Additive noise σ for image samples.
const NOISE_SIGMA: f32 = 1.5;

/// Natural client counts (paper Table III).
pub fn natural_clients(kind: DatasetKind) -> usize {
    match kind {
        DatasetKind::Femnist => 3550,
        DatasetKind::Shakespeare => 1129,
        DatasetKind::Cifar10 => 100, // "flexible" in the paper
    }
}

/// Natural *average* samples per client.
///
/// FEMNIST: 805,263 / 3,550 ≈ 227. Shakespeare's natural 3,743 avg is
/// capped at 512 for CPU tractability (documented in DESIGN.md);
/// CIFAR-10: 60,000 split across the federation.
pub fn natural_mean_samples(kind: DatasetKind, num_clients: usize) -> usize {
    match kind {
        DatasetKind::Femnist => 227,
        DatasetKind::Shakespeare => 512,
        DatasetKind::Cifar10 => (60_000 / num_clients.max(1)).max(8),
    }
}

/// Paper Table III headline statistics for reporting benches.
pub fn table3_stats(kind: DatasetKind) -> (&'static str, usize, usize, &'static str) {
    match kind {
        DatasetKind::Femnist => ("FEMNIST", 805_263, 3550, "CNN (2 Conv + 2 FC) → mlp"),
        DatasetKind::Shakespeare => ("Shakespeare", 4_226_158, 1129, "RNN (2 LSTM + 1 FC) → charcnn"),
        DatasetKind::Cifar10 => ("CIFAR-10", 60_000, 0, "ResNet18 → cnn"),
    }
}

/// (num_classes, per-sample input shape, dtype) for a dataset kind.
pub fn shape_of(kind: DatasetKind) -> (usize, Vec<usize>, InputDtype) {
    match kind {
        DatasetKind::Femnist => (62, vec![784], InputDtype::F32),
        DatasetKind::Shakespeare => (CHAR_VOCAB, vec![CHAR_SEQ], InputDtype::I32),
        DatasetKind::Cifar10 => (10, vec![32, 32, 3], InputDtype::F32),
    }
}

/// Deterministic class prototypes (image kinds; empty for text).
pub fn class_prototypes(
    kind: DatasetKind,
    seed: u64,
    num_classes: usize,
    input_shape: &[usize],
) -> Vec<Vec<f32>> {
    if kind == DatasetKind::Shakespeare {
        return Vec::new();
    }
    let input_len: usize = input_shape.iter().product();
    (0..num_classes)
        .map(|c| {
            let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
            (0..input_len).map(|_| rng.normal() as f32).collect()
        })
        .collect()
}

/// Materialize features for a pre-sampled label vector.
pub fn materialize_features(
    kind: DatasetKind,
    prototypes: &[Vec<f32>],
    y: &[i32],
    input_len: usize,
    style_strength: f32,
    rng: &mut Rng,
) -> Features {
    match kind {
        DatasetKind::Shakespeare => {
            Features::I32(markov_windows(y, rng))
        }
        _ => {
            // Writer style: one deterministic offset vector per client.
            let style: Vec<f32> =
                (0..input_len).map(|_| rng.normal() as f32).collect();
            let mut out = Vec::with_capacity(y.len() * input_len);
            for &label in y {
                let proto = &prototypes[label as usize];
                for i in 0..input_len {
                    let noise = rng.normal() as f32 * NOISE_SIGMA;
                    out.push(proto[i] + style_strength * style[i] + noise);
                }
            }
            Features::F32(out)
        }
    }
}

/// Order-1 Markov windows whose final token predicts the label.
fn markov_windows(y: &[i32], rng: &mut Rng) -> Vec<i32> {
    let mut out = Vec::with_capacity(y.len() * CHAR_SEQ);
    for &label in y {
        let mut c = rng.below(CHAR_VOCAB as u64) as i32;
        for t in 0..CHAR_SEQ {
            if t == CHAR_SEQ - 1 {
                // Final token couples to the label (learnable signal).
                c = if rng.uniform() < LABEL_COUPLING {
                    label
                } else {
                    rng.below(CHAR_VOCAB as u64) as i32
                };
            } else {
                // Deterministic chain: next = a·c + b mod V, with jitter.
                let step = (5 * c + 17) % CHAR_VOCAB as i32;
                c = if rng.uniform() < 0.8 {
                    step
                } else {
                    rng.below(CHAR_VOCAB as u64) as i32
                };
            }
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_are_deterministic_and_distinct() {
        let a = class_prototypes(DatasetKind::Femnist, 1, 62, &[784]);
        let b = class_prototypes(DatasetKind::Femnist, 1, 62, &[784]);
        assert_eq!(a, b);
        assert_eq!(a.len(), 62);
        // Distinct classes have far-apart prototypes whp.
        let d: f32 = a[0]
            .iter()
            .zip(a[1].iter())
            .map(|(x, y)| (x - y).powi(2))
            .sum();
        assert!(d.sqrt() > 10.0);
    }

    #[test]
    fn markov_last_token_tracks_label() {
        let mut rng = Rng::new(3);
        let y: Vec<i32> = (0..500).map(|i| (i % 64) as i32).collect();
        let w = markov_windows(&y, &mut rng);
        let hits = y
            .iter()
            .enumerate()
            .filter(|(i, &label)| w[i * CHAR_SEQ + CHAR_SEQ - 1] == label)
            .count();
        assert!(hits > 400, "coupling too weak: {hits}/500");
    }

    #[test]
    fn natural_sizes_sane() {
        assert_eq!(natural_clients(DatasetKind::Femnist), 3550);
        assert_eq!(natural_clients(DatasetKind::Shakespeare), 1129);
        assert!(natural_mean_samples(DatasetKind::Cifar10, 100) == 600);
    }
}
