//! Decentralized gossip federation: serverless P2P rounds over peer
//! graphs.
//!
//! Every other engine in the platform — sync deadline, async FedBuff,
//! hierarchical client→edge→cloud — funnels updates through one trusted
//! coordinator. This subsystem removes it: clients exchange updates
//! directly with their neighbors on a seed-deterministic [`PeerGraph`]
//! and fold what they receive through the same registered streaming
//! aggregators the server engines use, so `bytes_to_cloud` is zero *by
//! construction* and robustness rules (`trimmed_mean`, `median`,
//! `krum`) apply per-neighborhood.
//!
//! Selecting it is pure config, like every other flow abstraction:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.sim.engine = "gossip".into();   // serverless rounds
//! cfg.topology = "gossip(8)".into();  // 8-regular peer graph
//! let report = easyfl::simnet::simulate(&cfg).unwrap();
//! assert_eq!(report.bytes_to_cloud, 0);
//! # let _ = report.consensus_distance;
//! ```
//!
//! Two layers live here; the event-level driver (per-edge upload
//! costing, dropout, chaos, checkpointing) is `SimNet::run_gossip` in
//! the simnet module, which owns clocks and clients:
//!
//! * [`PeerGraph`] — seed-deterministic `gossip(k)` k-regular graphs
//!   and the degree-2 `ring`, registered as topology specs beside
//!   `flat` / `edges(n)` / `clusters(file)`, with degree/parity and
//!   BFS-connectivity validation.
//! * [`GossipEngine`] — the pure per-client state machine: local drift,
//!   neighborhood folds, ring all-reduce, and the consensus-distance
//!   metric (max pairwise L∞ divergence) that `SimReport` surfaces.

mod engine;
mod graph;

pub use engine::GossipEngine;
pub use graph::PeerGraph;
