//! GossipEngine — the serverless consensus state machine.
//!
//! The engine owns one surrogate parameter vector per client and steps
//! them through gossip rounds: every participating client takes a local
//! training step (a fixed per-client drift direction with geometrically
//! decaying magnitude — clients pull *apart*), broadcasts its state to
//! its [`PeerGraph`] neighbors, and folds what it received through a
//! registered streaming [`Aggregator`] (clients pull *together*). With
//! the plain mean this is classic gossip averaging; with
//! `trimmed_mean` / `median` / `krum` each neighborhood fold is
//! Byzantine-robust, so the adversary plane composes per-neighborhood
//! exactly as it does per-cohort on the server engines.
//!
//! The engine is deliberately a *pure* state machine: all randomness
//! (initial states, drift directions) is drawn once at construction
//! from the RNG the caller passes in, and `local_train` / `exchange`
//! draw nothing. That is what makes gossip checkpointing cheap — a
//! snapshot is just the state matrix plus the round counter, and resume
//! rebuilds the graph and drift table from the same seed.
//!
//! Progress is measured by **consensus distance**: the maximum
//! per-coordinate spread (`max − min`) across honest clients, i.e. the
//! exact maximum pairwise L∞ divergence. It starts at the initial
//! spread, shrinks geometrically as gossip mixes, and stalls if the
//! graph is too sparse or an adversary keeps re-injecting outliers —
//! which is exactly the signal a federation operator needs.

use crate::aggregate::Aggregator;
use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;
use crate::util::rng::Rng;

use super::graph::PeerGraph;

/// Standard deviation of the initial per-coordinate states: peers start
/// genuinely disagreeing, so consensus distance has something to shrink.
const INIT_SPREAD: f64 = 1.0;

/// Scale of the per-client drift direction applied by `local_train`.
const DRIFT_SCALE: f64 = 0.1;

/// Geometric decay of the drift magnitude per round — local training
/// converges, so later rounds perturb less and consensus can close.
const DRIFT_DECAY: f64 = 0.8;

/// Per-client surrogate states evolving under drift + neighborhood
/// folds over a fixed peer graph.
pub struct GossipEngine {
    graph: PeerGraph,
    dim: usize,
    /// `n × dim` flattened current parameter state per client.
    states: Vec<f32>,
    /// `n × dim` fixed per-client drift directions (seed-deterministic,
    /// rebuilt identically on resume — never checkpointed).
    grads: Vec<f32>,
    /// Local-training steps applied so far (== closed gossip rounds).
    round: usize,
    /// Double buffer for synchronous folds.
    scratch: Vec<f32>,
}

impl GossipEngine {
    /// Draw initial states and drift directions. This is the only place
    /// the engine consumes randomness.
    pub fn new(graph: PeerGraph, dim: usize, rng: &mut Rng) -> GossipEngine {
        let n = graph.n();
        let states: Vec<f32> = (0..n * dim)
            .map(|_| (rng.normal() * INIT_SPREAD) as f32)
            .collect();
        let grads: Vec<f32> = (0..n * dim)
            .map(|_| (rng.normal() * DRIFT_SCALE) as f32)
            .collect();
        let scratch = states.clone();
        GossipEngine { graph, dim, states, grads, round: 0, scratch }
    }

    /// The wiring diagram the engine folds over.
    pub fn graph(&self) -> &PeerGraph {
        &self.graph
    }

    /// Closed rounds so far.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Flattened `n × dim` state matrix (checkpoint snapshot source).
    pub fn states(&self) -> &[f32] {
        &self.states
    }

    /// Client `c`'s current state.
    pub fn state(&self, c: usize) -> &[f32] {
        &self.states[c * self.dim..(c + 1) * self.dim]
    }

    /// Overwrite state matrix + round counter from a checkpoint.
    pub fn restore(&mut self, round: usize, states: Vec<f32>) -> Result<()> {
        if states.len() != self.states.len() {
            return Err(Error::Integrity(format!(
                "gossip checkpoint carries {} state words, engine needs {}",
                states.len(),
                self.states.len()
            )));
        }
        self.states = states;
        self.scratch = self.states.clone();
        self.round = round;
        Ok(())
    }

    /// One local training step for every participating client: add its
    /// drift direction scaled by `DRIFT_DECAY^round`. Draws no RNG.
    pub fn local_train(&mut self, participating: &[bool]) {
        let scale = DRIFT_DECAY.powi(self.round as i32) as f32;
        for c in 0..self.graph.n() {
            if !participating[c] {
                continue;
            }
            let base = c * self.dim;
            for p in 0..self.dim {
                self.states[base + p] += self.grads[base + p] * scale;
            }
        }
        self.round += 1;
    }

    /// Synchronous neighborhood fold: every participating client folds
    /// its own (true) state with the *broadcast* states of its
    /// participating neighbors through `agg`, all against the previous
    /// round's snapshot (double-buffered, so fold order across clients
    /// cannot matter). Non-participants keep their state.
    ///
    /// `broadcasts` is what each client *claims* its state is — the
    /// caller corrupts adversarial rows before handing it in, so a liar
    /// poisons its neighbors but never its own copy.
    pub fn exchange(
        &mut self,
        participating: &[bool],
        broadcasts: &[f32],
        agg: &mut dyn Aggregator,
    ) -> Result<()> {
        let (n, dim) = (self.graph.n(), self.dim);
        debug_assert_eq!(broadcasts.len(), n * dim);
        for c in 0..n {
            let dst = c * dim;
            if !participating[c] {
                self.scratch[dst..dst + dim]
                    .copy_from_slice(&self.states[dst..dst + dim]);
                continue;
            }
            agg.add(
                &Update::Dense(ParamVec(
                    self.states[dst..dst + dim].to_vec(),
                )),
                1.0,
            )?;
            for &j in self.graph.neighbors(c) {
                if participating[j] {
                    let src = j * dim;
                    agg.add(
                        &Update::Dense(ParamVec(
                            broadcasts[src..src + dim].to_vec(),
                        )),
                        1.0,
                    )?;
                }
            }
            let folded = agg.finish()?;
            self.scratch[dst..dst + dim].copy_from_slice(&folded.0);
        }
        std::mem::swap(&mut self.states, &mut self.scratch);
        Ok(())
    }

    /// Ring all-reduce: one global fold of every participant's
    /// broadcast, then every participant adopts the result. On the
    /// degree-2 ring this is the classic allreduce outcome; robust
    /// aggregators make it a Byzantine-filtered allreduce.
    pub fn ring_all_reduce(
        &mut self,
        participating: &[bool],
        broadcasts: &[f32],
        agg: &mut dyn Aggregator,
    ) -> Result<()> {
        let (n, dim) = (self.graph.n(), self.dim);
        debug_assert_eq!(broadcasts.len(), n * dim);
        let mut any = false;
        for c in 0..n {
            if participating[c] {
                let src = c * dim;
                agg.add(
                    &Update::Dense(ParamVec(
                        broadcasts[src..src + dim].to_vec(),
                    )),
                    1.0,
                )?;
                any = true;
            }
        }
        if !any {
            return Ok(());
        }
        let folded = agg.finish()?;
        for c in 0..n {
            if participating[c] {
                let dst = c * dim;
                self.states[dst..dst + dim].copy_from_slice(&folded.0);
            }
        }
        Ok(())
    }

    /// Maximum pairwise L∞ divergence across the flagged clients:
    /// `max_p (max_i x_ip − min_i x_ip)`. Exact (not sampled), O(n·dim).
    /// The mask selects whose divergence counts — pass the honest set so
    /// an adversary's own outlier state does not inflate the metric.
    pub fn consensus_distance(&self, mask: &[bool]) -> f64 {
        let (n, dim) = (self.graph.n(), self.dim);
        let mut worst = 0.0f64;
        for p in 0..dim {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for c in 0..n {
                if mask[c] {
                    let v = self.states[c * dim + p] as f64;
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            if hi >= lo {
                worst = worst.max(hi - lo);
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::aggregate::AggContext;
    use crate::registry;

    const DIM: usize = 8;

    fn engine(n: usize, k: usize, seed: u64) -> GossipEngine {
        let mut rng = Rng::new(seed);
        let graph = PeerGraph::build("gossip", k, n, &mut rng).unwrap();
        GossipEngine::new(graph, DIM, &mut rng)
    }

    fn mean_agg() -> Box<dyn Aggregator> {
        let ctx = AggContext::new(Arc::new(ParamVec::zeros(DIM)));
        registry::with_global(|r| r.aggregator("mean", &ctx)).unwrap()
    }

    #[test]
    fn gossip_rounds_shrink_consensus_distance() {
        let mut e = engine(40, 4, 9);
        let all = vec![true; 40];
        let d0 = e.consensus_distance(&all);
        assert!(d0 > 0.5, "initial states should disagree, got {d0}");
        let mut agg = mean_agg();
        for _ in 0..30 {
            e.local_train(&all);
            let broadcasts = e.states().to_vec();
            e.exchange(&all, &broadcasts, agg.as_mut()).unwrap();
        }
        let d = e.consensus_distance(&all);
        assert!(
            d < d0 / 4.0,
            "30 gossip rounds should mix: {d0} -> {d}"
        );
    }

    #[test]
    fn ring_all_reduce_reaches_exact_consensus_in_one_fold() {
        let mut rng = Rng::new(5);
        let graph = PeerGraph::build("ring", 2, 16, &mut rng).unwrap();
        let mut e = GossipEngine::new(graph, DIM, &mut rng);
        let all = vec![true; 16];
        let mut agg = mean_agg();
        e.local_train(&all);
        let broadcasts = e.states().to_vec();
        e.ring_all_reduce(&all, &broadcasts, agg.as_mut()).unwrap();
        let d = e.consensus_distance(&all);
        assert!(
            d < 1e-5,
            "all-reduce puts every participant on one state, got {d}"
        );
    }

    #[test]
    fn non_participants_keep_their_state() {
        let mut e = engine(10, 4, 3);
        let mut part = vec![true; 10];
        part[7] = false;
        let before = e.state(7).to_vec();
        let mut agg = mean_agg();
        e.local_train(&part);
        let broadcasts = e.states().to_vec();
        e.exchange(&part, &broadcasts, agg.as_mut()).unwrap();
        assert_eq!(e.state(7), &before[..], "offline peer must not move");
    }

    #[test]
    fn snapshot_restore_is_exact() {
        let mut e = engine(12, 4, 21);
        let all = vec![true; 12];
        let mut agg = mean_agg();
        for _ in 0..3 {
            e.local_train(&all);
            let b = e.states().to_vec();
            e.exchange(&all, &b, agg.as_mut()).unwrap();
        }
        let snap = e.states().to_vec();
        let round = e.round();
        // A fresh engine from the same seed, restored, then stepped,
        // must match the original stepped forward.
        let mut f = engine(12, 4, 21);
        f.restore(round, snap.clone()).unwrap();
        for eng in [&mut e, &mut f] {
            eng.local_train(&all);
            let b = eng.states().to_vec();
            eng.exchange(&all, &b, agg.as_mut()).unwrap();
        }
        assert_eq!(e.states(), f.states());
        // Wrong-length restore is an integrity error.
        assert!(f.restore(round, vec![0.0; 3]).is_err());
    }
}
