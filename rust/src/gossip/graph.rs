//! PeerGraph — seed-deterministic peer graphs for serverless rounds.
//!
//! A [`PeerGraph`] is the wiring diagram of a decentralized federation:
//! every client owns exactly `k` undirected links and exchanges updates
//! only over those links, so no coordinator ever sees an update. Two
//! families are constructible:
//!
//! * **`gossip(k)`** — a k-regular circulant: clients are laid out on a
//!   seed-permuted cycle and linked to the `k/2` nearest positions on
//!   each side (odd `k` adds the diameter chord, which needs an even
//!   population). Offset 1 alone already makes the graph connected; the
//!   extra chords shrink its diameter so consensus spreads in
//!   `O(n / k)` hops.
//! * **`ring`** — the degree-2 cycle itself, the classic all-reduce
//!   substrate.
//!
//! The node permutation is drawn from a dedicated RNG stream, so the
//! same `(seed, n, k)` always yields the same graph — a requirement for
//! bit-reproducible simulations and checkpoint resume — while different
//! seeds decorrelate neighborhoods. Construction validates degree
//! bounds and parity up front and BFS-checks connectivity afterwards:
//! a partitioned peer graph would silently stall consensus, so it is a
//! config error, not a runtime surprise.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// An undirected k-regular peer graph over `n` clients.
///
/// Adjacency is stored flattened (`n × k`, stride `k`) with each
/// client's neighbor list sorted ascending, so iteration order — and
/// therefore every downstream fold — is deterministic.
#[derive(Debug, Clone)]
pub struct PeerGraph {
    n: usize,
    k: usize,
    /// Flattened adjacency: client `c`'s neighbors occupy
    /// `[c*k, (c+1)*k)`, sorted ascending.
    neighbors: Vec<usize>,
    /// Spec head this graph was built from (`"gossip"` / `"ring"`).
    kind: &'static str,
}

impl PeerGraph {
    /// Check `(k, n)` feasibility without building anything — used by
    /// `SimNet::from_config` to fail fast at construction time.
    pub fn validate_dims(kind: &str, k: usize, n: usize) -> Result<()> {
        if n < 3 {
            return Err(Error::Config(format!(
                "{kind} topology needs at least 3 clients, got {n}"
            )));
        }
        if k < 2 || k >= n {
            return Err(Error::Config(format!(
                "{kind} degree k={k} must satisfy 2 <= k < n (n={n})"
            )));
        }
        if k % 2 == 1 && n % 2 == 1 {
            return Err(Error::Config(format!(
                "{kind} with odd degree k={k} needs an even population \
                 (got n={n}): the diameter chord must pair clients up"
            )));
        }
        Ok(())
    }

    /// Build the seed-deterministic k-regular graph. The permutation is
    /// the only randomness; everything after it is structural.
    pub fn build(
        kind: &'static str,
        k: usize,
        n: usize,
        rng: &mut Rng,
    ) -> Result<PeerGraph> {
        PeerGraph::validate_dims(kind, k, n)?;
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut neighbors = vec![0usize; n * k];
        for pos in 0..n {
            let c = perm[pos];
            let mut slot = c * k;
            for off in 1..=(k / 2) {
                neighbors[slot] = perm[(pos + off) % n];
                neighbors[slot + 1] = perm[(pos + n - off) % n];
                slot += 2;
            }
            if k % 2 == 1 {
                neighbors[slot] = perm[(pos + n / 2) % n];
            }
        }
        for c in 0..n {
            neighbors[c * k..(c + 1) * k].sort_unstable();
        }
        let graph = PeerGraph { n, k, neighbors, kind };
        graph.check_connected()?;
        Ok(graph)
    }

    /// BFS connectivity check: every client must reach every other, or
    /// gossip consensus can never close the gap between components.
    fn check_connected(&self) -> Result<()> {
        let mut seen = vec![false; self.n];
        let mut frontier = vec![0usize];
        seen[0] = true;
        let mut visited = 1usize;
        while let Some(c) = frontier.pop() {
            for &j in self.neighbors(c) {
                if !seen[j] {
                    seen[j] = true;
                    visited += 1;
                    frontier.push(j);
                }
            }
        }
        if visited != self.n {
            return Err(Error::Config(format!(
                "{} peer graph is disconnected: BFS reached {visited} of \
                 {} clients",
                self.kind, self.n
            )));
        }
        Ok(())
    }

    /// Population size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Uniform degree `k` — every client sends to exactly this many
    /// peers per round, which is what the cost model charges.
    pub fn degree(&self) -> usize {
        self.k
    }

    /// Undirected edge count (`n·k / 2`).
    pub fn num_edges(&self) -> usize {
        self.n * self.k / 2
    }

    /// Client `c`'s neighbors, sorted ascending.
    pub fn neighbors(&self, c: usize) -> &[usize] {
        &self.neighbors[c * self.k..(c + 1) * self.k]
    }

    /// Spec head this graph was built from.
    pub fn kind(&self) -> &'static str {
        self.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn degrees_ok(g: &PeerGraph) {
        for c in 0..g.n() {
            let nb = g.neighbors(c);
            assert_eq!(nb.len(), g.degree());
            // No self-loops, no duplicate edges (sorted ⇒ adjacent dups).
            assert!(nb.iter().all(|&j| j != c), "self-loop at {c}");
            assert!(
                nb.windows(2).all(|w| w[0] < w[1]),
                "duplicate neighbor at {c}: {nb:?}"
            );
            // Undirected: every link appears from both ends.
            for &j in nb {
                assert!(
                    g.neighbors(j).contains(&c),
                    "edge {c}->{j} missing its reverse"
                );
            }
        }
    }

    #[test]
    fn gossip_graphs_are_k_regular_symmetric_and_connected() {
        for (k, n) in [(2, 5), (4, 9), (8, 100), (3, 10), (5, 64)] {
            let mut rng = Rng::new(7);
            let g = PeerGraph::build("gossip", k, n, &mut rng).unwrap();
            degrees_ok(&g);
            assert_eq!(g.num_edges(), n * k / 2);
        }
    }

    #[test]
    fn ring_is_the_degree_two_cycle() {
        let mut rng = Rng::new(11);
        let g = PeerGraph::build("ring", 2, 12, &mut rng).unwrap();
        degrees_ok(&g);
        assert_eq!(g.degree(), 2);
        assert_eq!(g.num_edges(), 12);
    }

    #[test]
    fn same_seed_reproduces_the_graph_and_different_seeds_differ() {
        let build = |seed| {
            let mut rng = Rng::new(seed);
            PeerGraph::build("gossip", 4, 50, &mut rng).unwrap()
        };
        let a = build(3);
        let b = build(3);
        assert_eq!(a.neighbors, b.neighbors);
        let c = build(4);
        assert_ne!(
            a.neighbors, c.neighbors,
            "distinct seeds should permute the graph differently"
        );
    }

    #[test]
    fn infeasible_dims_are_config_errors() {
        let mut rng = Rng::new(1);
        // Too few clients.
        assert!(PeerGraph::build("gossip", 2, 2, &mut rng).is_err());
        // Degree out of range.
        assert!(PeerGraph::build("gossip", 1, 10, &mut rng).is_err());
        assert!(PeerGraph::build("gossip", 10, 10, &mut rng).is_err());
        // Odd degree needs an even population.
        assert!(PeerGraph::build("gossip", 3, 9, &mut rng).is_err());
        assert!(PeerGraph::validate_dims("gossip", 3, 9).is_err());
        assert!(PeerGraph::validate_dims("gossip", 8, 100).is_ok());
    }
}
