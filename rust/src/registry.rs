//! Component registry: the low-code core of the interface layer.
//!
//! The paper's promise is that any FL application is a *configuration*,
//! not a wiring exercise. This module makes that real: algorithms,
//! data sources, partitions and server flows self-register under string
//! names with typed constructor closures, and `easyfl::init` resolves a
//! [`Config`]'s `algorithm` / `data_source` / `partition` strings into
//! live components. A new algorithm becomes selectable from JSON config
//! (or three lines of Rust) by registering one closure:
//!
//! ```no_run
//! use easyfl::registry::{self, AlgorithmParts};
//! registry::register(|reg| {
//!     reg.register_algorithm("my-fedavg", std::sync::Arc::new(|_cfg| {
//!         Ok(AlgorithmParts {
//!             server_flow: Box::new(easyfl::flow::DefaultServerFlow),
//!             client_factory: easyfl::algorithms::fedavg_client_factory(),
//!         })
//!     }));
//! });
//! let mut cfg = easyfl::Config::default();
//! cfg.algorithm = "my-fedavg".into();
//! let report = easyfl::init(cfg).unwrap().run().unwrap();
//! ```
//!
//! Built-ins (fedavg / fedprox / stc / fedreid, the three paper datasets,
//! the four partition schemes) are installed by their own modules on
//! first access, so lookups always see the full catalog.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::aggregate::{AggContext, Aggregator, AggregatorBuilder};
use crate::codec::UpdateCodec;
use crate::config::{Config, Partition};
use crate::coordinator::ClientFlowFactory;
use crate::data::registry::DataSource;
use crate::error::{Error, Result};
use crate::flow::ServerFlow;
use crate::hierarchy::Topology;
use crate::simnet::{
    AdversaryModel, AvailabilityModel, ChurnModel, CostModel, Fault,
};

/// Everything an algorithm contributes to a session: the server half and
/// a per-device factory for the client half of the training flow.
pub struct AlgorithmParts {
    pub server_flow: Box<dyn ServerFlow>,
    pub client_factory: ClientFlowFactory,
}

/// Constructor closure for an algorithm (reads its params off the config).
pub type AlgorithmBuilder =
    Arc<dyn Fn(&Config) -> Result<AlgorithmParts> + Send + Sync>;

/// Constructor closure for a data source.
pub type DatasetBuilder =
    Arc<dyn Fn(&Config) -> Result<Arc<dyn DataSource>> + Send + Sync>;

/// Parser closure for a partition spec (receives the full spec string,
/// e.g. `"dir(0.5)"` for the registered name `"dir"`).
pub type PartitionParser =
    Arc<dyn Fn(&str) -> Result<Partition> + Send + Sync>;

/// Constructor closure for a standalone server flow (remote coordinator,
/// custom selection policies).
pub type ServerFlowBuilder =
    Arc<dyn Fn(&Config) -> Result<Box<dyn ServerFlow>> + Send + Sync>;

/// Parser closure for a SimNet availability spec (receives the full spec
/// string, e.g. `"diurnal(0.4)"` for the registered name `"diurnal"`).
pub type AvailabilityBuilder =
    Arc<dyn Fn(&str) -> Result<AvailabilityModel> + Send + Sync>;

/// Constructor closure for a SimNet cost model (reads `cfg.sim` tuning).
pub type CostModelBuilder =
    Arc<dyn Fn(&Config) -> Result<CostModel> + Send + Sync>;

/// Parser closure for a SimNet adversary spec (receives the full spec
/// string, e.g. `"scaled-noise(20)"` for the registered name
/// `"scaled-noise"`).
pub type AdversaryBuilder =
    Arc<dyn Fn(&str) -> Result<AdversaryModel> + Send + Sync>;

/// Parser closure for a federation topology spec (receives the full
/// spec string, e.g. `"edges(16)"` for the registered name `"edges"`).
pub type TopologyBuilder =
    Arc<dyn Fn(&str) -> Result<Topology> + Send + Sync>;

/// Parser closure for an update-codec spec (receives the full spec
/// string, e.g. `"top_k_i8(0.05)"` for the registered name
/// `"top_k_i8"`).
pub type CodecBuilder =
    Arc<dyn Fn(&str) -> Result<Arc<dyn UpdateCodec>> + Send + Sync>;

/// Parser closure for an elastic-membership churn spec (receives the
/// full spec string, e.g. `"flux(2,1)"` for the registered name
/// `"flux"`).
pub type ChurnBuilder =
    Arc<dyn Fn(&str) -> Result<ChurnModel> + Send + Sync>;

/// Parser closure for a chaos-plane fault spec (receives the full spec
/// string, e.g. `"kill_server_at_round(10)"` for the registered name
/// `"kill_server_at_round"`).
pub type FaultBuilder = Arc<dyn Fn(&str) -> Result<Fault> + Send + Sync>;

/// Name → constructor tables for every pluggable component kind.
#[derive(Default)]
pub struct ComponentRegistry {
    algorithms: BTreeMap<String, AlgorithmBuilder>,
    datasets: BTreeMap<String, DatasetBuilder>,
    partitions: BTreeMap<String, PartitionParser>,
    server_flows: BTreeMap<String, ServerFlowBuilder>,
    availability: BTreeMap<String, AvailabilityBuilder>,
    cost_models: BTreeMap<String, CostModelBuilder>,
    aggregators: BTreeMap<String, AggregatorBuilder>,
    adversaries: BTreeMap<String, AdversaryBuilder>,
    topologies: BTreeMap<String, TopologyBuilder>,
    codecs: BTreeMap<String, CodecBuilder>,
    churn: BTreeMap<String, ChurnBuilder>,
    faults: BTreeMap<String, FaultBuilder>,
}

fn unknown(kind: &str, name: &str, have: Vec<&String>) -> Error {
    let names: Vec<&str> = have.iter().map(|s| s.as_str()).collect();
    Error::Config(format!(
        "unknown {kind} {name:?} (registered: {})",
        names.join(", ")
    ))
}

/// Normalized head of a parameterized component spec: `"dir(0.5)"` →
/// `"dir"`, `"scaled-noise(20)"` → `"scaled-noise"`. Shared by every
/// spec-keyed lookup and parser so name resolution cannot diverge.
pub(crate) fn spec_head(spec: &str) -> String {
    spec.split('(')
        .next()
        .unwrap_or(spec)
        .trim()
        .to_ascii_lowercase()
}

/// Paren-wrapped argument of a parameterized spec: `"edges(16)"` →
/// `Some("16")`, `"trace(dev.json)"` → `Some("dev.json")`, `"flat"` →
/// `None`. Shared by spec parsers whose argument is not numeric (file
/// paths) so extraction cannot diverge from [`spec_head`].
pub(crate) fn spec_inner(spec: &str) -> Option<&str> {
    spec.find('(')
        .map(|i| &spec[i + 1..])
        .and_then(|r| r.strip_suffix(')'))
        .map(str::trim)
}

impl ComponentRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry pre-populated with every built-in component.
    pub fn with_builtins() -> Self {
        let mut reg = Self::new();
        crate::aggregate::register_builtins(&mut reg);
        crate::algorithms::register_builtins(&mut reg);
        crate::codec::register_builtins(&mut reg);
        crate::data::register_builtins(&mut reg);
        crate::flow::register_builtins(&mut reg);
        crate::hierarchy::register_builtins(&mut reg);
        crate::simnet::register_builtins(&mut reg);
        reg
    }

    // ------------------------------------------------------ registration

    /// Register (or replace) an algorithm under `name`.
    pub fn register_algorithm(&mut self, name: &str, b: AlgorithmBuilder) {
        self.algorithms.insert(name.to_string(), b);
    }

    /// Register (or replace) a data source under `name`
    /// (selected via `Config::data_source`).
    pub fn register_dataset(&mut self, name: &str, b: DatasetBuilder) {
        self.datasets.insert(name.to_string(), b);
    }

    /// Register (or replace) a partition parser. `name` is the spec head:
    /// the spec `"dir(0.5)"` resolves the parser registered as `"dir"`.
    pub fn register_partition(&mut self, name: &str, p: PartitionParser) {
        self.partitions.insert(name.to_string(), p);
    }

    /// Register (or replace) a standalone server flow under `name`.
    pub fn register_server_flow(&mut self, name: &str, b: ServerFlowBuilder) {
        self.server_flows.insert(name.to_string(), b);
    }

    /// Register (or replace) a SimNet availability model. `name` is the
    /// spec head: `"diurnal(0.4)"` resolves the parser registered as
    /// `"diurnal"`.
    pub fn register_availability(&mut self, name: &str, b: AvailabilityBuilder) {
        self.availability.insert(name.to_string(), b);
    }

    /// Register (or replace) a SimNet cost model under `name`.
    pub fn register_cost_model(&mut self, name: &str, b: CostModelBuilder) {
        self.cost_models.insert(name.to_string(), b);
    }

    /// Register (or replace) a streaming aggregator under `name`
    /// (selected via [`crate::flow::ServerFlow::aggregator_name`]).
    pub fn register_aggregator(&mut self, name: &str, b: AggregatorBuilder) {
        self.aggregators.insert(name.to_string(), b);
    }

    /// Register (or replace) a SimNet adversary model. `name` is the
    /// spec head: `"scaled-noise(20)"` resolves the parser registered
    /// as `"scaled-noise"`.
    pub fn register_adversary(&mut self, name: &str, b: AdversaryBuilder) {
        self.adversaries.insert(name.to_string(), b);
    }

    /// Register (or replace) a federation topology. `name` is the spec
    /// head: `"edges(16)"` resolves the parser registered as `"edges"`
    /// (selected via `Config.topology`).
    pub fn register_topology(&mut self, name: &str, b: TopologyBuilder) {
        self.topologies.insert(name.to_string(), b);
    }

    /// Register (or replace) an update codec. `name` is the spec head:
    /// `"top_k_i8(0.05)"` resolves the parser registered as
    /// `"top_k_i8"` (selected via `Config.codec`).
    pub fn register_codec(&mut self, name: &str, b: CodecBuilder) {
        self.codecs.insert(name.to_string(), b);
    }

    /// Register (or replace) an elastic-membership churn model. `name`
    /// is the spec head: `"flux(2,1)"` resolves the parser registered
    /// as `"flux"` (selected via `Config.sim.churn`).
    pub fn register_churn(&mut self, name: &str, b: ChurnBuilder) {
        self.churn.insert(name.to_string(), b);
    }

    /// Register (or replace) a chaos-plane fault. `name` is the spec
    /// head: `"drop_frames(0.05)"` resolves the parser registered as
    /// `"drop_frames"` (selected via the `Config.chaos` list).
    pub fn register_fault(&mut self, name: &str, b: FaultBuilder) {
        self.faults.insert(name.to_string(), b);
    }

    // ------------------------------------------------------------ lookup

    /// Instantiate the algorithm a config selects. When `cfg.codec` is
    /// set, the client factory is wrapped so every flow compresses
    /// through the selected codec (the codec stage replaces the
    /// algorithm's own `compress`); unset keeps the algorithm's flow
    /// untouched, bit-for-bit.
    pub fn algorithm(&self, cfg: &Config) -> Result<AlgorithmParts> {
        let mut parts = match self.algorithms.get(cfg.algorithm.as_str()) {
            Some(b) => b(cfg)?,
            None => {
                return Err(unknown(
                    "algorithm",
                    &cfg.algorithm,
                    self.algorithms.keys().collect(),
                ))
            }
        };
        if let Some(spec) = &cfg.codec {
            let codec = self.codec(spec)?;
            parts.client_factory = crate::codec::wrap_client_factory(
                parts.client_factory,
                codec,
                cfg.codec_error_feedback,
            );
        }
        Ok(parts)
    }

    /// True when an algorithm name is registered (cheap pre-flight check).
    pub fn has_algorithm(&self, name: &str) -> bool {
        self.algorithms.contains_key(name)
    }

    /// True when a data-source name is registered (cheap pre-flight check).
    pub fn has_dataset(&self, name: &str) -> bool {
        self.datasets.contains_key(name)
    }

    /// Instantiate a registered data source by name.
    pub fn dataset(&self, name: &str, cfg: &Config) -> Result<Arc<dyn DataSource>> {
        match self.datasets.get(name) {
            Some(b) => b(cfg),
            None => Err(unknown(
                "data source",
                name,
                self.datasets.keys().collect(),
            )),
        }
    }

    /// Parse a partition spec (`"iid"`, `"dir(0.5)"`, any registered name).
    /// The name lookup is case-insensitive, like the built-in parsers.
    pub fn partition(&self, spec: &str) -> Result<Partition> {
        let head = spec_head(spec);
        match self.partitions.get(head.as_str()) {
            Some(p) => p(spec),
            None => Err(unknown(
                "partition",
                spec,
                self.partitions.keys().collect(),
            )),
        }
    }

    /// Instantiate a registered server flow by name.
    pub fn server_flow(&self, name: &str, cfg: &Config) -> Result<Box<dyn ServerFlow>> {
        match self.server_flows.get(name) {
            Some(b) => b(cfg),
            None => Err(unknown(
                "server flow",
                name,
                self.server_flows.keys().collect(),
            )),
        }
    }

    /// Parse a SimNet availability spec (`"always-on"`, `"diurnal(0.4)"`,
    /// any registered name). Lookup mirrors [`ComponentRegistry::partition`].
    pub fn availability(&self, spec: &str) -> Result<AvailabilityModel> {
        let head = spec_head(spec);
        match self.availability.get(head.as_str()) {
            Some(b) => b(spec),
            None => Err(unknown(
                "availability model",
                spec,
                self.availability.keys().collect(),
            )),
        }
    }

    /// Instantiate a registered SimNet cost model by name.
    pub fn cost_model(&self, name: &str, cfg: &Config) -> Result<CostModel> {
        match self.cost_models.get(name) {
            Some(b) => b(cfg),
            None => Err(unknown(
                "cost model",
                name,
                self.cost_models.keys().collect(),
            )),
        }
    }

    /// Instantiate a registered aggregator by name for one round's
    /// reduction context.
    pub fn aggregator(
        &self,
        name: &str,
        ctx: &AggContext,
    ) -> Result<Box<dyn Aggregator>> {
        match self.aggregators.get(name) {
            Some(b) => b(ctx),
            None => Err(unknown(
                "aggregator",
                name,
                self.aggregators.keys().collect(),
            )),
        }
    }

    /// Registered aggregator names.
    pub fn aggregator_names(&self) -> Vec<String> {
        self.aggregators.keys().cloned().collect()
    }

    /// Parse a SimNet adversary spec (`"sign-flip"`,
    /// `"scaled-noise(20)"`, any registered name). Lookup mirrors
    /// [`ComponentRegistry::partition`].
    pub fn adversary(&self, spec: &str) -> Result<AdversaryModel> {
        let head = spec_head(spec);
        match self.adversaries.get(head.as_str()) {
            Some(b) => b(spec),
            None => Err(unknown(
                "adversary model",
                spec,
                self.adversaries.keys().collect(),
            )),
        }
    }

    /// Registered names per component kind:
    /// `(algorithms, datasets, partitions, server flows)`.
    pub fn names(&self) -> (Vec<String>, Vec<String>, Vec<String>, Vec<String>) {
        (
            self.algorithms.keys().cloned().collect(),
            self.datasets.keys().cloned().collect(),
            self.partitions.keys().cloned().collect(),
            self.server_flows.keys().cloned().collect(),
        )
    }

    /// Parse a federation topology spec (`"flat"`, `"edges(16)"`,
    /// `"clusters(file)"`, any registered name). Lookup mirrors
    /// [`ComponentRegistry::partition`].
    pub fn topology(&self, spec: &str) -> Result<Topology> {
        let head = spec_head(spec);
        match self.topologies.get(head.as_str()) {
            Some(b) => b(spec),
            None => Err(unknown(
                "topology",
                spec,
                self.topologies.keys().collect(),
            )),
        }
    }

    /// Registered topology names.
    pub fn topology_names(&self) -> Vec<String> {
        self.topologies.keys().cloned().collect()
    }

    /// Parse an update-codec spec (`"identity"`, `"top_k(0.05)"`,
    /// `"top_k_i8(0.05)"`, any registered name). Lookup mirrors
    /// [`ComponentRegistry::partition`].
    pub fn codec(&self, spec: &str) -> Result<Arc<dyn UpdateCodec>> {
        let head = spec_head(spec);
        match self.codecs.get(head.as_str()) {
            Some(b) => b(spec),
            None => Err(unknown("codec", spec, self.codecs.keys().collect())),
        }
    }

    /// Registered codec names.
    pub fn codec_names(&self) -> Vec<String> {
        self.codecs.keys().cloned().collect()
    }

    /// Parse an elastic-membership churn spec (`"none"`, `"grow(2)"`,
    /// `"flux(2,1)"`, any registered name). Lookup mirrors
    /// [`ComponentRegistry::partition`].
    pub fn churn(&self, spec: &str) -> Result<ChurnModel> {
        let head = spec_head(spec);
        match self.churn.get(head.as_str()) {
            Some(b) => b(spec),
            None => Err(unknown(
                "churn model",
                spec,
                self.churn.keys().collect(),
            )),
        }
    }

    /// Parse a chaos-plane fault spec (`"kill_server_at_round(10)"`,
    /// `"corrupt_checkpoint"`, any registered name). Lookup mirrors
    /// [`ComponentRegistry::partition`].
    pub fn fault(&self, spec: &str) -> Result<Fault> {
        let head = spec_head(spec);
        match self.faults.get(head.as_str()) {
            Some(b) => b(spec),
            None => {
                Err(unknown("fault", spec, self.faults.keys().collect()))
            }
        }
    }

    /// Registered chaos-plane fault names.
    pub fn fault_names(&self) -> Vec<String> {
        self.faults.keys().cloned().collect()
    }

    /// Registered SimNet model names:
    /// `(availability, cost models, adversaries, churn models)`.
    pub fn sim_names(
        &self,
    ) -> (Vec<String>, Vec<String>, Vec<String>, Vec<String>) {
        (
            self.availability.keys().cloned().collect(),
            self.cost_models.keys().cloned().collect(),
            self.adversaries.keys().cloned().collect(),
            self.churn.keys().cloned().collect(),
        )
    }
}

// ------------------------------------------------------- global registry

static GLOBAL: OnceLock<RwLock<ComponentRegistry>> = OnceLock::new();

fn global() -> &'static RwLock<ComponentRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(ComponentRegistry::with_builtins()))
}

/// Read access to the process-wide registry (built-ins pre-installed).
pub fn with_global<T>(f: impl FnOnce(&ComponentRegistry) -> T) -> T {
    f(&global().read().unwrap())
}

/// Mutate the process-wide registry (register custom components).
pub fn register(f: impl FnOnce(&mut ComponentRegistry)) {
    f(&mut global().write().unwrap());
}

/// Parse a partition spec against the global registry.
pub fn parse_partition(spec: &str) -> Result<Partition> {
    with_global(|r| r.partition(spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    #[test]
    fn builtins_are_installed() {
        let reg = ComponentRegistry::with_builtins();
        let (algos, datasets, partitions, flows) = reg.names();
        for a in ["fedavg", "fedprox", "stc", "fedreid"] {
            assert!(algos.iter().any(|n| n == a), "missing algorithm {a}");
        }
        for d in ["femnist", "shakespeare", "cifar10"] {
            assert!(datasets.iter().any(|n| n == d), "missing dataset {d}");
        }
        for p in ["iid", "realistic", "dir", "class"] {
            assert!(partitions.iter().any(|n| n == p), "missing partition {p}");
        }
        assert!(flows.iter().any(|n| n == "fedavg"));
    }

    #[test]
    fn unknown_algorithm_lists_registered_names() {
        let reg = ComponentRegistry::with_builtins();
        let mut cfg = Config::default();
        cfg.algorithm = "zorp".into();
        let err = reg.algorithm(&cfg).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("config error"), "{msg}");
        assert!(msg.contains("\"zorp\""), "{msg}");
        for a in ["fedavg", "fedprox", "stc", "fedreid"] {
            assert!(msg.contains(a), "{msg} should list {a}");
        }
    }

    #[test]
    fn partition_specs_resolve_through_registry() {
        let reg = ComponentRegistry::with_builtins();
        assert_eq!(reg.partition("iid").unwrap(), Partition::Iid);
        assert_eq!(reg.partition("dir(0.3)").unwrap(), Partition::Dirichlet(0.3));
        assert_eq!(reg.partition("class(4)").unwrap(), Partition::ByClass(4));
        let err = reg.partition("zipf(1.1)").unwrap_err().to_string();
        assert!(err.contains("registered:"), "{err}");
    }

    #[test]
    fn custom_components_register_and_resolve() {
        let mut reg = ComponentRegistry::with_builtins();
        reg.register_partition(
            "pathological",
            Arc::new(|_| Ok(Partition::ByClass(2))),
        );
        assert_eq!(reg.partition("pathological").unwrap(), Partition::ByClass(2));

        reg.register_dataset(
            "tiny",
            Arc::new(|cfg| {
                let mut c = cfg.clone();
                c.dataset = DatasetKind::Cifar10;
                c.num_clients = 4;
                Ok(Arc::new(crate::data::FedDataset::from_config(&c)?)
                    as Arc<dyn DataSource>)
            }),
        );
        let got = reg.dataset("tiny", &Config::default()).unwrap();
        assert_eq!(got.num_clients(), 4);
    }

    #[test]
    fn builtin_aggregators_resolve_by_name() {
        use crate::model::ParamVec;
        let reg = ComponentRegistry::with_builtins();
        let names = reg.aggregator_names();
        for a in ["mean", "backbone", "trimmed_mean", "median", "norm_clip"] {
            assert!(names.iter().any(|n| n == a), "missing aggregator {a}");
        }
        let ctx = AggContext::new(Arc::new(ParamVec::zeros(4)));
        for a in ["mean", "backbone", "trimmed_mean", "median", "norm_clip"] {
            assert_eq!(reg.aggregator(a, &ctx).unwrap().name(), a);
        }
        let err = reg.aggregator("krum", &ctx).unwrap_err().to_string();
        assert!(err.contains("mean"), "{err} should list registered names");
        assert!(err.contains("trimmed_mean"), "{err}");
    }

    #[test]
    fn builtin_adversaries_resolve_by_name() {
        let reg = ComponentRegistry::with_builtins();
        let (_, _, adversaries, _) = reg.sim_names();
        for a in ["sign-flip", "scaled-noise", "zero-update"] {
            assert!(
                adversaries.iter().any(|n| n == a),
                "missing adversary {a}"
            );
        }
        assert_eq!(
            reg.adversary("sign-flip").unwrap(),
            AdversaryModel::SignFlip
        );
        assert!(matches!(
            reg.adversary("scaled-noise(25)").unwrap(),
            AdversaryModel::ScaledNoise { .. }
        ));
        let err = reg.adversary("gaslight").unwrap_err().to_string();
        assert!(err.contains("sign-flip"), "{err}");
    }

    #[test]
    fn builtin_churn_and_faults_resolve_by_spec() {
        let reg = ComponentRegistry::with_builtins();
        let (_, _, _, churn) = reg.sim_names();
        for c in ["none", "grow", "shrink", "flux"] {
            assert!(churn.iter().any(|n| n == c), "missing churn model {c}");
        }
        assert_eq!(reg.churn("none").unwrap(), ChurnModel::None);
        assert!(matches!(
            reg.churn("flux(2,1)").unwrap(),
            ChurnModel::Flux { .. }
        ));
        let err = reg.churn("stampede").unwrap_err().to_string();
        assert!(err.contains("flux"), "{err} should list registered names");

        let faults = reg.fault_names();
        for f in [
            "kill_server_at_round",
            "partition_edge",
            "drop_frames",
            "corrupt_checkpoint",
        ] {
            assert!(faults.iter().any(|n| n == f), "missing fault {f}");
        }
        assert!(matches!(
            reg.fault("kill_server_at_round(10)").unwrap(),
            Fault::KillServerAtRound { round: 10 }
        ));
        let err = reg.fault("meteor").unwrap_err().to_string();
        assert!(err.contains("drop_frames"), "{err}");
    }

    #[test]
    fn builtin_codecs_resolve_by_spec() {
        let reg = ComponentRegistry::with_builtins();
        let names = reg.codec_names();
        for c in ["identity", "top_k", "top_k_f16", "top_k_i8"] {
            assert!(names.iter().any(|n| n == c), "missing codec {c}");
        }
        assert_eq!(reg.codec("identity").unwrap().spec(), "identity");
        assert_eq!(
            reg.codec("top_k_i8(0.05)").unwrap().spec(),
            "top_k_i8(0.05)"
        );
        let err = reg.codec("gzip").unwrap_err().to_string();
        assert!(err.contains("top_k"), "{err} should list registered names");
    }

    #[test]
    fn config_codec_wraps_the_client_compress_stage() {
        use crate::flow::Update;
        use crate::model::ParamVec;
        let reg = ComponentRegistry::with_builtins();
        let mut cfg = Config::default();
        cfg.codec = Some("top_k(0.1)".into());
        let parts = reg.algorithm(&cfg).unwrap();
        let mut flow = (parts.client_factory)();
        let global = ParamVec::zeros(50);
        let new = ParamVec(vec![0.25; 50]);
        let u = flow.compress(new, &global).unwrap();
        assert!(matches!(u, Update::Encoded(_)), "{u:?}");
        // Unset codec keeps the algorithm's own dense compress stage.
        let parts = reg.algorithm(&Config::default()).unwrap();
        let mut flow = (parts.client_factory)();
        let u = flow.compress(ParamVec(vec![0.25; 50]), &global).unwrap();
        assert!(matches!(u, Update::Dense(_)), "{u:?}");
        // A bad codec spec fails fast at resolution time.
        let mut cfg = Config::default();
        cfg.codec = Some("gzip".into());
        assert!(reg.algorithm(&cfg).is_err());
    }

    #[test]
    fn algorithm_parts_build_for_all_builtins() {
        let reg = ComponentRegistry::with_builtins();
        for name in ["fedavg", "fedprox", "stc", "fedreid"] {
            let mut cfg = Config::default();
            cfg.algorithm = name.into();
            let parts = reg.algorithm(&cfg).unwrap();
            // Each algorithm's flows carry its name for tracking.
            if name != "fedprox" {
                assert_eq!(parts.server_flow.name(), if name == "fedavg" { "fedavg" } else { name });
            }
            let _client = (parts.client_factory)();
        }
    }
}
