//! PJRT runtime: load AOT artifacts and execute them on the hot path.
//!
//! One [`Engine`] owns a PJRT CPU client plus a compile-once cache of
//! loaded executables. The `xla` crate's client is `Rc`-based (not
//! `Send`), so easyfl follows a **engine-per-device-thread** architecture:
//! every simulated device (worker thread) constructs its own `Engine`;
//! compiled executables are reused for the whole process lifetime, which
//! is the platform's key overhead win over re-compiling frameworks
//! (Table VI reproduction).

pub mod artifact_cache;
pub mod checkpoint;
pub mod engine;

pub use checkpoint::{CheckpointReader, CheckpointWriter};
pub use engine::{Batch, Engine, Features, StepOut};
