//! Round-boundary checkpoint container: the crash-safe operations
//! substrate (ROADMAP item 4).
//!
//! A checkpoint is a flat sequence of u64 words (f64s as raw bits) in a
//! self-verifying envelope:
//!
//! ```text
//! [ 8-byte magic "EFCKPT01" | N × 8-byte LE words | 8-byte LE FNV-1a ]
//! ```
//!
//! The trailing hash is FNV-1a 64 over the payload bytes — the same
//! construction the codec plane uses for update integrity — so a
//! tampered, truncated, or trashed file surfaces as a typed
//! [`Error::Integrity`] instead of a garbage resume. The word-stream
//! design keeps the format dependency-free and byte-stable across
//! platforms (everything is explicit little-endian).
//!
//! This module owns the envelope (writer/reader), the config
//! fingerprint that pins a checkpoint to the run shape that produced it,
//! and the file-naming scheme. What goes *into* the words is owned by
//! the engine being checkpointed (see `simnet::rounds`): global params,
//! aggregator/adaptive-clip state, RNG stream positions, and the full
//! event-queue/lifecycle state — enough that `resume_from` reproduces
//! the uninterrupted run's trace digest bit-for-bit.

use std::path::{Path, PathBuf};

use crate::config::Config;
use crate::error::{Error, Result};

/// Leading file magic: format name + version.
pub const MAGIC: &[u8; 8] = b"EFCKPT01";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over a byte slice (the codec plane's hash, reimplemented
/// here so `runtime` does not reach into `codec` internals).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Canonical checkpoint file name for a completed round count.
pub fn checkpoint_path(dir: &Path, rounds_done: usize) -> PathBuf {
    dir.join(format!("ckpt_round_{rounds_done}.bin"))
}

/// Fingerprint of the config facets a checkpoint is only valid for.
/// Resuming under a different seed, population, engine, or component
/// stack would silently diverge from the uninterrupted run, so the
/// reader rejects a fingerprint mismatch as [`Error::Config`] (the file
/// is intact — it just belongs to another run).
pub fn config_fingerprint(cfg: &Config) -> u64 {
    let mut bytes = Vec::with_capacity(128);
    for word in [
        cfg.seed,
        cfg.rounds as u64,
        cfg.num_clients as u64,
        cfg.clients_per_round as u64,
        cfg.num_devices as u64,
    ] {
        bytes.extend_from_slice(&word.to_le_bytes());
    }
    let partition = cfg.partition.name();
    for s in [
        cfg.sim.mode.name(),
        cfg.allocation.name(),
        cfg.dataset.name(),
        partition.as_str(),
        cfg.sim.availability.as_str(),
        cfg.sim.cost_model.as_str(),
        cfg.sim.adversary.as_str(),
        cfg.topology.as_str(),
        cfg.sim.churn.as_str(),
    ] {
        bytes.extend_from_slice(s.as_bytes());
        bytes.push(0); // field separator
    }
    if cfg.sim.engine != "server" {
        // Engine selection joined the fingerprint with the gossip PR;
        // gating on the non-default keeps every pre-existing
        // checkpoint's fingerprint valid.
        bytes.extend_from_slice(cfg.sim.engine.as_bytes());
        bytes.push(0);
        bytes
            .extend_from_slice(&(cfg.sim.gossip_rounds as u64).to_le_bytes());
    }
    fnv1a(&bytes)
}

/// Retention GC: delete all but the `keep` highest-round
/// `ckpt_round_*.bin` files in `dir`, returning the deleted paths.
/// `keep == 0` disables pruning (keep everything); the newest
/// checkpoint by round number is never deleted, and files that do not
/// match the naming scheme are never touched.
pub fn prune_checkpoints(dir: &Path, keep: usize) -> Result<Vec<PathBuf>> {
    if keep == 0 {
        return Ok(Vec::new());
    }
    let entries = std::fs::read_dir(dir).map_err(|e| {
        Error::Runtime(format!(
            "checkpoint: cannot list {}: {e}",
            dir.display()
        ))
    })?;
    let mut rounds: Vec<(usize, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| {
            Error::Runtime(format!(
                "checkpoint: cannot list {}: {e}",
                dir.display()
            ))
        })?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(round) = name
            .strip_prefix("ckpt_round_")
            .and_then(|r| r.strip_suffix(".bin"))
            .and_then(|r| r.parse::<usize>().ok())
        else {
            continue;
        };
        rounds.push((round, entry.path()));
    }
    // Numeric round order, not directory order: round 10 outlives
    // round 2.
    rounds.sort_unstable_by_key(|&(round, _)| round);
    let cut = rounds.len().saturating_sub(keep);
    let mut pruned = Vec::with_capacity(cut);
    for (_, path) in rounds.into_iter().take(cut) {
        std::fs::remove_file(&path).map_err(|e| {
            Error::Runtime(format!(
                "checkpoint: cannot prune {}: {e}",
                path.display()
            ))
        })?;
        pruned.push(path);
    }
    Ok(pruned)
}

/// Accumulates checkpoint words and writes the enveloped file.
#[derive(Default)]
pub struct CheckpointWriter {
    words: Vec<u64>,
}

impl CheckpointWriter {
    pub fn new() -> CheckpointWriter {
        CheckpointWriter::default()
    }

    pub fn push_u64(&mut self, v: u64) {
        self.words.push(v);
    }

    pub fn push_usize(&mut self, v: usize) {
        self.words.push(v as u64);
    }

    pub fn push_f64(&mut self, v: f64) {
        self.words.push(v.to_bits());
    }

    pub fn push_bool(&mut self, v: bool) {
        self.words.push(u64::from(v));
    }

    /// Option<f64> as a presence flag followed by the bits (0 when
    /// absent), keeping the stream fixed-shape per record.
    pub fn push_opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.words.push(1);
                self.words.push(x.to_bits());
            }
            None => {
                self.words.push(0);
                self.words.push(0);
            }
        }
    }

    /// Words pushed so far (for length-prefix bookkeeping).
    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Serialize into the enveloped byte form (magic + payload + hash).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.words.len() * 8);
        out.extend_from_slice(MAGIC);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        let hash = fnv1a(&out[MAGIC.len()..]);
        out.extend_from_slice(&hash.to_le_bytes());
        out
    }

    /// Write the enveloped file; parent directories are created. Returns
    /// the byte size written (the `checkpoint.bytes` counter's unit).
    pub fn write(&self, path: &Path) -> Result<usize> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| {
                    Error::Runtime(format!(
                        "checkpoint: cannot create {}: {e}",
                        parent.display()
                    ))
                })?;
            }
        }
        let bytes = self.to_bytes();
        std::fs::write(path, &bytes).map_err(|e| {
            Error::Runtime(format!(
                "checkpoint: cannot write {}: {e}",
                path.display()
            ))
        })?;
        Ok(bytes.len())
    }
}

/// Verifies the envelope and replays the word stream.
pub struct CheckpointReader {
    words: Vec<u64>,
    pos: usize,
}

impl CheckpointReader {
    /// Parse enveloped bytes: checks magic, 8-byte word alignment, and
    /// the trailing FNV-1a. Every failure mode — wrong file type,
    /// truncation, bit flips — is a typed [`Error::Integrity`].
    pub fn from_bytes(bytes: &[u8]) -> Result<CheckpointReader> {
        if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
            return Err(Error::Integrity(
                "checkpoint: bad magic (not a checkpoint file, or truncated)"
                    .into(),
            ));
        }
        let payload = &bytes[MAGIC.len()..bytes.len() - 8];
        if payload.len() % 8 != 0 {
            return Err(Error::Integrity(format!(
                "checkpoint: payload length {} is not word-aligned (truncated?)",
                payload.len()
            )));
        }
        let stored = u64::from_le_bytes(
            bytes[bytes.len() - 8..].try_into().unwrap(),
        );
        let computed = fnv1a(payload);
        if stored != computed {
            return Err(Error::Integrity(format!(
                "checkpoint: content hash mismatch \
                 (stored {stored:#018x}, computed {computed:#018x})"
            )));
        }
        let words = payload
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(CheckpointReader { words, pos: 0 })
    }

    /// Read and verify a checkpoint file.
    pub fn open(path: &Path) -> Result<CheckpointReader> {
        let bytes = std::fs::read(path).map_err(|e| {
            Error::Runtime(format!(
                "checkpoint: cannot read {}: {e}",
                path.display()
            ))
        })?;
        CheckpointReader::from_bytes(&bytes)
    }

    pub fn take_u64(&mut self) -> Result<u64> {
        let w = self.words.get(self.pos).copied().ok_or_else(|| {
            Error::Integrity(format!(
                "checkpoint: word stream exhausted at position {}",
                self.pos
            ))
        })?;
        self.pos += 1;
        Ok(w)
    }

    pub fn take_usize(&mut self) -> Result<usize> {
        Ok(self.take_u64()? as usize)
    }

    pub fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    pub fn take_bool(&mut self) -> Result<bool> {
        Ok(self.take_u64()? != 0)
    }

    pub fn take_opt_f64(&mut self) -> Result<Option<f64>> {
        let present = self.take_u64()? != 0;
        let bits = self.take_u64()?;
        Ok(present.then(|| f64::from_bits(bits)))
    }

    /// Words remaining (a fully-consumed stream ends at 0).
    pub fn remaining(&self) -> usize {
        self.words.len() - self.pos
    }
}

/// Deterministically flip one payload byte in a written checkpoint —
/// the `corrupt_checkpoint` chaos fault and the tamper tests both go
/// through here so "corruption" means the same thing everywhere. The
/// flipped byte sits mid-payload, so magic and trailer stay intact and
/// the damage is only detectable through the content hash.
pub fn corrupt_file(path: &Path) -> Result<()> {
    let mut bytes = std::fs::read(path).map_err(|e| {
        Error::Runtime(format!(
            "checkpoint: cannot read {}: {e}",
            path.display()
        ))
    })?;
    if bytes.len() <= MAGIC.len() + 8 {
        return Err(Error::Runtime(format!(
            "checkpoint: {} too small to corrupt",
            path.display()
        )));
    }
    let payload_len = bytes.len() - MAGIC.len() - 8;
    let target = MAGIC.len() + payload_len / 2;
    bytes[target] ^= 0xFF;
    std::fs::write(path, &bytes).map_err(|e| {
        Error::Runtime(format!(
            "checkpoint: cannot rewrite {}: {e}",
            path.display()
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_stream_round_trips() {
        let mut w = CheckpointWriter::new();
        w.push_u64(42);
        w.push_usize(7);
        w.push_f64(-1.5);
        w.push_bool(true);
        w.push_opt_f64(Some(2.25));
        w.push_opt_f64(None);
        let mut r = CheckpointReader::from_bytes(&w.to_bytes()).unwrap();
        assert_eq!(r.take_u64().unwrap(), 42);
        assert_eq!(r.take_usize().unwrap(), 7);
        assert_eq!(r.take_f64().unwrap().to_bits(), (-1.5f64).to_bits());
        assert!(r.take_bool().unwrap());
        assert_eq!(r.take_opt_f64().unwrap(), Some(2.25));
        assert_eq!(r.take_opt_f64().unwrap(), None);
        assert_eq!(r.remaining(), 0);
        assert!(matches!(r.take_u64(), Err(Error::Integrity(_))));
    }

    #[test]
    fn tampered_and_truncated_bytes_are_integrity_errors() {
        let mut w = CheckpointWriter::new();
        for i in 0..16u64 {
            w.push_u64(i.wrapping_mul(0x9E37_79B9));
        }
        let good = w.to_bytes();
        assert!(CheckpointReader::from_bytes(&good).is_ok());

        // A single flipped payload bit trips the hash.
        let mut bad = good.clone();
        bad[MAGIC.len() + 3] ^= 0x01;
        assert!(matches!(
            CheckpointReader::from_bytes(&bad),
            Err(Error::Integrity(_))
        ));

        // Truncation (word-aligned or not) never verifies.
        for cut in [good.len() - 1, good.len() - 8, MAGIC.len() + 4, 2] {
            assert!(matches!(
                CheckpointReader::from_bytes(&good[..cut]),
                Err(Error::Integrity(_)),
            ));
        }

        // Wrong magic is rejected before any hashing.
        let mut other = good;
        other[0] ^= 0xFF;
        assert!(matches!(
            CheckpointReader::from_bytes(&other),
            Err(Error::Integrity(_))
        ));
    }

    #[test]
    fn file_round_trip_and_corruption() {
        let dir = std::env::temp_dir().join(format!(
            "easyfl_ckpt_test_{}",
            std::process::id()
        ));
        let path = checkpoint_path(&dir, 3);
        assert!(path.to_string_lossy().ends_with("ckpt_round_3.bin"));
        let mut w = CheckpointWriter::new();
        w.push_u64(0xDEAD_BEEF);
        w.push_f64(1.0 / 3.0);
        let size = w.write(&path).unwrap();
        assert_eq!(size, 8 + 2 * 8 + 8);

        let mut r = CheckpointReader::open(&path).unwrap();
        assert_eq!(r.take_u64().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_f64().unwrap(), 1.0 / 3.0);

        corrupt_file(&path).unwrap();
        assert!(matches!(
            CheckpointReader::open(&path),
            Err(Error::Integrity(_))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_pins_the_run_shape() {
        let base = Config::default();
        let fp = config_fingerprint(&base);
        assert_eq!(fp, config_fingerprint(&base.clone()));
        let mut reseeded = base.clone();
        reseeded.seed ^= 1;
        assert_ne!(fp, config_fingerprint(&reseeded));
        let mut regrown = base.clone();
        regrown.num_clients += 1;
        assert_ne!(fp, config_fingerprint(&regrown));
        let mut remoded = base.clone();
        remoded.sim.availability = "diurnal(0.5)".into();
        assert_ne!(fp, config_fingerprint(&remoded));
        // The gossip engine fingerprints its own knobs — but only when
        // selected, so pre-gossip checkpoints stay resumable.
        let mut peered = base;
        peered.sim.engine = "gossip".into();
        peered.topology = "gossip(8)".into();
        let pfp = config_fingerprint(&peered);
        assert_ne!(fp, pfp);
        let mut longer = peered.clone();
        longer.sim.gossip_rounds = 50;
        assert_ne!(pfp, config_fingerprint(&longer));
    }

    #[test]
    fn prune_keeps_the_newest_rounds_in_numeric_order() {
        let dir = std::env::temp_dir().join(format!(
            "easyfl_ckpt_prune_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        for round in [1, 2, 10] {
            let mut w = CheckpointWriter::new();
            w.push_u64(round as u64);
            w.write(&checkpoint_path(&dir, round)).unwrap();
        }
        let bystander = dir.join("notes.txt");
        std::fs::write(&bystander, "not a checkpoint").unwrap();

        // keep == 0 disables pruning entirely.
        assert!(prune_checkpoints(&dir, 0).unwrap().is_empty());
        for round in [1, 2, 10] {
            assert!(checkpoint_path(&dir, round).is_file());
        }

        // keep = 2: round 1 goes; rounds 2 and 10 survive (numeric
        // order — lexically "10" < "2" would wrongly prune round 10).
        let pruned = prune_checkpoints(&dir, 2).unwrap();
        assert_eq!(pruned, vec![checkpoint_path(&dir, 1)]);
        assert!(!checkpoint_path(&dir, 1).exists());
        assert!(checkpoint_path(&dir, 2).is_file());
        assert!(checkpoint_path(&dir, 10).is_file());

        // keep beyond the population is a no-op; the newest always
        // survives even at keep = 1.
        assert!(prune_checkpoints(&dir, 5).unwrap().is_empty());
        let pruned = prune_checkpoints(&dir, 1).unwrap();
        assert_eq!(pruned, vec![checkpoint_path(&dir, 2)]);
        assert!(checkpoint_path(&dir, 10).is_file());
        assert!(bystander.is_file(), "unrelated files are never touched");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
