//! Process-wide artifact cache.
//!
//! Engines are per-thread (the PJRT client is `Rc`-based), but the
//! artifacts they load are immutable files — so metadata parses and
//! initial-parameter reads are shared across every engine, device worker
//! and [`crate::platform::Platform`] job in the process. A 32-job sweep
//! parses each `<model>_meta.json` and reads each `<model>_init.bin`
//! once, not 32 times.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::Result;
use crate::model::{ModelMeta, ParamVec};

type MetaMap = HashMap<(PathBuf, String), Arc<ModelMeta>>;
type InitMap = HashMap<PathBuf, Arc<ParamVec>>;

static METAS: OnceLock<Mutex<MetaMap>> = OnceLock::new();
static INITS: OnceLock<Mutex<InitMap>> = OnceLock::new();

/// Load (or fetch the cached) model metadata for `<dir>/<model>_meta.json`.
pub fn meta(dir: &Path, model: &str) -> Result<Arc<ModelMeta>> {
    let cache = METAS.get_or_init(Default::default);
    let key = (dir.to_path_buf(), model.to_string());
    if let Some(m) = cache.lock().unwrap().get(&key) {
        return Ok(m.clone());
    }
    // Load outside the lock; a racing duplicate load is harmless.
    let loaded = Arc::new(ModelMeta::load(dir, model)?);
    cache.lock().unwrap().insert(key, loaded.clone());
    Ok(loaded)
}

/// Load (or fetch the cached) initial parameters for a model.
pub fn init_params(meta: &ModelMeta) -> Result<ParamVec> {
    let cache = INITS.get_or_init(Default::default);
    let path = meta.init_path();
    if let Some(p) = cache.lock().unwrap().get(&path) {
        return Ok((**p).clone());
    }
    let loaded = Arc::new(ParamVec::from_file(&path, meta.param_count)?);
    cache.lock().unwrap().insert(path, loaded.clone());
    Ok((*loaded).clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_cache_returns_same_instance() {
        let dir = std::env::temp_dir().join("easyfl_artifact_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("cachetoy_meta.json"),
            r#"{
              "model": "cachetoy", "param_count": 6, "batch": 2, "agg_k": 4,
              "input_shape": [3], "input_dtype": "f32", "classes": 3,
              "layout": [["w", [3, 2]]],
              "files": {"train": "cachetoy_train.hlo.txt"},
              "init": "cachetoy_init.bin"
            }"#,
        )
        .unwrap();
        let a = meta(&dir, "cachetoy").unwrap();
        let b = meta(&dir, "cachetoy").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must hit the cache");

        let mut raw = Vec::new();
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0] {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("cachetoy_init.bin"), raw).unwrap();
        let p1 = init_params(&a).unwrap();
        let p2 = init_params(&a).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), 6);
    }

    #[test]
    fn missing_artifacts_still_error() {
        assert!(meta(Path::new("/nonexistent_cache_dir"), "mlp").is_err());
    }
}
