//! The execution engine: HLO text → PJRT executable → typed entry points.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::{InputDtype, ModelMeta, ParamVec};

/// Feature payload for a batch: matches the model's `input_dtype`.
#[derive(Debug, Clone, PartialEq)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Features {
    pub fn len(&self) -> usize {
        match self {
            Features::F32(v) => v.len(),
            Features::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> InputDtype {
        match self {
            Features::F32(_) => InputDtype::F32,
            Features::I32(_) => InputDtype::I32,
        }
    }
}

/// A materialized minibatch (fixed size B, wrap-around padded + masked).
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Features,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Output of one train/fedprox step.
#[derive(Debug)]
pub struct StepOut {
    pub params: ParamVec,
    pub momentum: ParamVec,
    pub sum_loss: f64,
    pub correct: f64,
}

/// Per-thread PJRT engine with a compile-once executable cache.
///
/// Metadata and initial parameters resolve through the process-wide
/// [`crate::runtime::artifact_cache`], so concurrent engines (device
/// workers, platform jobs) share one parse/read per artifact.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: RefCell<HashMap<String, Arc<ModelMeta>>>,
    execs: RefCell<HashMap<(String, &'static str), Rc<xla::PjRtLoadedExecutable>>>,
    /// Executions performed (profiling / Table VI bookkeeping).
    pub exec_count: std::cell::Cell<u64>,
}

impl Engine {
    /// Create an engine over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            metas: RefCell::new(HashMap::new()),
            execs: RefCell::new(HashMap::new()),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Load (and cache) a model's metadata.
    pub fn meta(&self, model: &str) -> Result<Arc<ModelMeta>> {
        if let Some(m) = self.metas.borrow().get(model) {
            return Ok(m.clone());
        }
        let m = crate::runtime::artifact_cache::meta(&self.dir, model)?;
        self.metas.borrow_mut().insert(model.to_string(), m.clone());
        Ok(m)
    }

    /// Initial parameters as produced by the Python compile path.
    pub fn init_params(&self, model: &str) -> Result<ParamVec> {
        let meta = self.meta(model)?;
        crate::runtime::artifact_cache::init_params(&meta)
    }

    /// Compile-once executable lookup.
    fn exec(
        &self,
        model: &str,
        entry: &'static str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (model.to_string(), entry);
        if let Some(e) = self.execs.borrow().get(&key) {
            return Ok(e.clone());
        }
        let meta = self.meta(model)?;
        let path = meta.hlo_path(entry)?;
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.execs.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Force compilation of the given entry points (warm-up).
    pub fn warm_up(&self, model: &str, entries: &[&'static str]) -> Result<()> {
        for e in entries {
            self.exec(model, e)?;
        }
        Ok(())
    }

    fn f32_literal(&self, data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        // SAFETY: f32 slice reinterpreted as bytes; host is little-endian
        // (asserted at engine construction on exotic targets).
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    fn i32_literal(&self, data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            bytes,
        )?)
    }

    fn x_literal(&self, meta: &ModelMeta, x: &Features) -> Result<xla::Literal> {
        let mut dims = vec![meta.batch];
        dims.extend_from_slice(&meta.input_shape);
        match (x, meta.input_dtype) {
            (Features::F32(v), InputDtype::F32) => self.f32_literal(v, &dims),
            (Features::I32(v), InputDtype::I32) => self.i32_literal(v, &dims),
            _ => Err(Error::Runtime(format!(
                "feature dtype {:?} mismatches model {}",
                x.dtype(),
                meta.model
            ))),
        }
    }

    fn run(
        &self,
        model: &str,
        entry: &'static str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.exec(model, entry)?;
        self.exec_count.set(self.exec_count.get() + 1);
        let result = exe.execute::<xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    fn batch_args(
        &self,
        meta: &ModelMeta,
        batch: &Batch,
    ) -> Result<[xla::Literal; 3]> {
        if batch.y.len() != meta.batch || batch.mask.len() != meta.batch {
            return Err(Error::Runtime(format!(
                "batch size {} != AOT batch {}",
                batch.y.len(),
                meta.batch
            )));
        }
        Ok([
            self.x_literal(meta, &batch.x)?,
            self.i32_literal(&batch.y, &[meta.batch])?,
            self.f32_literal(&batch.mask, &[meta.batch])?,
        ])
    }

    fn scalar1(lit: &xla::Literal) -> Result<f64> {
        Ok(lit.to_vec::<f32>()?[0] as f64)
    }

    /// One SGD-with-momentum minibatch step (L2 `train` entry point).
    pub fn train_step(
        &self,
        model: &str,
        params: &ParamVec,
        momentum: &ParamVec,
        batch: &Batch,
        lr: f32,
    ) -> Result<StepOut> {
        let meta = self.meta(model)?;
        let [x, y, mask] = self.batch_args(&meta, batch)?;
        let p = self.f32_literal(params, &[meta.param_count])?;
        let m = self.f32_literal(momentum, &[meta.param_count])?;
        let lr_l = self.f32_literal(&[lr], &[1])?;
        let outs = self.run(model, "train", &[p, m, x, y, mask, lr_l])?;
        self.step_out(outs)
    }

    /// FedProx local step (adds the proximal pull towards `global`).
    pub fn fedprox_step(
        &self,
        model: &str,
        params: &ParamVec,
        global: &ParamVec,
        momentum: &ParamVec,
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let meta = self.meta(model)?;
        let [x, y, mask] = self.batch_args(&meta, batch)?;
        let p = self.f32_literal(params, &[meta.param_count])?;
        let g = self.f32_literal(global, &[meta.param_count])?;
        let m = self.f32_literal(momentum, &[meta.param_count])?;
        let lr_l = self.f32_literal(&[lr], &[1])?;
        let mu_l = self.f32_literal(&[mu], &[1])?;
        let outs = self.run(model, "fedprox", &[p, g, m, x, y, mask, lr_l, mu_l])?;
        self.step_out(outs)
    }

    fn step_out(&self, outs: Vec<xla::Literal>) -> Result<StepOut> {
        if outs.len() != 4 {
            return Err(Error::Runtime(format!(
                "train entry returned {} outputs, expected 4",
                outs.len()
            )));
        }
        Ok(StepOut {
            params: ParamVec(outs[0].to_vec::<f32>()?),
            momentum: ParamVec(outs[1].to_vec::<f32>()?),
            sum_loss: Self::scalar1(&outs[2])?,
            correct: Self::scalar1(&outs[3])?,
        })
    }

    /// Masked evaluation: returns (sum_loss, correct_count).
    pub fn eval_step(
        &self,
        model: &str,
        params: &ParamVec,
        batch: &Batch,
    ) -> Result<(f64, f64)> {
        let meta = self.meta(model)?;
        let [x, y, mask] = self.batch_args(&meta, batch)?;
        let p = self.f32_literal(params, &[meta.param_count])?;
        let outs = self.run(model, "eval", &[p, x, y, mask])?;
        if outs.len() != 2 {
            return Err(Error::Runtime("eval returned wrong arity".into()));
        }
        Ok((Self::scalar1(&outs[0])?, Self::scalar1(&outs[1])?))
    }

    /// Streaming accumulator entry point: a registered
    /// [`crate::aggregate::Aggregator`] (`"mean"`, `"backbone"`, or any
    /// custom registration) validated against the model's parameter
    /// count. Updates fold in one at a time — O(threads·P) memory —
    /// where [`Engine::aggregate`] needs every dense vector materialized
    /// up front.
    pub fn accumulator(
        &self,
        model: &str,
        name: &str,
        ctx: &crate::aggregate::AggContext,
    ) -> Result<Box<dyn crate::aggregate::Aggregator>> {
        let meta = self.meta(model)?;
        if ctx.global.len() != meta.param_count {
            return Err(Error::Runtime(format!(
                "accumulator: global of len {} != P {}",
                ctx.global.len(),
                meta.param_count
            )));
        }
        crate::registry::with_global(|r| r.aggregator(name, ctx))
    }

    /// Weighted aggregation via the L1 Pallas kernel (legacy batch path;
    /// prefer [`Engine::accumulator`] for large cohorts).
    ///
    /// Handles any cohort size: ≤K in one call (zero-padded), larger
    /// cohorts in chunks whose partial sums are combined with weight 1.
    pub fn aggregate(
        &self,
        model: &str,
        vectors: &[&[f32]],
        weights: &[f32],
    ) -> Result<ParamVec> {
        let meta = self.meta(model)?;
        if vectors.len() != weights.len() || vectors.is_empty() {
            return Err(Error::Runtime(format!(
                "aggregate: {} vectors vs {} weights",
                vectors.len(),
                weights.len()
            )));
        }
        for v in vectors {
            if v.len() != meta.param_count {
                return Err(Error::Runtime(format!(
                    "aggregate: vector of len {} != P {}",
                    v.len(),
                    meta.param_count
                )));
            }
        }
        let k = meta.agg_k;
        if vectors.len() <= k {
            return self.aggregate_chunk(&meta, vectors, weights);
        }
        // Chunked: partial weighted sums combine associatively.
        let mut partials: Vec<ParamVec> = Vec::new();
        for (vs, ws) in vectors.chunks(k).zip(weights.chunks(k)) {
            partials.push(self.aggregate_chunk(&meta, vs, ws)?);
        }
        let refs: Vec<&[f32]> = partials.iter().map(|p| &p.0[..]).collect();
        let ones = vec![1.0f32; refs.len()];
        self.aggregate(model, &refs, &ones)
    }

    fn aggregate_chunk(
        &self,
        meta: &ModelMeta,
        vectors: &[&[f32]],
        weights: &[f32],
    ) -> Result<ParamVec> {
        let k = meta.agg_k;
        let p = meta.param_count;
        debug_assert!(vectors.len() <= k);
        let mut stack = vec![0.0f32; k * p];
        for (row, v) in vectors.iter().enumerate() {
            stack[row * p..(row + 1) * p].copy_from_slice(v);
        }
        let mut wts = vec![0.0f32; k];
        wts[..weights.len()].copy_from_slice(weights);
        let s = self.f32_literal(&stack, &[k, p])?;
        let w = self.f32_literal(&wts, &[k])?;
        let outs = self.run(&meta.model, "aggregate", &[s, w])?;
        Ok(ParamVec(outs[0].to_vec::<f32>()?))
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests here cover argument validation; numeric integration
    //! tests against real artifacts live in rust/tests/runtime_golden.rs.
    use super::*;

    #[test]
    fn features_dtype_and_len() {
        assert_eq!(Features::F32(vec![1.0; 4]).len(), 4);
        assert_eq!(Features::I32(vec![1; 3]).dtype(), InputDtype::I32);
        assert!(Features::F32(vec![]).is_empty());
    }

    #[test]
    fn engine_errors_on_missing_artifacts() {
        let e = Engine::new(Path::new("/nonexistent_dir")).unwrap();
        assert!(e.meta("mlp").is_err());
    }
}
