//! Flat parameter vector — the unit of state the platform moves around.

use std::ops::{Deref, DerefMut};

use crate::error::{Error, Result};
use crate::util::bytes;

/// A flat `f32[P]` parameter (or momentum/update) vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec(pub Vec<f32>);

impl ParamVec {
    /// All-zero vector (momentum buffers, accumulators).
    pub fn zeros(n: usize) -> ParamVec {
        ParamVec(vec![0.0; n])
    }

    /// Load from a little-endian f32 artifact file.
    pub fn from_file(path: &std::path::Path, expect_len: usize) -> Result<ParamVec> {
        let v = bytes::read_f32_file(path)?;
        if v.len() != expect_len {
            return Err(Error::Artifact(format!(
                "{}: has {} params, expected {expect_len}",
                path.display(),
                v.len()
            )));
        }
        Ok(ParamVec(v))
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Euclidean norm (f64 accumulation for stability).
    pub fn l2(&self) -> f64 {
        self.0.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt()
    }

    /// `self += alpha * other` (delta application).
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        assert_eq!(self.len(), other.len(), "axpy length mismatch");
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a += alpha * b;
        }
    }

    /// Elementwise difference `self - other` (update extraction).
    pub fn delta(&self, other: &ParamVec) -> ParamVec {
        assert_eq!(self.len(), other.len(), "delta length mismatch");
        ParamVec(
            self.0
                .iter()
                .zip(other.0.iter())
                .map(|(a, b)| a - b)
                .collect(),
        )
    }

    /// True when all entries are finite (divergence guard).
    pub fn is_finite(&self) -> bool {
        self.0.iter().all(|v| v.is_finite())
    }
}

impl Deref for ParamVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0
    }
}

impl DerefMut for ParamVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.0
    }
}

impl From<Vec<f32>> for ParamVec {
    fn from(v: Vec<f32>) -> Self {
        ParamVec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_l2_axpy_delta() {
        let mut a = ParamVec::zeros(4);
        assert_eq!(a.l2(), 0.0);
        let b = ParamVec(vec![1.0, 2.0, 2.0, 0.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.0, vec![2.0, 4.0, 4.0, 0.0]);
        assert!((a.l2() - 6.0).abs() < 1e-9);
        let d = a.delta(&b);
        assert_eq!(d.0, vec![1.0, 2.0, 2.0, 0.0]);
    }

    #[test]
    fn finite_guard() {
        assert!(ParamVec(vec![1.0, -2.0]).is_finite());
        assert!(!ParamVec(vec![1.0, f32::NAN]).is_finite());
        assert!(!ParamVec(vec![f32::INFINITY]).is_finite());
    }

    #[test]
    fn file_roundtrip_and_length_check() {
        let dir = std::env::temp_dir().join("easyfl_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let vals = [0.5f32, -1.5, 3.25];
        let mut raw = Vec::new();
        for v in vals {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, raw).unwrap();
        let p = ParamVec::from_file(&path, 3).unwrap();
        assert_eq!(&p.0, &vals);
        assert!(ParamVec::from_file(&path, 4).is_err());
    }
}
