//! Model manager (paper §IV-A): artifact metadata and flat parameter
//! vectors.
//!
//! Rust never sees a model graph — only the flat `f32[P]` parameter vector
//! contract described in DESIGN.md, plus the metadata the AOT compiler
//! records in `artifacts/<model>_meta.json`.

pub mod meta;
pub mod params;

pub use meta::{InputDtype, ModelMeta};
pub use params::ParamVec;
