//! `artifacts/<model>_meta.json` — the L2 ↔ L3 shape contract.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Element type of the model's input features.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputDtype {
    F32,
    I32,
}

impl InputDtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Self::F32),
            "i32" => Ok(Self::I32),
            other => Err(Error::Artifact(format!("bad input_dtype {other:?}"))),
        }
    }
}

/// One named parameter tensor in the flat layout.
#[derive(Debug, Clone)]
pub struct LayoutEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed model metadata.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub model: String,
    /// Total flat parameter count P.
    pub param_count: usize,
    /// AOT minibatch size B.
    pub batch: usize,
    /// AOT aggregation width K.
    pub agg_k: usize,
    /// Per-sample input shape (without the batch dimension).
    pub input_shape: Vec<usize>,
    pub input_dtype: InputDtype,
    pub classes: usize,
    pub layout: Vec<LayoutEntry>,
    /// entry name → HLO file name.
    files: Vec<(String, String)>,
    pub init_file: String,
    /// Directory the metadata was loaded from.
    pub dir: PathBuf,
}

impl ModelMeta {
    /// Load `<dir>/<model>_meta.json`.
    pub fn load(dir: &Path, model: &str) -> Result<ModelMeta> {
        let path = dir.join(format!("{model}_meta.json"));
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} (run `make artifacts`?)",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let layout = v
            .get("layout")
            .as_arr()
            .ok_or_else(|| Error::Artifact("meta: missing layout".into()))?
            .iter()
            .map(|e| {
                let pair = e.as_arr().ok_or_else(|| {
                    Error::Artifact("meta: bad layout entry".into())
                })?;
                let name = pair[0]
                    .as_str()
                    .ok_or_else(|| Error::Artifact("meta: bad layout name".into()))?
                    .to_string();
                let shape = pair[1]
                    .as_arr()
                    .ok_or_else(|| Error::Artifact("meta: bad layout shape".into()))?
                    .iter()
                    .map(|d| {
                        d.as_usize().ok_or_else(|| {
                            Error::Artifact("meta: bad layout dim".into())
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(LayoutEntry { name, shape })
            })
            .collect::<Result<Vec<_>>>()?;

        let files = v
            .get("files")
            .as_obj()
            .ok_or_else(|| Error::Artifact("meta: missing files".into()))?
            .iter()
            .map(|(k, f)| {
                Ok((
                    k.clone(),
                    f.as_str()
                        .ok_or_else(|| Error::Artifact("meta: bad file".into()))?
                        .to_string(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;

        let meta = ModelMeta {
            model: v.req_str("model")?,
            param_count: v.req_usize("param_count")?,
            batch: v.req_usize("batch")?,
            agg_k: v.req_usize("agg_k")?,
            input_shape: v
                .get("input_shape")
                .as_arr()
                .ok_or_else(|| Error::Artifact("meta: missing input_shape".into()))?
                .iter()
                .map(|d| {
                    d.as_usize().ok_or_else(|| {
                        Error::Artifact("meta: bad input dim".into())
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            input_dtype: InputDtype::parse(&v.req_str("input_dtype")?)?,
            classes: v.req_usize("classes")?,
            layout,
            files,
            init_file: v.req_str("init")?,
            dir: dir.to_path_buf(),
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Cross-check the layout against the declared parameter count.
    pub fn validate(&self) -> Result<()> {
        let total: usize = self.layout.iter().map(LayoutEntry::len).sum();
        if total != self.param_count {
            return Err(Error::Artifact(format!(
                "meta {}: layout sums to {total}, param_count says {}",
                self.model, self.param_count
            )));
        }
        if self.batch == 0 || self.agg_k == 0 || self.classes == 0 {
            return Err(Error::Artifact(format!(
                "meta {}: zero batch/agg_k/classes",
                self.model
            )));
        }
        Ok(())
    }

    /// Per-sample feature element count.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Path of the HLO file for an entry point.
    pub fn hlo_path(&self, entry: &str) -> Result<PathBuf> {
        self.files
            .iter()
            .find(|(k, _)| k == entry)
            .map(|(_, f)| self.dir.join(f))
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "model {} has no entry point {entry:?}",
                    self.model
                ))
            })
    }

    /// Path of the initial-parameter artifact.
    pub fn init_path(&self) -> PathBuf {
        self.dir.join(&self.init_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path) {
        std::fs::write(
            dir.join("toy_meta.json"),
            r#"{
              "model": "toy", "param_count": 10, "batch": 2, "agg_k": 4,
              "input_shape": [5], "input_dtype": "f32", "classes": 3,
              "layout": [["w", [5, 2]]],
              "files": {"train": "toy_train.hlo.txt"},
              "init": "toy_init.bin"
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_validates() {
        let dir = std::env::temp_dir().join("easyfl_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir);
        let m = ModelMeta::load(&dir, "toy").unwrap();
        assert_eq!(m.param_count, 10);
        assert_eq!(m.input_len(), 5);
        assert_eq!(m.input_dtype, InputDtype::F32);
        assert!(m.hlo_path("train").unwrap().ends_with("toy_train.hlo.txt"));
        assert!(m.hlo_path("nope").is_err());
    }

    #[test]
    fn rejects_inconsistent_layout() {
        let dir = std::env::temp_dir().join("easyfl_meta_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("bad_meta.json"),
            r#"{
              "model": "bad", "param_count": 99, "batch": 2, "agg_k": 4,
              "input_shape": [5], "input_dtype": "f32", "classes": 3,
              "layout": [["w", [5, 2]]],
              "files": {}, "init": "x.bin"
            }"#,
        )
        .unwrap();
        assert!(ModelMeta::load(&dir, "bad").is_err());
    }

    #[test]
    fn missing_file_mentions_make_artifacts() {
        let err = ModelMeta::load(Path::new("/nonexistent"), "mlp").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
