//! The simulated device pool (paper §VI).
//!
//! Each "GPU" is a worker thread owning its **own** PJRT engine (the
//! `xla` client is single-threaded) and its own [`ClientFlow`] instance;
//! clients allocated to a device train sequentially, devices in parallel —
//! exactly the paper's distributed-training model under resource
//! constraints. Engines compile once and live for the pool's lifetime.
//!
//! Outcomes *stream*: workers push each [`ClientOutcome`] through the
//! reply channel the moment its client finishes, so the server's
//! aggregator (or an edge tier of the [`crate::hierarchy`] plane)
//! consumes updates incrementally instead of buffering the cohort —
//! the same shape the remote ingest path already has.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::client::{execute_client_round, ClientJob, ClientOutcome};
use crate::data::registry::DataSource;
use crate::error::{Error, Result};
use crate::flow::ClientFlow;
use crate::runtime::Engine;
use crate::util::clock::Clock;

/// Factory producing one [`ClientFlow`] per worker thread.
pub type ClientFlowFactory = Arc<dyn Fn() -> Box<dyn ClientFlow> + Send + Sync>;

struct DeviceJob {
    jobs: Vec<ClientJob>,
    /// Per-outcome reply stream: one message per finished client, or a
    /// single error that aborts the device's batch.
    reply: Sender<(usize, Result<ClientOutcome>)>,
}

/// A pool of M simulated devices.
pub struct DevicePool {
    senders: Vec<Sender<DeviceJob>>,
    handles: Vec<JoinHandle<()>>,
}

impl DevicePool {
    /// Spawn `m` device workers.
    pub fn new(
        m: usize,
        artifacts_dir: std::path::PathBuf,
        data: Arc<dyn DataSource>,
        clock: Arc<dyn Clock>,
        flow_factory: ClientFlowFactory,
    ) -> Result<DevicePool> {
        assert!(m > 0);
        let mut senders = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        // Engines are constructed inside the threads (PjRtClient is !Send);
        // construction errors surface on the first job instead.
        for device in 0..m {
            let (tx, rx): (Sender<DeviceJob>, Receiver<DeviceJob>) = channel();
            let dir = artifacts_dir.clone();
            let data = data.clone();
            let clock = clock.clone();
            let factory = flow_factory.clone();
            let handle = std::thread::Builder::new()
                .name(format!("easyfl-dev{device}"))
                .spawn(move || {
                    let engine = Engine::new(&dir);
                    let mut flow = factory();
                    while let Ok(DeviceJob { jobs, reply }) = rx.recv() {
                        match &engine {
                            Err(e) => {
                                // Receiver may have given up; ignore
                                // send errors throughout.
                                let _ = reply.send((
                                    device,
                                    Err(Error::Runtime(format!(
                                        "device {device}: engine init \
                                         failed: {e}"
                                    ))),
                                ));
                            }
                            Ok(engine) => {
                                for job in &jobs {
                                    let out = execute_client_round(
                                        flow.as_mut(),
                                        engine,
                                        data.as_ref(),
                                        clock.as_ref(),
                                        job,
                                    );
                                    let failed = out.is_err();
                                    if reply.send((device, out)).is_err()
                                        || failed
                                    {
                                        // Fail-fast per batch, exactly
                                        // like the old collect() path.
                                        break;
                                    }
                                }
                            }
                        }
                    }
                })
                .map_err(|e| Error::Runtime(format!("spawn device: {e}")))?;
            senders.push(tx);
            handles.push(handle);
        }
        Ok(DevicePool { senders, handles })
    }

    pub fn num_devices(&self) -> usize {
        self.senders.len()
    }

    /// Run one round, streaming: `groups[d]` trains sequentially on
    /// device `d`, and `on_outcome(device, outcome)` is invoked on the
    /// caller's thread for each client the moment it finishes — in
    /// completion order across devices. The first error (from a worker
    /// or from the callback) aborts the drain and is returned; remaining
    /// in-flight work is dropped on the floor like before.
    ///
    /// Returns the number of outcomes delivered.
    pub fn run_round_with<F>(
        &self,
        groups: Vec<Vec<ClientJob>>,
        mut on_outcome: F,
    ) -> Result<usize>
    where
        F: FnMut(usize, ClientOutcome) -> Result<()>,
    {
        if groups.len() > self.senders.len() {
            return Err(Error::Runtime(format!(
                "{} groups for {} devices",
                groups.len(),
                self.senders.len()
            )));
        }
        let (reply_tx, reply_rx) = channel();
        let mut expected = 0usize;
        for (device, jobs) in groups.into_iter().enumerate() {
            if jobs.is_empty() {
                continue;
            }
            expected += jobs.len();
            self.senders[device]
                .send(DeviceJob { jobs, reply: reply_tx.clone() })
                .map_err(|_| Error::Runtime(format!("device {device} died")))?;
        }
        drop(reply_tx);
        let mut delivered = 0usize;
        while delivered < expected {
            let (device, result) = reply_rx
                .recv()
                .map_err(|_| Error::Runtime("device pool hung up".into()))?;
            on_outcome(device, result?)?;
            delivered += 1;
        }
        Ok(delivered)
    }

    /// Run one round and collect every outcome, per device (same
    /// indexing as `groups`). Buffered convenience wrapper over
    /// [`DevicePool::run_round_with`] for callers that genuinely need
    /// the whole cohort at once.
    pub fn run_round(
        &self,
        groups: Vec<Vec<ClientJob>>,
    ) -> Result<Vec<Vec<ClientOutcome>>> {
        let mut per_device: Vec<Vec<ClientOutcome>> =
            (0..self.senders.len()).map(|_| Vec::new()).collect();
        self.run_round_with(groups, |device, outcome| {
            per_device[device].push(outcome);
            Ok(())
        })?;
        Ok(per_device)
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        self.senders.clear(); // disconnect: workers exit their recv loops
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
