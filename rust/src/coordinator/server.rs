//! The federated server: the round loop tying every module together.

use std::sync::Arc;

use crate::aggregate::AggContext;
use crate::client::{execute_client_round, ClientJob, ClientOutcome};
use crate::config::Config;
use crate::coordinator::pool::{ClientFlowFactory, DevicePool};
use crate::data::registry::DataSource;
use crate::error::{Error, Result};
use crate::flow::ServerFlow;
use crate::hierarchy::{HierPlane, Topology};
use crate::model::ParamVec;
use crate::obs::{Histogram, Telemetry};
use crate::runtime::{Batch, Engine};
use crate::scheduler::{self, Strategy};
use crate::simulation::HeterogeneityPlan;
use crate::tracking::{ClientMetrics, RoundMetrics, Tracker};
use crate::util::clock::{Clock, RealClock, Stopwatch, VirtualClock};
use crate::util::rng::Rng;

/// The FL server (paper §IV-A "server" module).
pub struct Server {
    pub cfg: Config,
    data: Arc<dyn DataSource>,
    /// Main-thread engine: evaluation + aggregation (and, in standalone
    /// mode, client training — perf iteration 2 in EXPERIMENTS.md §Perf:
    /// one engine ⇒ one compile, no thread hop).
    engine: Engine,
    /// Parallel device pool; `None` in standalone mode (num_devices == 1).
    pool: Option<DevicePool>,
    /// Client flow used for inline standalone training.
    standalone_flow: Option<Box<dyn crate::flow::ClientFlow>>,
    strategy: Box<dyn Strategy>,
    flow: Box<dyn ServerFlow>,
    /// Aggregation-tree shape (client→edge→cloud when not flat); every
    /// round reduces through a [`HierPlane`] built from it.
    topology: Topology,
    plan: HeterogeneityPlan,
    tracker: Arc<Tracker>,
    clock: Arc<dyn Clock>,
    /// Telemetry plane (off unless configured): round-stage spans, client
    /// round-time histograms, aggregation latency.
    tel: Telemetry,
    /// The global model, shared by reference: distribution hands clients
    /// an `Arc` clone instead of copying P floats per round.
    params: Arc<ParamVec>,
    rng: Rng,
    test_batches: Vec<Batch>,
}

impl Server {
    /// Assemble a server from the configured modules.
    pub fn new(
        cfg: Config,
        data: Arc<dyn DataSource>,
        flow: Box<dyn ServerFlow>,
        client_factory: ClientFlowFactory,
        tracker: Arc<Tracker>,
    ) -> Result<Server> {
        let mut cfg = cfg;
        cfg.model = cfg.resolved_model();
        cfg.validate()?;
        let engine = Engine::new(&cfg.artifacts_dir)?;
        let params = Arc::new(engine.init_params(&cfg.model)?);
        let clock: Arc<dyn Clock> = if cfg.virtual_clock {
            Arc::new(VirtualClock::new())
        } else {
            Arc::new(RealClock::new(cfg.time_scale))
        };
        // Spans carry this server's clock: wall time normally, virtual
        // time under virtual_clock, so traces line up with round_ms.
        let tel = Telemetry::from_config(&cfg, clock.clone())?;
        tracker.set_telemetry(tel.clone());
        let topology =
            crate::registry::with_global(|r| r.topology(&cfg.topology))?;
        if let Some(edge_agg) = &cfg.edge_agg {
            // Fail fast on an unknown edge-tier aggregator before any
            // round streams into it.
            let probe = AggContext::from_config(params.clone(), &cfg);
            crate::registry::with_global(|r| r.aggregator(edge_agg, &probe))?;
        }
        let plan = HeterogeneityPlan::from_config(&cfg, data.num_clients());
        let strategy = scheduler::make_strategy(
            cfg.allocation,
            cfg.default_client_time_ms,
            cfg.profile_momentum,
        );
        let (pool, standalone_flow) = if cfg.num_devices == 1 {
            (None, Some(client_factory()))
        } else {
            (
                Some(DevicePool::new(
                    cfg.num_devices,
                    cfg.artifacts_dir.clone(),
                    data.clone(),
                    clock.clone(),
                    client_factory,
                )?),
                None,
            )
        };
        let test_batches = data
            .test_data(cfg.test_samples)?
            .batches(cfg.batch_size);
        let rng = Rng::new(cfg.seed ^ 0x5E17_EC70);

        tracker.set_config("dataset", cfg.dataset.name().to_string());
        tracker.set_config("model", cfg.model.clone());
        tracker.set_config("partition", cfg.partition.name());
        tracker.set_config("allocation", cfg.allocation.name().to_string());
        tracker.set_config("num_devices", cfg.num_devices.to_string());
        tracker.set_config("clients_per_round", cfg.clients_per_round.to_string());
        tracker.set_config("server_flow", flow.name().to_string());
        tracker.set_config("topology", topology.name());

        Ok(Server {
            cfg,
            data,
            engine,
            pool,
            standalone_flow,
            strategy,
            flow,
            topology,
            plan,
            tracker,
            clock,
            tel,
            params,
            rng,
            test_batches,
        })
    }

    pub fn tracker(&self) -> Arc<Tracker> {
        self.tracker.clone()
    }

    /// The server's telemetry handle (off unless configured).
    pub fn telemetry(&self) -> Telemetry {
        self.tel.clone()
    }

    pub fn params(&self) -> &ParamVec {
        &self.params
    }

    /// Replace the global model (remote ingest, tests).
    pub fn set_params(&mut self, params: ParamVec) {
        self.params = Arc::new(params);
    }

    /// Train all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for round in 0..self.cfg.rounds {
            self.run_round(round)?;
        }
        self.tel.flush()?;
        Ok(())
    }

    /// One FL round: select → allocate → distribute → train → aggregate →
    /// evaluate → track.
    pub fn run_round(&mut self, round: usize) -> Result<RoundMetrics> {
        let _round_span = self
            .tel
            .span_with("server.round", || vec![("round", round.to_string())]);
        let k = self.cfg.clients_per_round;
        let cohort =
            self.flow
                .select(self.data.num_clients(), k, round, &mut self.rng);
        let num_devices = self.cfg.num_devices;
        let groups = self.strategy.allocate(&cohort, num_devices, &mut self.rng);

        // Distribution stage: build + enqueue per-client payloads. The
        // payload shares the global by Arc — no per-round dense copy.
        let payload = self.flow.compress_model(self.params.clone(), round);
        let downlink_bytes = payload.wire_bytes * cohort.len();
        let sw_dist = Stopwatch::start();
        let dist_span = self.tel.span_with("server.distribute", || {
            vec![("cohort", cohort.len().to_string())]
        });
        let jobs: Vec<Vec<ClientJob>> = groups
            .iter()
            .map(|group| {
                group
                    .iter()
                    .map(|&client| ClientJob {
                        client,
                        round,
                        model: self.cfg.model.clone(),
                        payload: payload.clone(),
                        lr: self.cfg.lr as f32,
                        local_epochs: self.cfg.local_epochs,
                        batch_size: self.cfg.batch_size,
                        data_amount: self.cfg.data_amount,
                        seed: self.cfg.seed
                            ^ (round as u64) << 32
                            ^ client as u64,
                        speed_ratio: self.plan.speed_ratio(client),
                        device_name: self.plan.device_name(client).to_string(),
                    })
                    .collect()
            })
            .collect();
        // The round's aggregation tree (flat: the plain streaming
        // aggregator; hierarchical: one edge per active cluster + the
        // cloud fold) is built *before* training so each outcome streams
        // straight in the moment its device finishes — no cohort buffer.
        let ctx = AggContext::from_config(self.params.clone(), &self.cfg)
            .expect_updates(cohort.len())
            .telemetry(self.tel.clone());
        let mut plane = HierPlane::from_flow(
            self.flow.as_mut(),
            &self.engine,
            &self.cfg.model,
            &self.topology,
            ctx,
            &cohort,
        )?;
        drop(dist_span);

        let mut uplink_bytes = 0usize;
        let mut clients_m: Vec<ClientMetrics> = Vec::new();
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut device_ms = vec![0.0f64; num_devices];
        let mut sum_loss = 0.0f64;
        let mut sum_correct = 0.0f64;
        let mut total_samples = 0.0f64;
        let mut stream_agg_ms = 0.0f64;
        let train_span = self.tel.span("server.train");
        {
            let flow = self.flow.as_mut();
            let tel = &self.tel;
            let mut on_outcome = |device: usize,
                                  o: ClientOutcome|
             -> Result<()> {
                device_ms[device] += o.round_ms;
                measured.push((o.client, o.round_ms));
                tel.observe_ms("server.client_round_ms", o.round_ms);
                uplink_bytes += o.upload_bytes;
                let sw = Stopwatch::start();
                let decoded = flow.decode_update(&o.update)?;
                plane.add(
                    o.client,
                    decoded.as_ref(),
                    o.stats.num_samples as f64,
                )?;
                stream_agg_ms += sw.elapsed_ms();
                sum_loss += o.stats.sum_loss;
                sum_correct += o.stats.correct;
                total_samples += o.stats.num_samples as f64;
                clients_m.push(ClientMetrics {
                    client: o.client,
                    num_samples: o.stats.num_samples,
                    train_loss: o.stats.avg_loss(),
                    train_accuracy: o.stats.accuracy(),
                    compute_ms: o.compute_ms,
                    wait_ms: o.wait_ms,
                    round_ms: o.round_ms,
                    upload_bytes: o.upload_bytes,
                    device: o.device_name.clone(),
                });
                Ok(())
            };
            match &self.pool {
                Some(pool) => {
                    pool.run_round_with(jobs, &mut on_outcome)?;
                }
                None => {
                    // Standalone: inline on the server engine (single
                    // compile), still streaming through the same hook.
                    let standalone =
                        self.standalone_flow.as_mut().expect("standalone flow");
                    for (device, group) in jobs.into_iter().enumerate() {
                        for job in &group {
                            let o = execute_client_round(
                                standalone.as_mut(),
                                &self.engine,
                                self.data.as_ref(),
                                self.clock.as_ref(),
                                job,
                            )?;
                            on_outcome(device, o)?;
                        }
                    }
                }
            }
        }
        drop(train_span);
        let distribution_ms = sw_dist.elapsed_ms();
        if clients_m.is_empty() {
            return Err(Error::Runtime("round produced no outcomes".into()));
        }

        // Adaptive profiling feedback (Algorithm 1 line 14).
        self.strategy.observe(&measured);

        // Simulated round time = makespan over devices (+ real server work
        // below). With a real clock the wall time matches this; with a
        // virtual clock waits were free, so the makespan is authoritative.
        let makespan_ms = device_ms.iter().copied().fold(0.0, f64::max);

        // Close the tree: edges flush their partials, the cloud folds
        // them weighted by edge cohort mass.
        let sw_agg = Stopwatch::start();
        let agg_span = self.tel.span("server.aggregate");
        let (new_params, hier) = plane.finish()?;
        if !new_params.is_finite() {
            return Err(Error::Runtime(format!(
                "round {round}: aggregated parameters diverged (NaN/Inf); \
                 lower the learning rate"
            )));
        }
        self.params = Arc::new(new_params);
        drop(agg_span);
        let agg_ms = sw_agg.elapsed_ms() + stream_agg_ms;
        self.tel.observe_ms("server.aggregate_ms", agg_ms);

        // Evaluation.
        let (test_loss, test_accuracy) = if self.cfg.eval_every > 0
            && (round + 1) % self.cfg.eval_every == 0
        {
            let _eval_span = self.tel.span("server.evaluate");
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        // Per-client round-time quantiles: always computed (deterministic
        // — no telemetry dependency), so RoundMetrics exposes the
        // straggler tail the mean hides.
        let mut client_hist = Histogram::default();
        for (_, ms) in &measured {
            client_hist.record_ms(*ms);
        }
        let (client_ms_p50, client_ms_p95, client_ms_p99) =
            client_hist.quantiles_ms();

        // Tracking (three-level hierarchy).
        let metrics = RoundMetrics {
            round,
            train_loss: sum_loss / total_samples.max(1.0),
            train_accuracy: sum_correct / total_samples.max(1.0),
            test_loss,
            test_accuracy,
            round_ms: makespan_ms + agg_ms,
            distribution_ms,
            comm_bytes: downlink_bytes + uplink_bytes,
            // Flat rounds ship every uplink to the cloud; hierarchical
            // rounds ship one dense partial per active edge.
            bytes_to_cloud: if hier.tiered {
                hier.bytes_to_cloud
            } else {
                uplink_bytes
            },
            // In-process training has full participation: everyone
            // selected reports, nobody drops, updates are never stale.
            selected: clients_m.len(),
            reported: clients_m.len(),
            clients: clients_m,
            client_ms_p50,
            client_ms_p95,
            client_ms_p99,
            ..RoundMetrics::default()
        };
        self.tracker.record_round(metrics.clone());
        Ok(metrics)
    }

    /// Evaluate the global model on the IID test split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.evaluate_params(&self.params)
    }

    /// Evaluate arbitrary parameters (personalization diagnostics).
    pub fn evaluate_params(&self, params: &ParamVec) -> Result<(f64, f64)> {
        let mut sum_loss = 0.0;
        let mut correct = 0.0;
        let mut n = 0.0;
        for b in &self.test_batches {
            let (l, c) = self.engine.eval_step(&self.cfg.model, params, b)?;
            sum_loss += l;
            correct += c;
            n += b.mask.iter().sum::<f32>() as f64;
        }
        if n == 0.0 {
            return Err(Error::Runtime("empty test split".into()));
        }
        Ok((sum_loss / n, correct / n))
    }

    /// The engine (plugins may need aggregation access).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Elapsed simulated time (virtual-clock experiments).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }
}
