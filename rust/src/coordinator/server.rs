//! The federated server: the round loop tying every module together.

use std::sync::Arc;

use crate::aggregate::AggContext;
use crate::client::{execute_client_round, ClientJob, ClientOutcome};
use crate::config::Config;
use crate::coordinator::pool::{ClientFlowFactory, DevicePool};
use crate::data::registry::DataSource;
use crate::error::{Error, Result};
use crate::flow::ServerFlow;
use crate::model::ParamVec;
use crate::runtime::{Batch, Engine};
use crate::scheduler::{self, Strategy};
use crate::simulation::HeterogeneityPlan;
use crate::tracking::{ClientMetrics, RoundMetrics, Tracker};
use crate::util::clock::{Clock, RealClock, Stopwatch, VirtualClock};
use crate::util::rng::Rng;

/// The FL server (paper §IV-A "server" module).
pub struct Server {
    pub cfg: Config,
    data: Arc<dyn DataSource>,
    /// Main-thread engine: evaluation + aggregation (and, in standalone
    /// mode, client training — perf iteration 2 in EXPERIMENTS.md §Perf:
    /// one engine ⇒ one compile, no thread hop).
    engine: Engine,
    /// Parallel device pool; `None` in standalone mode (num_devices == 1).
    pool: Option<DevicePool>,
    /// Client flow used for inline standalone training.
    standalone_flow: Option<Box<dyn crate::flow::ClientFlow>>,
    strategy: Box<dyn Strategy>,
    flow: Box<dyn ServerFlow>,
    plan: HeterogeneityPlan,
    tracker: Arc<Tracker>,
    clock: Arc<dyn Clock>,
    /// The global model, shared by reference: distribution hands clients
    /// an `Arc` clone instead of copying P floats per round.
    params: Arc<ParamVec>,
    rng: Rng,
    test_batches: Vec<Batch>,
}

impl Server {
    /// Assemble a server from the configured modules.
    pub fn new(
        cfg: Config,
        data: Arc<dyn DataSource>,
        flow: Box<dyn ServerFlow>,
        client_factory: ClientFlowFactory,
        tracker: Arc<Tracker>,
    ) -> Result<Server> {
        let mut cfg = cfg;
        cfg.model = cfg.resolved_model();
        cfg.validate()?;
        let engine = Engine::new(&cfg.artifacts_dir)?;
        let params = Arc::new(engine.init_params(&cfg.model)?);
        let clock: Arc<dyn Clock> = if cfg.virtual_clock {
            Arc::new(VirtualClock::new())
        } else {
            Arc::new(RealClock::new(cfg.time_scale))
        };
        let plan = HeterogeneityPlan::from_config(&cfg, data.num_clients());
        let strategy = scheduler::make_strategy(
            cfg.allocation,
            cfg.default_client_time_ms,
            cfg.profile_momentum,
        );
        let (pool, standalone_flow) = if cfg.num_devices == 1 {
            (None, Some(client_factory()))
        } else {
            (
                Some(DevicePool::new(
                    cfg.num_devices,
                    cfg.artifacts_dir.clone(),
                    data.clone(),
                    clock.clone(),
                    client_factory,
                )?),
                None,
            )
        };
        let test_batches = data
            .test_data(cfg.test_samples)?
            .batches(cfg.batch_size);
        let rng = Rng::new(cfg.seed ^ 0x5E17_EC70);

        tracker.set_config("dataset", cfg.dataset.name().to_string());
        tracker.set_config("model", cfg.model.clone());
        tracker.set_config("partition", cfg.partition.name());
        tracker.set_config("allocation", cfg.allocation.name().to_string());
        tracker.set_config("num_devices", cfg.num_devices.to_string());
        tracker.set_config("clients_per_round", cfg.clients_per_round.to_string());
        tracker.set_config("server_flow", flow.name().to_string());

        Ok(Server {
            cfg,
            data,
            engine,
            pool,
            standalone_flow,
            strategy,
            flow,
            plan,
            tracker,
            clock,
            params,
            rng,
            test_batches,
        })
    }

    pub fn tracker(&self) -> Arc<Tracker> {
        self.tracker.clone()
    }

    pub fn params(&self) -> &ParamVec {
        &self.params
    }

    /// Replace the global model (remote ingest, tests).
    pub fn set_params(&mut self, params: ParamVec) {
        self.params = Arc::new(params);
    }

    /// Train all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for round in 0..self.cfg.rounds {
            self.run_round(round)?;
        }
        Ok(())
    }

    /// One FL round: select → allocate → distribute → train → aggregate →
    /// evaluate → track.
    pub fn run_round(&mut self, round: usize) -> Result<RoundMetrics> {
        let k = self.cfg.clients_per_round;
        let cohort =
            self.flow
                .select(self.data.num_clients(), k, round, &mut self.rng);
        let num_devices = self.cfg.num_devices;
        let groups = self.strategy.allocate(&cohort, num_devices, &mut self.rng);

        // Distribution stage: build + enqueue per-client payloads. The
        // payload shares the global by Arc — no per-round dense copy.
        let payload = self.flow.compress_model(self.params.clone(), round);
        let downlink_bytes = payload.wire_bytes * cohort.len();
        let sw_dist = Stopwatch::start();
        let jobs: Vec<Vec<ClientJob>> = groups
            .iter()
            .map(|group| {
                group
                    .iter()
                    .map(|&client| ClientJob {
                        client,
                        round,
                        model: self.cfg.model.clone(),
                        payload: payload.clone(),
                        lr: self.cfg.lr as f32,
                        local_epochs: self.cfg.local_epochs,
                        batch_size: self.cfg.batch_size,
                        data_amount: self.cfg.data_amount,
                        seed: self.cfg.seed
                            ^ (round as u64) << 32
                            ^ client as u64,
                        speed_ratio: self.plan.speed_ratio(client),
                        device_name: self.plan.device_name(client).to_string(),
                    })
                    .collect()
            })
            .collect();
        let per_device = match &self.pool {
            Some(pool) => pool.run_round(jobs)?,
            None => {
                // Standalone: inline on the server engine (single compile).
                let flow = self.standalone_flow.as_mut().expect("standalone flow");
                let mut out = Vec::with_capacity(jobs.len());
                for group in jobs {
                    let mut outs = Vec::with_capacity(group.len());
                    for job in &group {
                        outs.push(execute_client_round(
                            flow.as_mut(),
                            &self.engine,
                            self.data.as_ref(),
                            self.clock.as_ref(),
                            job,
                        )?);
                    }
                    out.push(outs);
                }
                out
            }
        };
        let distribution_ms = sw_dist.elapsed_ms();

        // Adaptive profiling feedback (Algorithm 1 line 14).
        let measured: Vec<(usize, f64)> = per_device
            .iter()
            .flatten()
            .map(|o| (o.client, o.round_ms))
            .collect();
        self.strategy.observe(&measured);

        // Simulated round time = makespan over devices (+ real server work
        // below). With a real clock the wall time matches this; with a
        // virtual clock waits were free, so the makespan is authoritative.
        let makespan_ms = per_device
            .iter()
            .map(|outs| outs.iter().map(|o| o.round_ms).sum::<f64>())
            .fold(0.0, f64::max);

        // Streaming aggregation: decode each outcome and feed it straight
        // into the round's accumulator — no per-client dense vectors.
        let sw_agg = Stopwatch::start();
        let outcomes: Vec<&ClientOutcome> = per_device.iter().flatten().collect();
        if outcomes.is_empty() {
            return Err(Error::Runtime("round produced no outcomes".into()));
        }
        let ctx = AggContext::from_config(self.params.clone(), &self.cfg)
            .expect_updates(outcomes.len());
        let mut agg =
            self.flow.make_aggregator(&self.engine, &self.cfg.model, ctx)?;
        let mut uplink_bytes = 0usize;
        for o in &outcomes {
            uplink_bytes += o.upload_bytes;
            let decoded = self.flow.decode_update(&o.update)?;
            agg.add(decoded.as_ref(), o.stats.num_samples as f64)?;
        }
        let new_params = agg.finish()?;
        if !new_params.is_finite() {
            return Err(Error::Runtime(format!(
                "round {round}: aggregated parameters diverged (NaN/Inf); \
                 lower the learning rate"
            )));
        }
        self.params = Arc::new(new_params);
        let agg_ms = sw_agg.elapsed_ms();

        // Evaluation.
        let (test_loss, test_accuracy) = if self.cfg.eval_every > 0
            && (round + 1) % self.cfg.eval_every == 0
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        // Tracking (three-level hierarchy).
        let clients: Vec<ClientMetrics> = outcomes
            .iter()
            .map(|o| ClientMetrics {
                client: o.client,
                num_samples: o.stats.num_samples,
                train_loss: o.stats.avg_loss(),
                train_accuracy: o.stats.accuracy(),
                compute_ms: o.compute_ms,
                wait_ms: o.wait_ms,
                round_ms: o.round_ms,
                upload_bytes: o.upload_bytes,
                device: o.device_name.clone(),
            })
            .collect();
        let total_samples: f64 =
            outcomes.iter().map(|o| o.stats.num_samples as f64).sum();
        let train_loss = outcomes
            .iter()
            .map(|o| o.stats.sum_loss)
            .sum::<f64>()
            / total_samples.max(1.0);
        let train_accuracy = outcomes
            .iter()
            .map(|o| o.stats.correct)
            .sum::<f64>()
            / total_samples.max(1.0);
        let metrics = RoundMetrics {
            round,
            train_loss,
            train_accuracy,
            test_loss,
            test_accuracy,
            round_ms: makespan_ms + agg_ms,
            distribution_ms,
            comm_bytes: downlink_bytes + uplink_bytes,
            // In-process training has full participation: everyone
            // selected reports, nobody drops, updates are never stale.
            selected: clients.len(),
            reported: clients.len(),
            clients,
            ..RoundMetrics::default()
        };
        self.tracker.record_round(metrics.clone());
        Ok(metrics)
    }

    /// Evaluate the global model on the IID test split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.evaluate_params(&self.params)
    }

    /// Evaluate arbitrary parameters (personalization diagnostics).
    pub fn evaluate_params(&self, params: &ParamVec) -> Result<(f64, f64)> {
        let mut sum_loss = 0.0;
        let mut correct = 0.0;
        let mut n = 0.0;
        for b in &self.test_batches {
            let (l, c) = self.engine.eval_step(&self.cfg.model, params, b)?;
            sum_loss += l;
            correct += c;
            n += b.mask.iter().sum::<f32>() as f64;
        }
        if n == 0.0 {
            return Err(Error::Runtime("empty test split".into()));
        }
        Ok((sum_loss / n, correct / n))
    }

    /// The engine (plugins may need aggregation access).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Elapsed simulated time (virtual-clock experiments).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }
}
