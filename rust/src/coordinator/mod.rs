//! Coordinator (paper §IV-A): the server module, the device pool, and the
//! round orchestration that composes selection, scheduling, training-flow
//! stages, aggregation, evaluation and tracking.

pub mod pool;
pub mod server;

pub use pool::{ClientFlowFactory, DevicePool};
pub use server::Server;
