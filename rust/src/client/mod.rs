//! Client-node logic (paper §IV-A "clients" module).
//!
//! One function executes a client's whole round — used identically by the
//! in-process device pool (standalone/distributed training) and by the
//! remote client service (production), which is exactly how the paper
//! decouples training from communication.

use std::sync::Arc;

use crate::flow::{run_client_round, ClientFlow, ModelPayload, TrainStats, Update};
use crate::runtime::Engine;
use crate::util::clock::{Clock, Stopwatch};

/// Work order for one client in one round.
#[derive(Clone)]
pub struct ClientJob {
    pub client: usize,
    pub round: usize,
    pub model: String,
    pub payload: ModelPayload,
    pub lr: f32,
    pub local_epochs: usize,
    pub batch_size: usize,
    pub data_amount: f64,
    /// Per-(client, round) seed for reproducible shuffling.
    pub seed: u64,
    /// System-heterogeneity speed ratio (1.0 ⇒ no straggling).
    pub speed_ratio: f64,
    /// Simulated device-class name (tracking).
    pub device_name: String,
}

/// Everything the server needs back from a client round.
#[derive(Debug)]
pub struct ClientOutcome {
    pub client: usize,
    pub update: Update,
    pub stats: TrainStats,
    /// Real HLO execution + data materialization time.
    pub compute_ms: f64,
    /// Simulated straggler wait injected after compute.
    pub wait_ms: f64,
    /// compute + wait: the time the scheduler profiles.
    pub round_ms: f64,
    pub upload_bytes: usize,
    pub device_name: String,
}

/// Execute one client round: materialize data, run the client stages,
/// then inject the system-heterogeneity wait.
pub fn execute_client_round(
    flow: &mut dyn ClientFlow,
    engine: &Engine,
    data: &dyn crate::data::registry::DataSource,
    clock: &dyn Clock,
    job: &ClientJob,
) -> crate::error::Result<ClientOutcome> {
    let sw = Stopwatch::start();
    let local = Arc::new(data.client_data(job.client, job.data_amount)?);
    let task = crate::flow::TrainTask {
        client: job.client,
        round: job.round,
        model: job.model.clone(),
        payload: job.payload.clone(),
        data: local,
        lr: job.lr,
        local_epochs: job.local_epochs,
        batch_size: job.batch_size,
        seed: job.seed,
    };
    let (update, stats) = run_client_round(flow, engine, &task)?;
    let compute_ms = sw.elapsed_ms();
    let wait_ms = (job.speed_ratio - 1.0).max(0.0) * compute_ms;
    clock.wait_ms(wait_ms);
    let upload_bytes = update.wire_bytes();
    Ok(ClientOutcome {
        client: job.client,
        update,
        stats,
        compute_ms,
        wait_ms,
        round_ms: compute_ms + wait_ms,
        upload_bytes,
        device_name: job.device_name.clone(),
    })
}
