//! Streaming per-coordinate quantile sketches: rank-based robust
//! aggregation without the O(cohort·P) buffer.
//!
//! The exact `"trimmed_mean"` / `"median"` aggregators materialize every
//! decoded update ([`super::robust::UpdateBuffer`]-style rows) because
//! order statistics need the whole column. At a 1M-client cohort that
//! buffer is the box's memory ceiling. This module holds the cohort's
//! *distribution* instead: one mergeable quantile sketch per coordinate
//! (a uniform-resolution cousin of the t-digest), capped at
//! [`SKETCH_CAP`] centroids, so memory is O(P · SKETCH_CAP) no matter
//! how many clients stream in.
//!
//! **Exact below the cap, approximate above it.** While at most
//! [`SKETCH_CAP`] updates have arrived, every centroid is one original
//! value with its original weight and the reductions replicate the
//! buffered path *bit-for-bit* — the exact aggregators stay the
//! equivalence oracle, and SimNet digests are untouched for its small
//! surrogate cohorts. Past the cap, centroids merge pairwise
//! (value-adjacent, weighted means) and the trim/median queries run on
//! cumulative centroid weight. Each compression halves the centroid
//! count, so a centroid never absorbs more than `cohort / (SKETCH_CAP/2)`
//! rows of *adjacent order statistics* — the quantile error is bounded
//! by that mass fraction (≈3% of the cohort at the default cap), which
//! the tolerance tests pin down against the exact path.
//!
//! **Deterministic everywhere.** Compression is sort + pairwise merge —
//! no RNG, no clocks — and coordinates are independent, so the
//! chunk-parallel layout (coordinate blocks on scoped threads, wired
//! through the same [`AggContext`] knobs as the rest of the plane) is
//! bit-identical to the sequential reduce at any thread count.
//!
//! Selected by `Config.agg_sketch = true`: the registry then builds
//! [`SketchTrimmedMean`] / [`SketchMedian`] under the *same*
//! `"trimmed_mean"` / `"median"` names, so every consumer — server flow,
//! remote ingest, SimNet, [`crate::runtime::Engine::accumulator`] — gets
//! the streaming variant purely from config.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;

use super::mean::{check_weight, MIN_PARALLEL_LEN};
use super::{AggContext, Aggregator};

/// Centroids kept per coordinate before a pairwise-merge compression.
/// 64 keeps small cohorts (every SimNet surrogate reduction) in the
/// exact regime while bounding memory at `P · 64 · 12` bytes.
pub const SKETCH_CAP: usize = 64;

/// One contiguous coordinate range of the sketch. Blocks are the unit
/// of parallelism: disjoint `&mut` regions, independently compressible.
/// Layout is slot-major inside the block (`means[s·width + j]`), so an
/// incoming row appends with one `extend_from_slice` per block.
struct Block {
    width: usize,
    /// Centroid means; slot s, local coordinate j at `s·width + j`.
    means: Vec<f32>,
    /// Matching centroid weights. Per-coordinate (not per-slot): after
    /// a compression the value-adjacent pairing differs per coordinate.
    weights: Vec<f64>,
}

impl Block {
    /// Pairwise-merge the `len` occupied slots down to `⌈len/2⌉`:
    /// per coordinate, sort centroids by mean and merge neighbours into
    /// their weighted mean. Element-wise independent and fully
    /// deterministic.
    fn compress(&mut self, len: usize) {
        let w = self.width;
        let new_len = len.div_ceil(2);
        let mut new_means = vec![0.0f32; new_len * w];
        let mut new_weights = vec![0.0f64; new_len * w];
        let mut col: Vec<(f32, f64)> = Vec::with_capacity(len);
        for j in 0..w {
            col.clear();
            for s in 0..len {
                col.push((self.means[s * w + j], self.weights[s * w + j]));
            }
            col.sort_by(|a, b| a.0.total_cmp(&b.0));
            for (t, pair) in col.chunks(2).enumerate() {
                let (m, wt) = match pair {
                    [a, b] => {
                        let wsum = a.1 + b.1;
                        let m = if wsum > 0.0 {
                            ((a.0 as f64 * a.1 + b.0 as f64 * b.1) / wsum)
                                as f32
                        } else {
                            // Two zero-weight centroids: keep midpoint.
                            ((a.0 as f64 + b.0 as f64) / 2.0) as f32
                        };
                        (m, wsum)
                    }
                    [a] => (a.0, a.1),
                    _ => unreachable!("chunks(2)"),
                };
                new_means[t * w + j] = m;
                new_weights[t * w + j] = wt;
            }
        }
        self.means = new_means;
        self.weights = new_weights;
    }
}

/// P independent per-coordinate quantile sketches sharing one slot
/// count (every added row contributes exactly one centroid to every
/// coordinate, and compression halves all coordinates together).
pub(crate) struct CoordSketches {
    p: usize,
    /// Coordinates per block (last block may be narrower).
    block_width: usize,
    blocks: Vec<Block>,
    /// Occupied slots, uniform across blocks and coordinates.
    len: usize,
    /// Rows folded in since construction / the last reset.
    count: usize,
    /// Sum of raw row weights, accumulated in arrival order (the same
    /// f64 order as the exact buffered path).
    total_weight: f64,
    /// Whether any lossy pairwise merge has happened: while false, the
    /// queries replicate the exact buffered reductions bit-for-bit.
    compressed: bool,
}

impl CoordSketches {
    fn from_ctx(ctx: &AggContext) -> CoordSketches {
        let p = ctx.global.len();
        let threads =
            if ctx.use_parallel(p) { ctx.effective_threads() } else { 1 };
        let nblocks = if threads > 1 && p >= MIN_PARALLEL_LEN {
            threads.min(p)
        } else {
            1
        };
        let block_width = p.div_ceil(nblocks.max(1)).max(1);
        let mut blocks = Vec::new();
        let mut start = 0;
        while start < p {
            let width = block_width.min(p - start);
            blocks.push(Block {
                width,
                means: Vec::new(),
                weights: Vec::new(),
            });
            start += width;
        }
        if blocks.is_empty() {
            blocks.push(Block { width: 0, means: Vec::new(), weights: Vec::new() });
        }
        CoordSketches {
            p,
            block_width,
            blocks,
            len: 0,
            count: 0,
            total_weight: 0.0,
            compressed: false,
        }
    }

    /// Fold one dense row in. `row.len()` must equal P (callers
    /// validate).
    fn add_row(&mut self, row: &[f32], weight: f64) {
        debug_assert_eq!(row.len(), self.p);
        if self.len == SKETCH_CAP {
            self.compress_all();
        }
        let mut start = 0;
        for block in &mut self.blocks {
            let end = start + block.width;
            block.means.extend_from_slice(&row[start..end]);
            let new_len = block.weights.len() + block.width;
            block.weights.resize(new_len, weight);
            start = end;
        }
        self.len += 1;
        self.count += 1;
        self.total_weight += weight;
    }

    fn compress_all(&mut self) {
        let len = self.len;
        if self.blocks.len() == 1 {
            self.blocks[0].compress(len);
        } else {
            std::thread::scope(|s| {
                for block in self.blocks.iter_mut() {
                    s.spawn(move || block.compress(len));
                }
            });
        }
        self.len = len.div_ceil(2);
        self.compressed = true;
    }

    /// Run `reduce(block, slots, dst)` over every block, chunk-parallel
    /// when the sketch was built with multiple blocks. `reduce` must be
    /// coordinate-wise independent (it is: every query below reads one
    /// column at a time), so the block layout never changes the result.
    fn for_each_block(
        &self,
        out: &mut [f32],
        reduce: &(dyn Fn(&Block, usize, &mut [f32]) + Sync),
    ) {
        let len = self.len;
        if self.blocks.len() == 1 {
            reduce(&self.blocks[0], len, out);
            return;
        }
        std::thread::scope(|s| {
            for (block, dst) in
                self.blocks.iter().zip(out.chunks_mut(self.block_width))
            {
                s.spawn(move || reduce(block, len, dst));
            }
        });
    }

    fn check_finish(&self) -> Result<()> {
        if self.count == 0 {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        }
        if self.total_weight <= 0.0 {
            return Err(Error::Runtime("aggregate: zero total weight".into()));
        }
        Ok(())
    }

    fn reset(&mut self) {
        for block in &mut self.blocks {
            block.means = Vec::new();
            block.weights = Vec::new();
        }
        self.len = 0;
        self.count = 0;
        self.total_weight = 0.0;
        self.compressed = false;
    }

    /// Bytes held by the centroid arrays right now — the number the
    /// memory-win tests and `ingest_bench` account (the exact path's
    /// equivalent is `cohort · P · 4` for its rows alone).
    pub(crate) fn approx_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.means.len() * 4 + b.weights.len() * 8)
            .sum()
    }
}

/// Shared `add` for the sketch aggregators: validate, densify
/// delta-encoded updates transiently (O(P), dropped after the fold —
/// never retained per-client), and feed the sketch.
fn add_update(
    sk: &mut CoordSketches,
    global: &ParamVec,
    update: &Update,
    weight: f64,
) -> Result<()> {
    check_weight(weight)?;
    match update {
        Update::Dense(x) => {
            if x.len() != global.len() {
                return Err(Error::Runtime(format!(
                    "aggregate: vector of len {} != P {}",
                    x.len(),
                    global.len()
                )));
            }
            sk.add_row(&x.0, weight);
        }
        Update::SparseTernary { .. } | Update::Encoded(_) => {
            let dense = update.to_dense(global)?;
            sk.add_row(&dense.0, weight);
        }
        Update::Masked { .. } => {
            return Err(Error::Runtime(
                "aggregate: masked update reached the aggregator; a \
                 server plugin with a decryption stage must unmask \
                 uploads first"
                    .into(),
            ))
        }
    }
    Ok(())
}

// ------------------------------------------------------- trimmed mean

/// Sketch-backed per-coordinate trimmed weighted mean: the
/// `"trimmed_mean"` entry when `Config.agg_sketch` is on.
pub struct SketchTrimmedMean {
    sk: CoordSketches,
    global: Arc<ParamVec>,
    trim_frac: f64,
}

impl SketchTrimmedMean {
    /// Build from a construction context; same `trim_frac` validation
    /// as the exact aggregator.
    pub fn from_ctx(ctx: &AggContext) -> Result<SketchTrimmedMean> {
        if !(0.0..0.5).contains(&ctx.trim_frac) {
            return Err(Error::Config(format!(
                "trimmed_mean: trim_frac must be in [0, 0.5), got {}",
                ctx.trim_frac
            )));
        }
        Ok(SketchTrimmedMean {
            sk: CoordSketches::from_ctx(ctx),
            global: ctx.global.clone(),
            trim_frac: ctx.trim_frac,
        })
    }

    /// Live centroid-array footprint in bytes (see
    /// [`CoordSketches::approx_bytes`]).
    pub fn sketch_bytes(&self) -> usize {
        self.sk.approx_bytes()
    }
}

impl Aggregator for SketchTrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        add_update(&mut self.sk, &self.global, update, weight)
    }

    fn count(&self) -> usize {
        self.sk.count
    }

    fn total_weight(&self) -> f64 {
        self.sk.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.sk.check_finish()?;
        let n = self.sk.count;
        let k = (self.trim_frac * n as f64).floor() as usize;
        if 2 * k >= n {
            return Err(Error::Runtime(format!(
                "trimmed_mean: trimming {k} from each end empties the \
                 cohort of {n}"
            )));
        }
        let total = self.sk.total_weight;
        let compressed = self.sk.compressed;
        let trim_frac = self.trim_frac;
        let mut out = vec![0.0f32; self.global.len()];
        let reduce = |block: &Block, len: usize, dst: &mut [f32]| {
            let w = block.width;
            // Exact regime: centroids ARE the original rows (arrival
            // order preserved) — replicate the buffered reduction
            // bit-for-bit.
            if !compressed && k == 0 {
                for (j, o) in dst.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for s in 0..len {
                        acc += block.weights[s * w + j]
                            * block.means[s * w + j] as f64;
                    }
                    *o = (acc / total) as f32;
                }
                return;
            }
            let mut col: Vec<(f32, f64)> = Vec::with_capacity(len);
            for (j, o) in dst.iter_mut().enumerate() {
                col.clear();
                for s in 0..len {
                    col.push((
                        block.means[s * w + j],
                        block.weights[s * w + j],
                    ));
                }
                col.sort_by(|a, b| a.0.total_cmp(&b.0));
                if !compressed {
                    // Item-count trimming, identical to the exact path.
                    let kept = &col[k..len - k];
                    let mut acc = 0.0f64;
                    let mut wsum = 0.0f64;
                    for (v, wt) in kept {
                        acc += wt * *v as f64;
                        wsum += wt;
                    }
                    *o = if wsum > 0.0 {
                        (acc / wsum) as f32
                    } else {
                        (kept.iter().map(|(v, _)| *v as f64).sum::<f64>()
                            / kept.len() as f64) as f32
                    };
                } else {
                    // Compressed regime: trim by cumulative weight
                    // *mass* (the weighted generalization of per-end
                    // item trimming), with boundary centroids counted
                    // fractionally.
                    let cut = trim_frac * total;
                    let lo = cut;
                    let hi = total - cut;
                    let mut acc = 0.0f64;
                    let mut wsum = 0.0f64;
                    let mut c0 = 0.0f64;
                    for (v, wt) in &col {
                        let c1 = c0 + wt;
                        let overlap = (c1.min(hi) - c0.max(lo)).max(0.0);
                        if overlap > 0.0 {
                            acc += *v as f64 * overlap;
                            wsum += overlap;
                        }
                        c0 = c1;
                    }
                    *o = if wsum > 0.0 {
                        (acc / wsum) as f32
                    } else {
                        // Degenerate mass distribution: fall back to the
                        // unweighted centroid mean.
                        (col.iter().map(|(v, _)| *v as f64).sum::<f64>()
                            / col.len() as f64) as f32
                    };
                }
            }
        };
        self.sk.for_each_block(&mut out, &reduce);
        self.sk.reset();
        Ok(ParamVec(out))
    }
}

// ------------------------------------------------------------- median

/// Sketch-backed per-coordinate weighted lower median: the `"median"`
/// entry when `Config.agg_sketch` is on.
pub struct SketchMedian {
    sk: CoordSketches,
    global: Arc<ParamVec>,
}

impl SketchMedian {
    pub fn from_ctx(ctx: &AggContext) -> SketchMedian {
        SketchMedian {
            sk: CoordSketches::from_ctx(ctx),
            global: ctx.global.clone(),
        }
    }

    /// Live centroid-array footprint in bytes.
    pub fn sketch_bytes(&self) -> usize {
        self.sk.approx_bytes()
    }
}

impl Aggregator for SketchMedian {
    fn name(&self) -> &'static str {
        "median"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        add_update(&mut self.sk, &self.global, update, weight)
    }

    fn count(&self) -> usize {
        self.sk.count
    }

    fn total_weight(&self) -> f64 {
        self.sk.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.sk.check_finish()?;
        let half = self.sk.total_weight / 2.0;
        let mut out = vec![0.0f32; self.global.len()];
        let reduce = |block: &Block, len: usize, dst: &mut [f32]| {
            let w = block.width;
            let mut col: Vec<(f32, f64)> = Vec::with_capacity(len);
            for (j, o) in dst.iter_mut().enumerate() {
                col.clear();
                for s in 0..len {
                    col.push((
                        block.means[s * w + j],
                        block.weights[s * w + j],
                    ));
                }
                col.sort_by(|a, b| a.0.total_cmp(&b.0));
                // Weighted lower median over centroids. In the exact
                // regime this is precisely the buffered reduction; once
                // compressed it returns a centroid mean within the
                // merged neighbourhood of the true median.
                let mut cum = 0.0f64;
                let mut pick = col[len - 1].0;
                for (v, wt) in &col {
                    cum += wt;
                    if cum >= half {
                        pick = *v;
                        break;
                    }
                }
                *o = pick;
            }
        };
        self.sk.for_each_block(&mut out, &reduce);
        self.sk.reset();
        Ok(ParamVec(out))
    }
}

#[cfg(test)]
mod tests {
    use super::super::robust::{
        CoordinateMedianAggregator, TrimmedMeanAggregator,
    };
    use super::*;
    use crate::util::rng::Rng;

    fn ctx(p: usize) -> AggContext {
        AggContext::new(Arc::new(ParamVec::zeros(p)))
    }

    fn random_cohort(
        seed: u64,
        n: usize,
        p: usize,
    ) -> Vec<(Update, f64)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let row =
                    (0..p).map(|_| rng.normal() as f32).collect::<Vec<_>>();
                let weight = 1.0 + rng.below(3) as f64;
                (Update::Dense(ParamVec(row)), weight)
            })
            .collect()
    }

    fn reduce(
        agg: &mut dyn Aggregator,
        cohort: &[(Update, f64)],
    ) -> ParamVec {
        for (u, w) in cohort {
            agg.add(u, *w).unwrap();
        }
        agg.finish().unwrap()
    }

    #[test]
    fn uncompressed_sketch_is_bit_identical_to_the_exact_path() {
        // Cohort under SKETCH_CAP: the sketch must replicate the
        // buffered aggregators exactly, bit for bit.
        let p = 37;
        let cohort = random_cohort(11, SKETCH_CAP - 3, p);
        for trim_frac in [0.0, 0.1, 0.3] {
            let mut c = ctx(p);
            c.trim_frac = trim_frac;
            let exact = reduce(
                &mut TrimmedMeanAggregator::from_ctx(&c).unwrap(),
                &cohort,
            );
            let sketch =
                reduce(&mut SketchTrimmedMean::from_ctx(&c).unwrap(), &cohort);
            for (a, b) in exact.iter().zip(sketch.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "trim {trim_frac}");
            }
        }
        let c = ctx(p);
        let exact =
            reduce(&mut CoordinateMedianAggregator::from_ctx(&c), &cohort);
        let sketch = reduce(&mut SketchMedian::from_ctx(&c), &cohort);
        for (a, b) in exact.iter().zip(sketch.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn compressed_sketch_tracks_the_exact_path_within_tolerance() {
        // Cohort far over the cap: lossy regime. Each centroid absorbs
        // ≤ n/(SKETCH_CAP/2) value-adjacent rows, so the quantile error
        // is a small mass fraction; for N(0,1) data the reduced values
        // must stay near the exact ones.
        let p = 29;
        let n = 8 * SKETCH_CAP;
        let cohort = random_cohort(23, n, p);
        let mut c = ctx(p);
        c.trim_frac = 0.2;
        let exact = reduce(
            &mut TrimmedMeanAggregator::from_ctx(&c).unwrap(),
            &cohort,
        );
        let sketch =
            reduce(&mut SketchTrimmedMean::from_ctx(&c).unwrap(), &cohort);
        for (a, b) in exact.iter().zip(sketch.iter()) {
            assert!(
                (a - b).abs() < 0.1,
                "trimmed mean drifted: exact {a}, sketch {b}"
            );
        }
        let exact =
            reduce(&mut CoordinateMedianAggregator::from_ctx(&c), &cohort);
        let sketch = reduce(&mut SketchMedian::from_ctx(&c), &cohort);
        for (a, b) in exact.iter().zip(sketch.iter()) {
            assert!(
                (a - b).abs() < 0.2,
                "median drifted: exact {a}, sketch {b}"
            );
        }
    }

    #[test]
    fn sketch_results_are_thread_count_invariant() {
        // Chunk-parallel (multi-block) and sequential layouts must
        // produce bit-identical results in the compressed regime too.
        let p = MIN_PARALLEL_LEN;
        let n = 2 * SKETCH_CAP + 5;
        let cohort = random_cohort(7, n, p);
        let mut seq = ctx(p);
        seq.trim_frac = 0.25;
        let mut par = seq.clone();
        par.threads = 4;
        par.parallel_threshold = 0;
        par.expect_updates = n;
        let a =
            reduce(&mut SketchTrimmedMean::from_ctx(&seq).unwrap(), &cohort);
        let b =
            reduce(&mut SketchTrimmedMean::from_ctx(&par).unwrap(), &cohort);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let a = reduce(&mut SketchMedian::from_ctx(&seq), &cohort);
        let b = reduce(&mut SketchMedian::from_ctx(&par), &cohort);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sketch_memory_stays_bounded_by_the_cap() {
        let p = 256;
        let n = 4096; // 64× the cap
        let mut agg = SketchMedian::from_ctx(&ctx(p));
        let mut rng = Rng::new(3);
        let mut peak = 0usize;
        for _ in 0..n {
            let row: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
            agg.add(&Update::Dense(ParamVec(row)), 1.0).unwrap();
            peak = peak.max(agg.sketch_bytes());
        }
        // Centroid arrays: ≤ SKETCH_CAP slots × (4 + 8) bytes per
        // coordinate, regardless of cohort size.
        assert!(peak <= SKETCH_CAP * p * 12, "peak {peak}");
        // The exact path would hold cohort·P·4 bytes of rows — the
        // sketch must be an order of magnitude under that here, and the
        // gap widens linearly with cohort size.
        assert!(peak * 10 < n * p * 4, "no win over buffering: {peak}");
        agg.finish().unwrap();
        assert_eq!(agg.sketch_bytes(), 0, "finish releases the arrays");
    }

    #[test]
    fn sketch_aggregators_reset_for_reuse_and_validate_inputs() {
        let c = ctx(8);
        let mut agg = SketchTrimmedMean::from_ctx(&c).unwrap();
        assert!(agg.finish().is_err(), "empty cohort");
        let cohort = random_cohort(5, 10, 8);
        let first = reduce(&mut agg, &cohort);
        // Same instance, same cohort again: identical result.
        let second = reduce(&mut agg, &cohort);
        for (a, b) in first.iter().zip(second.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Wrong length and masked updates are typed errors.
        assert!(agg
            .add(&Update::Dense(ParamVec(vec![0.0; 3])), 1.0)
            .is_err());
        let masked = Update::Masked {
            xor_key: 1,
            inner: Box::new(Update::Dense(ParamVec(vec![0.0; 8]))),
        };
        let err = agg.add(&masked, 1.0).unwrap_err().to_string();
        assert!(err.contains("decryption stage"), "{err}");
        // Hostile trim fractions are rejected at construction.
        let mut bad = ctx(8);
        bad.trim_frac = 0.5;
        assert!(SketchTrimmedMean::from_ctx(&bad).is_err());
    }

    #[test]
    fn sketch_folds_sparse_and_encoded_updates_like_the_exact_path() {
        let p = 16;
        let global = Arc::new(ParamVec(
            (0..p).map(|i| i as f32 * 0.1).collect::<Vec<_>>(),
        ));
        let mut c = AggContext::new(global.clone());
        c.trim_frac = 0.0;
        let mut rng = Rng::new(17);
        let mut cohort: Vec<(Update, f64)> = Vec::new();
        for _ in 0..12 {
            let new = ParamVec(
                global
                    .iter()
                    .map(|g| g + rng.normal() as f32 * 0.05)
                    .collect::<Vec<_>>(),
            );
            let update = crate::codec::parse("top_k(0.5)")
                .unwrap()
                .encode(new, &global)
                .unwrap();
            cohort.push((update, 1.0 + rng.below(2) as f64));
        }
        cohort.push((
            Update::SparseTernary {
                len: p,
                indices: vec![0, 5],
                signs: vec![true, false],
                magnitude: 0.25,
            },
            2.0,
        ));
        let exact = reduce(
            &mut TrimmedMeanAggregator::from_ctx(&c).unwrap(),
            &cohort,
        );
        let sketch =
            reduce(&mut SketchTrimmedMean::from_ctx(&c).unwrap(), &cohort);
        for (a, b) in exact.iter().zip(sketch.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
