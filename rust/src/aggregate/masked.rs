//! Slice-masked aggregation (the `"backbone"` registry entry).
//!
//! FedReID federates the feature backbone while each client keeps a
//! personal classifier head — on the flat-parameter contract, the
//! trailing `protected_tail` coordinates. The old batch path averaged
//! the full vector and discarded the head average anyway (clients
//! restore their own heads on download); this accumulator never touches
//! the tail at all: only the backbone slice is reduced, and the global
//! model's own head is carried over unchanged, keeping it finite and
//! stable without averaging incompatible identity spaces.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;
use crate::obs::Telemetry;

use super::mean::{axpy_into, check_weight, finish_into};
use super::{AggContext, Aggregator};

/// Weighted mean over the leading `P − protected_tail` coordinates; the
/// trailing slice is copied from the global model at `finish`.
pub struct SliceMaskedAggregator {
    /// Accumulator over the backbone slice only.
    acc: Vec<f64>,
    sparse_weight: f64,
    total_weight: f64,
    count: usize,
    global: Arc<ParamVec>,
    /// Backbone length = P − protected_tail.
    split: usize,
    threads: usize,
    tel: Telemetry,
}

impl SliceMaskedAggregator {
    pub fn from_ctx(ctx: &AggContext) -> SliceMaskedAggregator {
        let p = ctx.global.len();
        let split = p.saturating_sub(ctx.protected_tail);
        let threads =
            if ctx.use_parallel(split) { ctx.effective_threads() } else { 1 };
        SliceMaskedAggregator {
            acc: vec![0.0; split],
            sparse_weight: 0.0,
            total_weight: 0.0,
            count: 0,
            global: ctx.global.clone(),
            split,
            threads,
            tel: ctx.tel.clone(),
        }
    }

    /// Coordinates excluded from aggregation (the personal-head length).
    pub fn protected_tail(&self) -> usize {
        self.global.len() - self.split
    }
}

impl Aggregator for SliceMaskedAggregator {
    fn name(&self) -> &'static str {
        "backbone"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        check_weight(weight)?;
        let p = self.global.len();
        match update {
            Update::Dense(x) => {
                if x.len() != p {
                    return Err(Error::Runtime(format!(
                        "aggregate: vector of len {} != P {p}",
                        x.len()
                    )));
                }
                axpy_into(&mut self.acc, &x[..self.split], weight, self.threads);
            }
            // Delta-encoded (sparse ternary / codec-encoded) updates go
            // through the shared fold with the backbone split as the
            // active limit: head coordinates are protected, so deltas
            // there are dropped exactly as a backbone-only upload would
            // be. Masked errors inside the shared fold.
            _ => {
                super::fold_delta_update(
                    &mut self.acc,
                    p,
                    update,
                    weight,
                    self.split,
                )?;
                self.sparse_weight += weight;
            }
        }
        self.count += 1;
        self.total_weight += weight;
        Ok(())
    }

    fn count(&self) -> usize {
        self.count
    }

    fn total_weight(&self) -> f64 {
        self.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        if self.count == 0 {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        }
        if self.total_weight <= 0.0 {
            return Err(Error::Runtime("aggregate: zero total weight".into()));
        }
        let mut out = finish_into(
            &self.acc,
            &self.global[..self.split],
            self.sparse_weight,
            self.total_weight,
            self.threads,
            &self.tel,
        );
        // Protected tail: the global model's own head, untouched.
        out.extend_from_slice(&self.global[self.split..]);
        self.acc.iter_mut().for_each(|v| *v = 0.0);
        self.sparse_weight = 0.0;
        self.total_weight = 0.0;
        self.count = 0;
        Ok(ParamVec(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(global: Vec<f32>, tail: usize) -> AggContext {
        AggContext::new(Arc::new(ParamVec(global))).protected_tail(tail)
    }

    #[test]
    fn backbone_is_averaged_and_tail_is_kept_from_the_global() {
        let mut agg =
            SliceMaskedAggregator::from_ctx(&ctx(vec![9.0, 9.0, 7.0, 8.0], 2));
        assert_eq!(agg.protected_tail(), 2);
        agg.add(&Update::Dense(ParamVec(vec![1.0, 2.0, 0.0, 0.0])), 1.0)
            .unwrap();
        agg.add(&Update::Dense(ParamVec(vec![3.0, 6.0, 5.0, 5.0])), 3.0)
            .unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 2.5).abs() < 1e-7);
        assert!((out[1] - 5.0).abs() < 1e-7);
        // Client head values are ignored; the global head survives.
        assert_eq!(&out.0[2..], &[7.0, 8.0]);
    }

    #[test]
    fn sparse_deltas_in_the_tail_are_dropped() {
        let mut agg = SliceMaskedAggregator::from_ctx(&ctx(vec![1.0; 4], 1));
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![0, 3],
            signs: vec![true, true],
            magnitude: 2.0,
        };
        agg.add(&u, 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 3.0).abs() < 1e-7, "backbone delta applies");
        assert!((out[3] - 1.0).abs() < 1e-7, "head delta is protected");
    }

    #[test]
    fn zero_tail_degenerates_to_the_plain_mean() {
        let mut agg = SliceMaskedAggregator::from_ctx(&ctx(vec![0.0; 3], 0));
        agg.add(&Update::Dense(ParamVec(vec![2.0, 4.0, 6.0])), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn finish_resets_between_rounds() {
        let mut agg = SliceMaskedAggregator::from_ctx(&ctx(vec![0.0; 3], 1));
        agg.add(&Update::Dense(ParamVec(vec![2.0, 2.0, 2.0])), 1.0).unwrap();
        agg.finish().unwrap();
        assert_eq!(agg.count(), 0);
        agg.add(&Update::Dense(ParamVec(vec![4.0, 4.0, 4.0])), 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert_eq!(out.0, vec![4.0, 4.0, 0.0]);
    }
}
