//! Byzantine-robust aggregation (the `"trimmed_mean"`, `"median"` and
//! `"norm_clip"` registry entries).
//!
//! The weighted mean is a single-point-of-failure reduction: one hostile
//! client shifting its update by `n·Δ` moves the aggregate by `Δ`. The
//! three accumulators here bound that influence:
//!
//! * [`TrimmedMeanAggregator`] — per coordinate, drop the `⌊f·n⌋` lowest
//!   and highest values before the weighted mean. Tolerates up to `⌊f·n⌋`
//!   arbitrarily corrupted updates per coordinate; `f = 0` degenerates to
//!   the plain weighted mean bit-for-bit on dense cohorts.
//! * [`CoordinateMedianAggregator`] — per coordinate, the weighted lower
//!   median. As long as corrupted weight stays below half the total, the
//!   output is pinned inside the honest clients' per-coordinate envelope.
//! * [`NormClipAggregator`] — rescale each update's delta from the global
//!   model to L2 norm ≤ `clip_norm`, then reduce with the streaming mean.
//!   Updates already under the threshold pass through *unchanged* (the
//!   reduction is bit-identical to `"mean"`), so clipping costs honest
//!   clients nothing while capping any single client's pull at
//!   `clip_norm / Σw`. With `clip_norm = 0` the threshold is *adaptive*:
//!   a DP-FedAvg-style geometric update tracks the
//!   [`ADAPTIVE_CLIP_QUANTILE`] of observed honest norms, so no tuning
//!   is needed — the threshold converges onto the stationary norm
//!   distribution and outliers beyond it are clipped.
//!
//! Order statistics need the whole cohort, so the trimmed mean and the
//! median buffer decoded updates — O(cohort·P) memory, the intrinsic
//! price of rank-based robustness (norm-clip stays O(P) streaming). Both
//! reduce chunk-parallel over coordinate ranges for large vectors,
//! element-wise independent and therefore bit-identical to the
//! sequential path.
//!
//! All three are selectable per config: `cfg.agg = "trimmed_mean"` (with
//! `cfg.agg_trim_frac` / `cfg.agg_clip_norm`) makes any algorithm
//! Byzantine-robust without touching its flow.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;

use super::mean::{check_weight, MeanAggregator, MIN_PARALLEL_LEN};
use super::{AggContext, Aggregator};

/// Decoded-cohort buffer shared by the rank-based aggregators: every
/// update is validated and materialized dense against the global model.
struct UpdateBuffer {
    global: Arc<ParamVec>,
    /// (decoded dense update, raw weight), in arrival order.
    rows: Vec<(Vec<f32>, f64)>,
    total_weight: f64,
    threads: usize,
}

impl UpdateBuffer {
    fn from_ctx(ctx: &AggContext) -> UpdateBuffer {
        let len = ctx.global.len();
        let threads =
            if ctx.use_parallel(len) { ctx.effective_threads() } else { 1 };
        UpdateBuffer {
            global: ctx.global.clone(),
            rows: Vec::with_capacity(ctx.expect_updates),
            total_weight: 0.0,
            threads,
        }
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        check_weight(weight)?;
        let p = self.global.len();
        let dense = match update {
            Update::Dense(x) => {
                if x.len() != p {
                    return Err(Error::Runtime(format!(
                        "aggregate: vector of len {} != P {p}",
                        x.len()
                    )));
                }
                x.0.clone()
            }
            // Rank statistics intrinsically need the dense column view,
            // so delta-encoded updates (sparse ternary / codec-encoded)
            // materialize here; `to_dense` runs the integrity check on
            // encoded payloads.
            Update::SparseTernary { .. } | Update::Encoded(_) => {
                update.to_dense(&self.global)?.0
            }
            Update::Masked { .. } => {
                return Err(Error::Runtime(
                    "aggregate: masked update reached the aggregator; a \
                     server plugin with a decryption stage must unmask \
                     uploads first"
                        .into(),
                ))
            }
        };
        self.rows.push((dense, weight));
        self.total_weight += weight;
        Ok(())
    }

    fn check_finish(&self) -> Result<()> {
        if self.rows.is_empty() {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        }
        if self.total_weight <= 0.0 {
            return Err(Error::Runtime("aggregate: zero total weight".into()));
        }
        Ok(())
    }

    /// Run `reduce(offset, dst)` over the P coordinates, chunk-parallel
    /// for large vectors. `reduce` must be element-wise independent so
    /// the thread count never changes the result.
    fn for_each_chunk(&self, out: &mut [f32], reduce: &(dyn Fn(usize, &mut [f32]) + Sync)) {
        if self.threads <= 1 || out.len() < MIN_PARALLEL_LEN {
            reduce(0, out);
            return;
        }
        let chunk = out.len().div_ceil(self.threads);
        std::thread::scope(|s| {
            for (ci, dst) in out.chunks_mut(chunk).enumerate() {
                s.spawn(move || reduce(ci * chunk, dst));
            }
        });
    }

    fn reset(&mut self) {
        self.rows.clear();
        self.total_weight = 0.0;
    }
}

// ------------------------------------------------------- trimmed mean

/// Per-coordinate trimmed weighted mean (the `"trimmed_mean"` entry).
pub struct TrimmedMeanAggregator {
    buf: UpdateBuffer,
    /// Fraction trimmed from *each* end, in [0, 0.5).
    trim_frac: f64,
}

impl TrimmedMeanAggregator {
    /// Build from a construction context; `ctx.trim_frac` must be in
    /// [0, 0.5) — trimming half the cohort from both ends leaves nothing.
    pub fn from_ctx(ctx: &AggContext) -> Result<TrimmedMeanAggregator> {
        if !(0.0..0.5).contains(&ctx.trim_frac) {
            return Err(Error::Config(format!(
                "trimmed_mean: trim_frac must be in [0, 0.5), got {}",
                ctx.trim_frac
            )));
        }
        Ok(TrimmedMeanAggregator {
            buf: UpdateBuffer::from_ctx(ctx),
            trim_frac: ctx.trim_frac,
        })
    }
}

impl Aggregator for TrimmedMeanAggregator {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        self.buf.add(update, weight)
    }

    fn count(&self) -> usize {
        self.buf.rows.len()
    }

    fn total_weight(&self) -> f64 {
        self.buf.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.buf.check_finish()?;
        let n = self.buf.rows.len();
        let k = (self.trim_frac * n as f64).floor() as usize;
        // trim_frac < 0.5 guarantees 2k < n; keep the guard for direct
        // construction with a hostile fraction.
        if 2 * k >= n {
            return Err(Error::Runtime(format!(
                "trimmed_mean: trimming {k} from each end empties the \
                 cohort of {n}"
            )));
        }
        let rows = &self.buf.rows;
        let total = self.buf.total_weight;
        let mut out = vec![0.0f32; self.buf.global.len()];
        let reduce = |offset: usize, dst: &mut [f32]| {
            // k == 0: sum in arrival order, exactly like the streaming
            // mean — bit-identical on dense cohorts.
            if k == 0 {
                for (i, o) in dst.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for (row, w) in rows {
                        acc += w * row[offset + i] as f64;
                    }
                    *o = (acc / total) as f32;
                }
                return;
            }
            let mut col: Vec<(f32, f64)> = Vec::with_capacity(n);
            for (i, o) in dst.iter_mut().enumerate() {
                col.clear();
                col.extend(rows.iter().map(|(row, w)| (row[offset + i], *w)));
                col.sort_by(|a, b| a.0.total_cmp(&b.0));
                let kept = &col[k..n - k];
                let mut acc = 0.0f64;
                let mut wsum = 0.0f64;
                for (v, w) in kept {
                    acc += w * *v as f64;
                    wsum += w;
                }
                *o = if wsum > 0.0 {
                    (acc / wsum) as f32
                } else {
                    // Every surviving weight is zero: fall back to the
                    // unweighted mean of the kept values.
                    (kept.iter().map(|(v, _)| *v as f64).sum::<f64>()
                        / kept.len() as f64) as f32
                };
            }
        };
        self.buf.for_each_chunk(&mut out, &reduce);
        self.buf.reset();
        Ok(ParamVec(out))
    }
}

// ------------------------------------------------------------- median

/// Per-coordinate weighted lower median (the `"median"` entry).
pub struct CoordinateMedianAggregator {
    buf: UpdateBuffer,
}

impl CoordinateMedianAggregator {
    pub fn from_ctx(ctx: &AggContext) -> CoordinateMedianAggregator {
        CoordinateMedianAggregator { buf: UpdateBuffer::from_ctx(ctx) }
    }
}

impl Aggregator for CoordinateMedianAggregator {
    fn name(&self) -> &'static str {
        "median"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        self.buf.add(update, weight)
    }

    fn count(&self) -> usize {
        self.buf.rows.len()
    }

    fn total_weight(&self) -> f64 {
        self.buf.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.buf.check_finish()?;
        let n = self.buf.rows.len();
        let rows = &self.buf.rows;
        let half = self.buf.total_weight / 2.0;
        let mut out = vec![0.0f32; self.buf.global.len()];
        let reduce = |offset: usize, dst: &mut [f32]| {
            let mut col: Vec<(f32, f64)> = Vec::with_capacity(n);
            for (i, o) in dst.iter_mut().enumerate() {
                col.clear();
                col.extend(rows.iter().map(|(row, w)| (row[offset + i], *w)));
                col.sort_by(|a, b| a.0.total_cmp(&b.0));
                // Weighted lower median: the smallest value whose
                // cumulative weight reaches half the total. The output
                // is always one of the input values, so with honest
                // weight > half it cannot leave the honest envelope.
                let mut cum = 0.0f64;
                let mut pick = col[n - 1].0;
                for (v, w) in &col {
                    cum += w;
                    if cum >= half {
                        pick = *v;
                        break;
                    }
                }
                *o = pick;
            }
        };
        self.buf.for_each_chunk(&mut out, &reduce);
        self.buf.reset();
        Ok(ParamVec(out))
    }
}

// ---------------------------------------------------------- norm clip

/// Adaptive clipping targets this quantile of observed update norms
/// (DP-FedAvg uses the median; a high quantile leaves honest stragglers
/// untouched while still capping outliers).
pub const ADAPTIVE_CLIP_QUANTILE: f64 = 0.95;

/// Geometric step size of the adaptive threshold update: each observed
/// norm nudges the threshold by `exp(±η)`-ish factors, so the estimate
/// tracks slow drift without chasing single outliers.
pub const ADAPTIVE_CLIP_ETA: f64 = 0.05;

/// Initial adaptive threshold, before any norm has been observed.
/// Deliberately conservative: over-clipping early honest updates only
/// shrinks their magnitude (direction is preserved) and the geometric
/// update recovers the true scale within tens of observations — whereas
/// seeding from the first *observed* norm would let a Byzantine client
/// that reports first disable clipping for its whole window.
pub const ADAPTIVE_CLIP_INIT: f64 = 1.0;

/// Running-quantile threshold tracker (DP-FedAvg-style adaptive
/// clipping): `C ← C · exp(−η (b − γ))` where `b` indicates the norm
/// fell at/under the current threshold and `γ` is the target quantile.
/// The fixed point satisfies `P(norm ≤ C) = γ`, i.e. `C` converges onto
/// the `γ`-quantile of a stationary norm distribution from the
/// conservative [`ADAPTIVE_CLIP_INIT`] start.
struct AdaptiveClip {
    threshold: f64,
}

impl AdaptiveClip {
    fn new() -> AdaptiveClip {
        AdaptiveClip { threshold: ADAPTIVE_CLIP_INIT }
    }

    /// Observe one norm and return the threshold to clip it against
    /// (the pre-update estimate — no single observation, however large,
    /// can raise the threshold applied to itself).
    fn observe(&mut self, norm: f64) -> f64 {
        let c = self.threshold;
        let below = if norm <= c { 1.0 } else { 0.0 };
        self.threshold = (c
            * (-ADAPTIVE_CLIP_ETA * (below - ADAPTIVE_CLIP_QUANTILE)).exp())
        .max(f64::MIN_POSITIVE);
        c
    }
}

enum ClipMode {
    /// Fixed threshold from `agg_clip_norm`.
    Static(f64),
    /// Running-quantile threshold (selected by `agg_clip_norm = 0`).
    Adaptive(AdaptiveClip),
}

impl ClipMode {
    /// The threshold this norm is clipped against (adaptive mode also
    /// folds the observation into the running estimate).
    fn threshold_for(&mut self, norm: f64) -> f64 {
        match self {
            ClipMode::Static(c) => *c,
            ClipMode::Adaptive(a) => a.observe(norm),
        }
    }
}

/// L2 norm clipping in front of the streaming mean (the `"norm_clip"`
/// entry): each update's delta from the global model is rescaled to norm
/// ≤ `clip_norm` before it folds in. Below-threshold updates are
/// forwarded verbatim, so the un-attacked reduction is bit-identical to
/// `"mean"` — and memory stays O(P), fully streaming. `clip_norm = 0`
/// selects the adaptive running-quantile threshold; the tracker state
/// survives `finish`, so a long-lived aggregator keeps refining its
/// estimate across rounds.
pub struct NormClipAggregator {
    inner: MeanAggregator,
    global: Arc<ParamVec>,
    clip: ClipMode,
}

impl NormClipAggregator {
    /// Build from a construction context; `ctx.clip_norm` must be a
    /// positive finite threshold, or exactly 0 for adaptive clipping.
    pub fn from_ctx(ctx: &AggContext) -> Result<NormClipAggregator> {
        let clip = if ctx.clip_norm == 0.0 {
            ClipMode::Adaptive(AdaptiveClip::new())
        } else if ctx.clip_norm > 0.0 && ctx.clip_norm.is_finite() {
            ClipMode::Static(ctx.clip_norm)
        } else {
            return Err(Error::Config(format!(
                "norm_clip: clip_norm must be finite and ≥ 0 (0 = \
                 adaptive), got {}",
                ctx.clip_norm
            )));
        };
        Ok(NormClipAggregator {
            inner: MeanAggregator::from_ctx(ctx),
            global: ctx.global.clone(),
            clip,
        })
    }

    /// The current clipping threshold (the running estimate in adaptive
    /// mode, starting from [`ADAPTIVE_CLIP_INIT`]).
    pub fn clip_threshold(&self) -> f64 {
        match &self.clip {
            ClipMode::Static(c) => *c,
            ClipMode::Adaptive(a) => a.threshold,
        }
    }
}

impl Aggregator for NormClipAggregator {
    fn name(&self) -> &'static str {
        "norm_clip"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        match update {
            Update::Dense(x) => {
                if x.len() != self.global.len() {
                    // Let the inner mean produce the canonical error.
                    return self.inner.add(update, weight);
                }
                let norm2: f64 = x
                    .iter()
                    .zip(self.global.iter())
                    .map(|(v, g)| {
                        let d = (*v - *g) as f64;
                        d * d
                    })
                    .sum();
                let norm = norm2.sqrt();
                if !norm.is_finite() {
                    return Err(Error::Runtime(
                        "norm_clip: update delta has non-finite norm \
                         (NaN/Inf poisoning rejected)"
                            .into(),
                    ));
                }
                let clip = self.clip.threshold_for(norm);
                if norm <= clip {
                    return self.inner.add(update, weight);
                }
                let scale = (clip / norm) as f32;
                let clipped: Vec<f32> = x
                    .iter()
                    .zip(self.global.iter())
                    .map(|(v, g)| g + scale * (v - g))
                    .collect();
                self.inner.add(&Update::Dense(ParamVec(clipped)), weight)
            }
            Update::SparseTernary { len, indices, signs, magnitude } => {
                if !magnitude.is_finite() {
                    return Err(Error::Runtime(
                        "norm_clip: update delta has non-finite norm \
                         (NaN/Inf poisoning rejected)"
                            .into(),
                    ));
                }
                // A ternary delta is ±magnitude at each index, so its
                // L2 norm is |magnitude|·√k; uniform rescaling keeps it
                // ternary with a shrunk magnitude.
                let norm =
                    (*magnitude as f64).abs() * (indices.len() as f64).sqrt();
                let clip = self.clip.threshold_for(norm);
                if norm <= clip {
                    return self.inner.add(update, weight);
                }
                let clipped = Update::SparseTernary {
                    len: *len,
                    indices: indices.clone(),
                    signs: signs.clone(),
                    magnitude: magnitude * (clip / norm) as f32,
                };
                self.inner.add(&clipped, weight)
            }
            Update::Encoded(e) => {
                // Integrity-verified sparse norm — no dense
                // materialization unless the update actually clips.
                let norm = e.delta_l2(self.global.len())?;
                if !norm.is_finite() {
                    return Err(Error::Runtime(
                        "norm_clip: update delta has non-finite norm \
                         (NaN/Inf poisoning rejected)"
                            .into(),
                    ));
                }
                let clip = self.clip.threshold_for(norm);
                if norm <= clip {
                    return self.inner.add(update, weight);
                }
                // Clipping de-quantizes: decode, rescale the delta, and
                // fold the dense result (rare path — only over-threshold
                // updates pay it).
                let dense = update.to_dense(&self.global)?;
                let scale = (clip / norm) as f32;
                let clipped: Vec<f32> = dense
                    .iter()
                    .zip(self.global.iter())
                    .map(|(v, g)| g + scale * (v - g))
                    .collect();
                self.inner.add(&Update::Dense(ParamVec(clipped)), weight)
            }
            Update::Masked { .. } => self.inner.add(update, weight),
        }
    }

    fn count(&self) -> usize {
        self.inner.count()
    }

    fn total_weight(&self) -> f64 {
        self.inner.total_weight()
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.inner.finish()
    }
}

// --------------------------------------------------------------- krum

/// Krum selection (the `"krum"` entry): return the *single* buffered
/// update whose summed squared distance to its `n − f − 2` nearest
/// peers is smallest.
///
/// Where the trimmed mean and the median are per-coordinate order
/// statistics, Krum is a whole-vector distance rule: a corrupted update
/// is far from the honest cluster in L2 no matter which coordinates it
/// poisoned, so with `f < (n − 2) / 2` Byzantine updates the minimizer
/// is an honest vector (Blanchard et al., NeurIPS 2017). The assumed
/// Byzantine count is `f = ⌊trim_frac·n⌋` — the same knob the trimmed
/// mean uses — clamped so at least one neighbor distance always scores.
///
/// Selection ignores weights (distance is a property of the vectors);
/// the chosen update is returned verbatim. O(n²·P) pairwise distances —
/// the intrinsic price of distance-based robustness — which at gossip
/// neighborhood sizes (k+1 updates) is trivially cheap, making `krum`
/// a natural per-neighborhood rule for the gossip engine.
pub struct KrumAggregator {
    buf: UpdateBuffer,
    /// Assumed Byzantine fraction, in [0, 0.5) (`ctx.trim_frac`).
    trim_frac: f64,
}

impl KrumAggregator {
    /// Build from a construction context; `ctx.trim_frac` is the
    /// assumed Byzantine fraction, validated like `trimmed_mean`'s.
    pub fn from_ctx(ctx: &AggContext) -> Result<KrumAggregator> {
        if !(0.0..0.5).contains(&ctx.trim_frac) {
            return Err(Error::Config(format!(
                "krum: trim_frac must be in [0, 0.5), got {}",
                ctx.trim_frac
            )));
        }
        Ok(KrumAggregator {
            buf: UpdateBuffer::from_ctx(ctx),
            trim_frac: ctx.trim_frac,
        })
    }
}

impl Aggregator for KrumAggregator {
    fn name(&self) -> &'static str {
        "krum"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        self.buf.add(update, weight)
    }

    fn count(&self) -> usize {
        self.buf.rows.len()
    }

    fn total_weight(&self) -> f64 {
        self.buf.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        self.buf.check_finish()?;
        let rows = &self.buf.rows;
        let n = rows.len();
        let f = ((self.trim_frac * n as f64).floor() as usize)
            .min(n.saturating_sub(3));
        // Score over the n−f−2 nearest peers; degenerate cohorts (n ≤ 3)
        // still score their single nearest neighbor.
        let closest = (n - f).saturating_sub(2).max(1);
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        let mut dists = Vec::with_capacity(n.saturating_sub(1));
        for i in 0..n {
            dists.clear();
            for j in 0..n {
                if j == i {
                    continue;
                }
                let d2: f64 = rows[i]
                    .0
                    .iter()
                    .zip(rows[j].0.iter())
                    .map(|(a, b)| {
                        let d = (*a - *b) as f64;
                        d * d
                    })
                    .sum();
                dists.push(d2);
            }
            dists.sort_by(|a, b| a.total_cmp(b));
            let score: f64 = dists[..closest.min(dists.len())].iter().sum();
            // Strict `<` keeps the lowest index on ties — deterministic.
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        let out = ParamVec(rows[best].0.clone());
        self.buf.reset();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(global: Vec<f32>) -> AggContext {
        AggContext::new(Arc::new(ParamVec(global)))
    }

    fn dense(v: Vec<f32>) -> Update {
        Update::Dense(ParamVec(v))
    }

    #[test]
    fn trimmed_mean_drops_outliers_per_coordinate() {
        let mut c = ctx(vec![0.0; 2]);
        c.trim_frac = 0.25; // n = 5 ⇒ trim 1 from each end
        let mut agg = TrimmedMeanAggregator::from_ctx(&c).unwrap();
        for v in [
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![1e9, -1e9], // hostile
            vec![-1e9, 1e9], // hostile
        ] {
            agg.add(&dense(v), 1.0).unwrap();
        }
        let out = agg.finish().unwrap();
        assert!((out[0] - 2.0).abs() < 1e-6, "{}", out[0]);
        assert!((out[1] - 20.0).abs() < 1e-5, "{}", out[1]);
    }

    #[test]
    fn trimmed_mean_zero_trim_is_the_weighted_mean() {
        let c = ctx(vec![0.0; 2]);
        let mut agg = TrimmedMeanAggregator::from_ctx(&c).unwrap();
        agg.add(&dense(vec![1.0, 2.0]), 1.0).unwrap();
        agg.add(&dense(vec![3.0, 6.0]), 3.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 2.5).abs() < 1e-12);
        assert!((out[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_rejects_bad_fractions() {
        for f in [0.5, 0.9, -0.1, f64::NAN] {
            let mut c = ctx(vec![0.0; 2]);
            c.trim_frac = f;
            assert!(TrimmedMeanAggregator::from_ctx(&c).is_err(), "{f}");
        }
    }

    #[test]
    fn median_is_the_middle_value_and_resets() {
        let c = ctx(vec![0.0; 2]);
        let mut agg = CoordinateMedianAggregator::from_ctx(&c);
        agg.add(&dense(vec![1.0, -5.0]), 1.0).unwrap();
        agg.add(&dense(vec![100.0, 0.0]), 1.0).unwrap();
        agg.add(&dense(vec![2.0, 5.0]), 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert_eq!(out.0, vec![2.0, 0.0]);
        assert_eq!(agg.count(), 0);
        // Weighted: a heavy client pulls the crossing point.
        agg.add(&dense(vec![1.0, 1.0]), 3.0).unwrap();
        agg.add(&dense(vec![9.0, 9.0]), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![1.0, 1.0]);
    }

    #[test]
    fn median_decodes_sparse_against_the_global() {
        let c = ctx(vec![1.0; 3]);
        let mut agg = CoordinateMedianAggregator::from_ctx(&c);
        let sparse = Update::SparseTernary {
            len: 3,
            indices: vec![0],
            signs: vec![true],
            magnitude: 0.5,
        };
        agg.add(&sparse, 1.0).unwrap();
        agg.add(&dense(vec![2.0, 2.0, 2.0]), 1.0).unwrap();
        agg.add(&dense(vec![0.0, 0.0, 0.0]), 1.0).unwrap();
        // Columns: [1.5, 2, 0] → 1.5; [1, 2, 0] → 1; [1, 2, 0] → 1.
        let out = agg.finish().unwrap();
        assert_eq!(out.0, vec![1.5, 1.0, 1.0]);
    }

    #[test]
    fn rank_aggregators_reject_malformed_updates() {
        let c = ctx(vec![0.0; 4]);
        let mut agg = TrimmedMeanAggregator::from_ctx(&c).unwrap();
        assert!(agg.add(&dense(vec![0.0; 3]), 1.0).is_err());
        assert!(agg.add(&dense(vec![0.0; 4]), -1.0).is_err());
        let masked = Update::Masked {
            xor_key: 7,
            inner: Box::new(dense(vec![0.0; 4])),
        };
        let err = agg.add(&masked, 1.0).unwrap_err().to_string();
        assert!(err.contains("decryption"), "{err}");
        let oob = Update::SparseTernary {
            len: 4,
            indices: vec![9],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(agg.add(&oob, 1.0).is_err());
        assert!(agg.finish().is_err(), "only failed adds ⇒ empty cohort");
    }

    #[test]
    fn norm_clip_passes_small_updates_and_caps_large_ones() {
        let mut c = ctx(vec![0.0; 4]);
        c.clip_norm = 2.0;
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        // ‖[1,0,0,0]‖ = 1 ≤ 2: identity.
        agg.add(&dense(vec![1.0, 0.0, 0.0, 0.0]), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![1.0, 0.0, 0.0, 0.0]);
        // ‖[8,6,0,0]‖ = 10 > 2: rescaled to norm 2.
        agg.add(&dense(vec![8.0, 6.0, 0.0, 0.0]), 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 1.6).abs() < 1e-6);
        assert!((out[1] - 1.2).abs() < 1e-6);
    }

    #[test]
    fn norm_clip_scales_sparse_magnitude() {
        let mut c = ctx(vec![0.0; 4]);
        c.clip_norm = 1.0;
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        // Delta norm = 3·√4 = 6 > 1 ⇒ magnitude shrinks to 3/6 = 0.5.
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![0, 1, 2, 3],
            signs: vec![true, true, false, false],
            magnitude: 3.0,
        };
        agg.add(&u, 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 0.5).abs() < 1e-6);
        assert!((out[3] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn norm_clip_screens_encoded_updates_by_sparse_norm() {
        let mut c = ctx(vec![0.0; 4]);
        c.clip_norm = 2.0;
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        let codec = crate::codec::parse("top_k(1.0)").unwrap();
        // ‖[1,0,0,0]‖ = 1 ≤ 2: forwarded verbatim (streams index-wise).
        let small = codec
            .encode(ParamVec(vec![1.0, 0.0, 0.0, 0.0]), &c.global)
            .unwrap();
        agg.add(&small, 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 1.0).abs() < 1e-6);
        // ‖[8,6,0,0]‖ = 10 > 2: decoded and rescaled to norm 2.
        let big = codec
            .encode(ParamVec(vec![8.0, 6.0, 0.0, 0.0]), &c.global)
            .unwrap();
        agg.add(&big, 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 1.6).abs() < 1e-6);
        assert!((out[1] - 1.2).abs() < 1e-6);
    }

    #[test]
    fn rank_aggregators_decode_encoded_updates() {
        let c = ctx(vec![1.0; 3]);
        let mut agg = CoordinateMedianAggregator::from_ctx(&c);
        let codec = crate::codec::parse("top_k(1.0)").unwrap();
        let enc = codec
            .encode(ParamVec(vec![1.5, 1.0, 1.0]), &c.global)
            .unwrap();
        agg.add(&enc, 1.0).unwrap();
        agg.add(&dense(vec![2.0, 2.0, 2.0]), 1.0).unwrap();
        agg.add(&dense(vec![0.0, 0.0, 0.0]), 1.0).unwrap();
        // Columns: [1.5, 2, 0] → 1.5; [1, 2, 0] → 1; [1, 2, 0] → 1.
        let out = agg.finish().unwrap();
        assert_eq!(out.0, vec![1.5, 1.0, 1.0]);
        // A tampered payload is a typed integrity error, not a panic.
        let mut bad = match codec
            .encode(ParamVec(vec![1.5, 1.0, 1.0]), &c.global)
            .unwrap()
        {
            Update::Encoded(e) => e,
            other => panic!("expected encoded update, got {other:?}"),
        };
        bad.content_hash ^= 1;
        let err = agg.add(&Update::Encoded(bad), 1.0).unwrap_err();
        assert!(matches!(err, Error::Integrity(_)), "{err}");
    }

    #[test]
    fn norm_clip_rejects_nan_poisoning() {
        let c = ctx(vec![0.0; 2]);
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        let err = agg
            .add(&dense(vec![f32::NAN, 1.0]), 1.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite"), "{err}");
        let sparse = Update::SparseTernary {
            len: 2,
            indices: vec![0],
            signs: vec![true],
            magnitude: f32::INFINITY,
        };
        assert!(agg.add(&sparse, 1.0).is_err());
        // Bad thresholds are rejected at construction (0 is the
        // adaptive sentinel, so only negatives and non-finites fail).
        for clip in [-1.0, f64::INFINITY, f64::NAN] {
            let mut c = ctx(vec![0.0; 2]);
            c.clip_norm = clip;
            assert!(NormClipAggregator::from_ctx(&c).is_err(), "{clip}");
        }
    }

    #[test]
    fn adaptive_threshold_converges_onto_a_stationary_quantile() {
        use crate::util::rng::Rng;
        let mut c = ctx(vec![0.0; 8]);
        c.clip_norm = 0.0; // adaptive
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        assert_eq!(agg.clip_threshold(), ADAPTIVE_CLIP_INIT);
        let mut rng = Rng::new(9);
        // Stationary honest-norm distribution: uniform in [1, 3], whose
        // 0.95-quantile is 2.9. The threshold survives `finish`, so the
        // estimate keeps refining across simulated rounds.
        for _round in 0..200 {
            for _ in 0..10 {
                let norm = 1.0 + 2.0 * rng.uniform();
                let mut v = vec![0.0f32; 8];
                v[0] = norm as f32;
                agg.add(&dense(v), 1.0).unwrap();
            }
            agg.finish().unwrap();
        }
        let t = agg.clip_threshold();
        assert!(
            (2.4..=3.4).contains(&t),
            "threshold {t} should converge near the 0.95-quantile 2.9"
        );
    }

    #[test]
    fn adaptive_clipping_caps_outliers_after_warmup() {
        let mut c = ctx(vec![0.0; 2]);
        c.clip_norm = 0.0;
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        // Warm the tracker on unit-norm honest updates.
        for _ in 0..50 {
            agg.add(&dense(vec![1.0, 0.0]), 1.0).unwrap();
        }
        agg.finish().unwrap();
        let t = agg.clip_threshold();
        assert!(t > 0.5 && t < 2.0, "warmed threshold {t} tracks norm 1");
        // A 1e6-norm poisoning attempt is rescaled onto ~the threshold.
        agg.add(&dense(vec![1e6, 0.0]), 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!(
            (out[0] as f64) < 3.0,
            "outlier must be clipped to the learned threshold, got {}",
            out[0]
        );
    }

    #[test]
    fn adaptive_clipping_caps_a_byzantine_first_reporter() {
        // The first update of a window must NOT get to choose the
        // threshold it is clipped against: a 1e9-norm opener is capped
        // at the conservative init, not waved through.
        let mut c = ctx(vec![0.0; 2]);
        c.clip_norm = 0.0;
        let mut agg = NormClipAggregator::from_ctx(&c).unwrap();
        agg.add(&dense(vec![1e9, 0.0]), 1.0).unwrap();
        for _ in 0..9 {
            agg.add(&dense(vec![1.0, 0.0]), 1.0).unwrap();
        }
        let out = agg.finish().unwrap();
        // (1·ADAPTIVE_CLIP_INIT + 9·1) / 10 ≈ 1, nowhere near 1e8.
        assert!(
            (out[0] as f64) < 2.0,
            "first-reporter attack must be capped, got {}",
            out[0]
        );
    }

    #[test]
    fn buffered_aggregators_reset_between_rounds() {
        let mut c = ctx(vec![0.0; 2]);
        c.trim_frac = 0.0;
        let mut agg = TrimmedMeanAggregator::from_ctx(&c).unwrap();
        agg.add(&dense(vec![4.0, 4.0]), 2.0).unwrap();
        assert_eq!(agg.count(), 1);
        assert_eq!(agg.finish().unwrap().0, vec![4.0, 4.0]);
        assert_eq!(agg.count(), 0);
        assert_eq!(agg.total_weight(), 0.0);
        agg.add(&dense(vec![2.0, 2.0]), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![2.0, 2.0]);
    }

    #[test]
    fn krum_picks_an_honest_update_under_sign_flip_corruption() {
        // Property: over many seeded cohorts with f < n/2 − 1 sign-flip
        // corruptions, the Krum winner is always one of the honest rows.
        let p = 8;
        let n = 10;
        let mut rng = crate::util::rng::Rng::new(0x4B52_554D);
        for trial in 0..50 {
            // f ∈ {1, 2, 3} satisfies f < n/2 − 1 = 4.
            let f = 1 + (trial % 3);
            let mut c = ctx(vec![0.0; p]);
            c.trim_frac = f as f64 / n as f64 + 1e-9;
            let mut agg = KrumAggregator::from_ctx(&c).unwrap();
            // Honest updates cluster around a common direction.
            let center: Vec<f32> =
                (0..p).map(|_| rng.normal() as f32).collect();
            let mut honest: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n - f {
                let row: Vec<f32> = center
                    .iter()
                    .map(|v| v + (rng.normal() * 0.05) as f32)
                    .collect();
                honest.push(row);
            }
            // Corrupted rows are honest-shaped but sign-flipped (and
            // scaled, the classic model-poisoning shape).
            let mut rows: Vec<Vec<f32>> = honest.clone();
            for _ in 0..f {
                rows.push(center.iter().map(|v| v * -5.0).collect());
            }
            // Interleave: corrupt rows first, so index order can't help.
            rows.rotate_right(f);
            for row in &rows {
                agg.add(&dense(row.clone()), 1.0).unwrap();
            }
            let out = agg.finish().unwrap();
            assert!(
                honest.iter().any(|h| h[..] == out.0[..]),
                "trial {trial}: krum returned a corrupted row: {:?}",
                out.0
            );
        }
    }

    #[test]
    fn krum_degenerates_gracefully_on_tiny_cohorts() {
        let mut c = ctx(vec![0.0; 2]);
        c.trim_frac = 0.2;
        let mut agg = KrumAggregator::from_ctx(&c).unwrap();
        // Singleton cohort: the only row wins.
        agg.add(&dense(vec![3.0, 4.0]), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![3.0, 4.0]);
        // Pair: symmetric scores, lowest index wins deterministically.
        agg.add(&dense(vec![1.0, 1.0]), 1.0).unwrap();
        agg.add(&dense(vec![2.0, 2.0]), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![1.0, 1.0]);
        // Empty cohort errors like every other aggregator.
        assert!(agg.finish().is_err());
        // Hostile fraction rejected at construction.
        let mut bad = ctx(vec![0.0; 2]);
        bad.trim_frac = 0.5;
        assert!(KrumAggregator::from_ctx(&bad).is_err());
    }

    #[test]
    fn krum_returns_a_buffered_row_verbatim_and_resets() {
        let mut c = ctx(vec![0.0; 3]);
        c.trim_frac = 0.0;
        let mut agg = KrumAggregator::from_ctx(&c).unwrap();
        let rows =
            [vec![1.0, 0.0, 0.0], vec![1.1, 0.0, 0.0], vec![9.0, 9.0, 9.0]];
        for r in &rows {
            agg.add(&dense(r.clone()), 1.0).unwrap();
        }
        assert_eq!(agg.count(), 3);
        let out = agg.finish().unwrap();
        assert!(
            rows.iter().any(|r| r[..] == out.0[..]),
            "krum must return one of its inputs verbatim"
        );
        assert!(out.0[0] < 2.0, "the outlier row must not win");
        assert_eq!(agg.count(), 0, "finish resets for the next round");
    }
}
