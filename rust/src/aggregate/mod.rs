//! The streaming aggregation plane.
//!
//! The original design materialized a dense `ParamVec` per client before
//! reducing — O(cohort × P) memory and three duplicated copies of the
//! decompress→aggregate loop (server round, remote ingest, SimNet). This
//! module replaces the batch path with one incremental [`Aggregator`]
//! shared by every consumer:
//!
//! * [`MeanAggregator`] — weighted mean over a stream of updates. Dense
//!   updates fold in via a fused axpy, sparse ternary updates index-wise
//!   in place; no per-client dense materialization, no clone of the
//!   global. Cohorts at/above a configurable threshold reduce
//!   chunk-parallel (`std::thread` over P-ranges).
//! * [`SliceMaskedAggregator`] — FedReID-style backbone merge: only the
//!   leading `P − protected_tail` coordinates are averaged; the trailing
//!   personal-head slice is carried over from the global model.
//! * [`FedBuffBuffer`] — FedBuff's staleness discount expressed as
//!   aggregator weights, shared by SimNet's async engine and any
//!   buffered-asynchronous server flow.
//! * [`robust`] — Byzantine-robust reductions (`"trimmed_mean"`,
//!   `"median"`, `"norm_clip"`), selectable per config via `Config.agg`
//!   so any algorithm hardens against hostile uploads without a new flow.
//!
//! Aggregators are registry-backed: algorithms pick theirs by name
//! (`"mean"`, `"backbone"`, or any custom registration) through
//! [`crate::flow::ServerFlow::make_aggregator`]. Peak memory is
//! O(threads · P) instead of O(cohort · P) — except the rank-based
//! robust reductions, which intrinsically buffer the cohort. For
//! cohorts where even that buffer is too large, `Config.agg_sketch`
//! swaps the `"trimmed_mean"` / `"median"` registrations for the
//! [`sketch`] variants: mergeable per-coordinate quantile sketches
//! with O(P · cap) memory, bit-identical to the exact path for small
//! cohorts and within a bounded quantile error above the cap.

pub mod masked;
pub mod mean;
pub mod robust;
pub mod sketch;

pub use masked::SliceMaskedAggregator;
pub use mean::MeanAggregator;
pub use robust::{
    CoordinateMedianAggregator, KrumAggregator, NormClipAggregator,
    TrimmedMeanAggregator,
};
pub use sketch::{SketchMedian, SketchTrimmedMean};

use std::sync::Arc;

use crate::config::Config;
use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;
use crate::obs::Telemetry;

/// Streaming reduction over client updates: `add` folds one update in,
/// `finish` yields the reduced model and resets the accumulator so the
/// instance can serve the next round.
pub trait Aggregator: Send {
    fn name(&self) -> &'static str;

    /// Fold one update in with its raw (unnormalized) weight — typically
    /// the client's sample count, or a staleness-discounted weight.
    fn add(&mut self, update: &Update, weight: f64) -> Result<()>;

    /// Updates folded in since construction / the last `finish`.
    fn count(&self) -> usize;

    /// Sum of raw weights folded in so far (normalization denominator).
    fn total_weight(&self) -> f64;

    /// Complete the reduction: the weighted mean of everything added.
    /// Resets the accumulator for reuse. Errors on an empty cohort or a
    /// non-positive total weight.
    fn finish(&mut self) -> Result<ParamVec>;
}

/// Construction context handed to registered aggregator builders.
#[derive(Clone)]
pub struct AggContext {
    /// The distributed global model this round's updates are relative to
    /// (sparse deltas decode against it; slice-masked tails copy from it).
    pub global: Arc<ParamVec>,
    /// How many updates are expected to stream in (chunk-parallel gate;
    /// 0 = unknown).
    pub expect_updates: usize,
    /// Cohort size at/above which dense adds reduce chunk-parallel
    /// (0 = always parallel when the vector is large enough).
    pub parallel_threshold: usize,
    /// Worker threads for the chunk-parallel reduce (0 = all cores,
    /// capped at 8).
    pub threads: usize,
    /// Trailing coordinates excluded from aggregation (FedReID's
    /// personal head). 0 for full-vector aggregators.
    pub protected_tail: usize,
    /// Registered-aggregator name override (`Config.agg`): when set, the
    /// default [`crate::flow::ServerFlow::make_aggregator`] resolves this
    /// name instead of the flow's own `aggregator_name` — the pure-config
    /// path to Byzantine-robust reductions.
    pub agg_override: Option<String>,
    /// Registered-aggregator name for the *edge* tier of a hierarchical
    /// topology (`Config.edge_agg`); [`crate::hierarchy::HierPlane`]
    /// resolves it per edge, falling back to `agg_override` then the
    /// flow default. Flat reductions ignore it.
    pub edge_agg: Option<String>,
    /// Use the streaming quantile-sketch variants of the rank-based
    /// robust aggregators (`Config.agg_sketch`): same registry names,
    /// O(P · cap) memory instead of O(cohort · P).
    pub agg_sketch: bool,
    /// Per-end trim fraction for `"trimmed_mean"`, in [0, 0.5).
    pub trim_frac: f64,
    /// L2 delta-norm threshold for `"norm_clip"` (> 0 and finite, or 0
    /// for the adaptive running-quantile threshold).
    pub clip_norm: f64,
    /// Telemetry probe handle: chunk-parallel reduces emit per-worker
    /// spans through it, hierarchical planes time per-edge folds. Off by
    /// default (one branch per probe); owners that hold a live handle
    /// pass it down via [`AggContext::telemetry`].
    pub tel: Telemetry,
}

impl AggContext {
    pub fn new(global: Arc<ParamVec>) -> AggContext {
        AggContext {
            global,
            expect_updates: 0,
            parallel_threshold: 64,
            threads: 0,
            protected_tail: 0,
            agg_override: None,
            edge_agg: None,
            agg_sketch: false,
            trim_frac: 0.1,
            clip_norm: 10.0,
            tel: Telemetry::off(),
        }
    }

    /// Context tuned from a [`Config`]'s aggregation knobs.
    pub fn from_config(global: Arc<ParamVec>, cfg: &Config) -> AggContext {
        let mut ctx = AggContext::new(global);
        ctx.parallel_threshold = cfg.agg_parallel_threshold;
        ctx.threads = cfg.agg_threads;
        ctx.agg_override = cfg.agg.clone();
        ctx.edge_agg = cfg.edge_agg.clone();
        ctx.agg_sketch = cfg.agg_sketch;
        ctx.trim_frac = cfg.agg_trim_frac;
        ctx.clip_norm = cfg.agg_clip_norm;
        ctx
    }

    pub fn expect_updates(mut self, n: usize) -> AggContext {
        self.expect_updates = n;
        self
    }

    pub fn protected_tail(mut self, n: usize) -> AggContext {
        self.protected_tail = n;
        self
    }

    /// Attach a live telemetry handle (builders clone it into their
    /// aggregators).
    pub fn telemetry(mut self, tel: Telemetry) -> AggContext {
        self.tel = tel;
        self
    }

    /// Effective worker-thread count for the chunk-parallel reduce.
    pub(crate) fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        }
    }

    /// Whether the chunk-parallel path should engage for a vector of
    /// `len` coordinates. Each dense `add` spawns scoped threads, so the
    /// per-add work must amortize the spawn cost: with auto threading
    /// (`threads == 0`) that only holds for large vectors
    /// ([`mean::AUTO_PARALLEL_LEN`]); an explicit `threads` setting opts
    /// in down to [`mean::MIN_PARALLEL_LEN`].
    pub(crate) fn use_parallel(&self, len: usize) -> bool {
        let floor = if self.threads > 0 {
            mean::MIN_PARALLEL_LEN
        } else {
            mean::AUTO_PARALLEL_LEN
        };
        self.effective_threads() > 1
            && self.expect_updates >= self.parallel_threshold
            && len >= floor
    }
}

/// Fold one *delta-encoded* update into an f64 accumulator, index-wise.
///
/// This is the single shared fold for every streaming consumer — the
/// weighted mean, the slice-masked backbone merge, and the hierarchy's
/// edge partials all route their non-dense arms here, so a new wire
/// format (like [`crate::codec`]'s [`Update::Encoded`]) folds in exactly
/// one place:
///
/// * `SparseTernary` — `acc[idx] += weight · ±magnitude` below
///   `active_limit`.
/// * `Encoded` — integrity-verified, then `acc[idx] += weight · value`
///   below `active_limit` (values dequantized on the fly).
/// * `Masked` — the canonical "needs a decryption stage" error.
/// * `Dense` — **not** folded: returns `Ok(false)` so the caller runs
///   its own (possibly chunk-parallel) axpy path.
///
/// Returns `Ok(true)` when the update was a delta against the global
/// model — the caller must then count its weight toward the base-model
/// fold at `finish` (the `sparse_weight` ledger).
pub(crate) fn fold_delta_update(
    acc: &mut [f64],
    p: usize,
    update: &Update,
    weight: f64,
    active_limit: usize,
) -> Result<bool> {
    match update {
        Update::Dense(_) => Ok(false),
        Update::SparseTernary { len, indices, signs, magnitude } => {
            mean::fold_ternary(
                acc, p, *len, indices, signs, *magnitude, weight, active_limit,
            )?;
            Ok(true)
        }
        Update::Encoded(e) => {
            e.fold_into(acc, p, weight, active_limit)?;
            Ok(true)
        }
        Update::Masked { .. } => Err(Error::Runtime(
            "aggregate: masked update reached the aggregator; a server \
             plugin with a decryption stage must unmask uploads first"
                .into(),
        )),
    }
}

/// Constructor closure for a registered aggregator.
pub type AggregatorBuilder =
    Arc<dyn Fn(&AggContext) -> Result<Box<dyn Aggregator>> + Send + Sync>;

/// Install the built-in aggregators (called by
/// [`crate::registry::ComponentRegistry::with_builtins`]).
pub(crate) fn register_builtins(reg: &mut crate::registry::ComponentRegistry) {
    reg.register_aggregator(
        "mean",
        Arc::new(|ctx| {
            Ok(Box::new(MeanAggregator::from_ctx(ctx)) as Box<dyn Aggregator>)
        }),
    );
    reg.register_aggregator(
        "backbone",
        Arc::new(|ctx| {
            Ok(Box::new(SliceMaskedAggregator::from_ctx(ctx))
                as Box<dyn Aggregator>)
        }),
    );
    reg.register_aggregator(
        "trimmed_mean",
        Arc::new(|ctx| {
            // `agg_sketch` swaps in the streaming quantile-sketch
            // variant under the same name, so every consumer (server
            // flow, remote ingest, hierarchy tiers, SimNet) switches
            // purely from config.
            if ctx.agg_sketch {
                Ok(Box::new(SketchTrimmedMean::from_ctx(ctx)?)
                    as Box<dyn Aggregator>)
            } else {
                Ok(Box::new(TrimmedMeanAggregator::from_ctx(ctx)?)
                    as Box<dyn Aggregator>)
            }
        }),
    );
    reg.register_aggregator(
        "median",
        Arc::new(|ctx| {
            if ctx.agg_sketch {
                Ok(Box::new(SketchMedian::from_ctx(ctx))
                    as Box<dyn Aggregator>)
            } else {
                Ok(Box::new(CoordinateMedianAggregator::from_ctx(ctx))
                    as Box<dyn Aggregator>)
            }
        }),
    );
    reg.register_aggregator(
        "norm_clip",
        Arc::new(|ctx| {
            Ok(Box::new(NormClipAggregator::from_ctx(ctx)?)
                as Box<dyn Aggregator>)
        }),
    );
    reg.register_aggregator(
        "krum",
        Arc::new(|ctx| {
            Ok(Box::new(KrumAggregator::from_ctx(ctx)?)
                as Box<dyn Aggregator>)
        }),
    );
}

// ------------------------------------------------------- legacy oracle

/// The legacy batch reduction: normalize weights, then one weighted sum
/// over fully materialized dense vectors — exactly what the deprecated
/// `ServerFlow::aggregate` computed through the L1 Pallas kernel. Kept
/// as the equivalence oracle for the property tests and `agg_bench`;
/// new code should stream through an [`Aggregator`] instead.
pub fn batch_weighted_mean(contributions: &[(&[f32], f64)]) -> Result<ParamVec> {
    let Some(((first, _), rest)) = contributions.split_first() else {
        return Err(Error::Runtime("aggregate: empty cohort".into()));
    };
    let total: f64 = contributions.iter().map(|(_, w)| w).sum();
    if total <= 0.0 {
        return Err(Error::Runtime("aggregate: zero total weight".into()));
    }
    for (v, _) in rest {
        if v.len() != first.len() {
            return Err(Error::Runtime(format!(
                "aggregate: vector of len {} != P {}",
                v.len(),
                first.len()
            )));
        }
    }
    let mut acc = vec![0.0f64; first.len()];
    for (v, w) in contributions {
        let nw = w / total;
        for (a, x) in acc.iter_mut().zip(v.iter()) {
            *a += nw * (*x as f64);
        }
    }
    Ok(ParamVec(acc.into_iter().map(|v| v as f32).collect()))
}

// ------------------------------------------------------------- fedbuff

/// FedBuff's staleness discount: an update aggregated `s` versions after
/// the model it trained against weighs `(1 + s)^-α`.
#[derive(Debug, Clone, Copy)]
pub struct StalenessDiscount {
    pub alpha: f64,
}

impl StalenessDiscount {
    pub fn new(alpha: f64) -> StalenessDiscount {
        StalenessDiscount { alpha }
    }

    /// The aggregator weight a report of this staleness carries.
    pub fn weight(&self, staleness: f64) -> f64 {
        (1.0 + staleness).powf(-self.alpha)
    }
}

/// One flushed FedBuff window.
pub struct FedBuffWindow {
    /// Reports aggregated in the window.
    pub arrivals: usize,
    /// Sum of staleness-discounted weights.
    pub total_weight: f64,
    /// Mean staleness over the window's reports.
    pub avg_staleness: f64,
    /// The reduced model when an [`Aggregator`] is attached; `None` in
    /// surrogate simulations that track weights only.
    pub params: Option<ParamVec>,
}

/// Buffered-asynchronous (FedBuff) aggregation: each arriving report is
/// pushed with its staleness, which the buffer converts into an
/// aggregator weight. With an attached [`Aggregator`] the updates stream
/// straight in; without one (SimNet's surrogate mode) only the weight
/// ledger is kept, so the same bookkeeping drives both real and
/// simulated federations.
pub struct FedBuffBuffer {
    discount: StalenessDiscount,
    agg: Option<Box<dyn Aggregator>>,
    arrivals: usize,
    sum_weight: f64,
    sum_staleness: f64,
}

impl FedBuffBuffer {
    /// Weight ledger only — no parameter reduction (surrogate SimNet).
    pub fn surrogate(alpha: f64) -> FedBuffBuffer {
        FedBuffBuffer {
            discount: StalenessDiscount::new(alpha),
            agg: None,
            arrivals: 0,
            sum_weight: 0.0,
            sum_staleness: 0.0,
        }
    }

    /// Stream updates into `agg` with staleness-discounted weights.
    pub fn with_aggregator(alpha: f64, agg: Box<dyn Aggregator>) -> FedBuffBuffer {
        FedBuffBuffer { agg: Some(agg), ..FedBuffBuffer::surrogate(alpha) }
    }

    /// Record one report. Returns the discounted weight it carried.
    /// `update` must be `Some` when an aggregator is attached.
    pub fn push(&mut self, staleness: f64, update: Option<&Update>) -> Result<f64> {
        let weight = self.discount.weight(staleness);
        if let Some(agg) = self.agg.as_mut() {
            let update = update.ok_or_else(|| {
                Error::Runtime(
                    "fedbuff: aggregator attached but no update supplied".into(),
                )
            })?;
            agg.add(update, weight)?;
        }
        self.arrivals += 1;
        self.sum_weight += weight;
        self.sum_staleness += staleness;
        Ok(weight)
    }

    /// Reports buffered since the last flush.
    pub fn len(&self) -> usize {
        self.arrivals
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals == 0
    }

    /// Sum of discounted weights in the current window.
    pub fn total_weight(&self) -> f64 {
        self.sum_weight
    }

    /// Mean staleness of the current window (0 when empty).
    pub fn avg_staleness(&self) -> f64 {
        if self.arrivals == 0 {
            0.0
        } else {
            self.sum_staleness / self.arrivals as f64
        }
    }

    /// Close the window: report its stats (and reduced model, when an
    /// aggregator is attached) and reset for the next one.
    pub fn flush(&mut self) -> Result<FedBuffWindow> {
        let window = FedBuffWindow {
            arrivals: self.arrivals,
            total_weight: self.sum_weight,
            avg_staleness: self.avg_staleness(),
            params: match self.agg.as_mut() {
                Some(agg) => Some(agg.finish()?),
                None => None,
            },
        };
        self.arrivals = 0;
        self.sum_weight = 0.0;
        self.sum_staleness = 0.0;
        Ok(window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_oracle_is_the_normalized_weighted_mean() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let out = batch_weighted_mean(&[(&a, 1.0), (&b, 3.0)]).unwrap();
        assert!((out[0] - 2.5).abs() < 1e-7);
        assert!((out[1] - 5.0).abs() < 1e-7);
        assert!(batch_weighted_mean(&[]).is_err());
        assert!(batch_weighted_mean(&[(&a[..], 0.0)]).is_err());
        assert!(batch_weighted_mean(&[(&a[..], 1.0), (&b[..1], 1.0)]).is_err());
    }

    #[test]
    fn staleness_discount_matches_fedbuff() {
        let d = StalenessDiscount::new(0.5);
        assert!((d.weight(0.0) - 1.0).abs() < 1e-12);
        assert!((d.weight(3.0) - 0.5).abs() < 1e-12);
        // α = 0 disables the discount entirely.
        assert_eq!(StalenessDiscount::new(0.0).weight(7.0), 1.0);
    }

    #[test]
    fn fedbuff_surrogate_ledger_tracks_weights_and_staleness() {
        let mut buf = FedBuffBuffer::surrogate(0.5);
        assert!(buf.is_empty());
        let w0 = buf.push(0.0, None).unwrap();
        let w3 = buf.push(3.0, None).unwrap();
        assert!((w0 - 1.0).abs() < 1e-12);
        assert!((w3 - 0.5).abs() < 1e-12);
        assert_eq!(buf.len(), 2);
        assert!((buf.total_weight() - 1.5).abs() < 1e-12);
        assert!((buf.avg_staleness() - 1.5).abs() < 1e-12);
        let window = buf.flush().unwrap();
        assert_eq!(window.arrivals, 2);
        assert!(window.params.is_none());
        // Flush resets the window.
        assert!(buf.is_empty());
        assert_eq!(buf.avg_staleness(), 0.0);
    }

    #[test]
    fn fedbuff_with_aggregator_streams_discounted_updates() {
        let global = Arc::new(ParamVec::zeros(4));
        let agg = Box::new(MeanAggregator::from_ctx(&AggContext::new(global)));
        let mut buf = FedBuffBuffer::with_aggregator(0.5, agg);
        // Missing update with an attached aggregator is an error.
        assert!(buf.push(0.0, None).is_err());
        let fresh = Update::Dense(ParamVec(vec![2.0; 4]));
        let stale = Update::Dense(ParamVec(vec![4.0; 4]));
        buf.push(0.0, Some(&fresh)).unwrap(); // weight 1
        buf.push(3.0, Some(&stale)).unwrap(); // weight 0.5
        let window = buf.flush().unwrap();
        let params = window.params.unwrap();
        // (1·2 + 0.5·4) / 1.5 = 8/3
        for v in params.iter() {
            assert!((v - 8.0 / 3.0).abs() < 1e-6);
        }
    }
}
