//! Streaming weighted-mean aggregation (the `"mean"` registry entry).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::flow::Update;
use crate::model::ParamVec;
use crate::obs::Telemetry;

use super::{AggContext, Aggregator};

/// Vectors shorter than this never engage the chunk-parallel path, even
/// with an explicit thread count: the thread-spawn cost dwarfs the
/// reduce.
pub(crate) const MIN_PARALLEL_LEN: usize = 4096;

/// Floor for *auto* threading (`AggContext::threads == 0`): scoped
/// threads are spawned per dense add, so the axpy must be big enough to
/// amortize ~tens of µs of spawn/join per thread. Explicitly configured
/// `agg_threads` opts in down to [`MIN_PARALLEL_LEN`].
pub(crate) const AUTO_PARALLEL_LEN: usize = 1 << 18;

/// `acc[i] += w · x[i]`, split over `threads` disjoint P-ranges when
/// `threads > 1`. Element-wise, so the result is bit-identical to the
/// sequential reduce regardless of thread count.
pub(crate) fn axpy_into(acc: &mut [f64], x: &[f32], w: f64, threads: usize) {
    if threads <= 1 || acc.len() < MIN_PARALLEL_LEN {
        for (a, v) in acc.iter_mut().zip(x.iter()) {
            *a += w * (*v as f64);
        }
        return;
    }
    let chunk = acc.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (a_chunk, x_chunk) in acc.chunks_mut(chunk).zip(x.chunks(chunk)) {
            s.spawn(move || {
                for (a, v) in a_chunk.iter_mut().zip(x_chunk.iter()) {
                    *a += w * (*v as f64);
                }
            });
        }
    });
}

/// `out[i] = (acc[i] + base_w · g[i]) / total` as f32, chunk-parallel for
/// large vectors. `g` may be empty when `base_w == 0` (pure-dense round).
/// Each chunk-parallel worker runs under an `"agg.worker"` span (one per
/// round, not per add, so the probe never lands on the axpy hot path).
pub(crate) fn finish_into(
    acc: &[f64],
    g: &[f32],
    base_w: f64,
    total: f64,
    threads: usize,
    tel: &Telemetry,
) -> Vec<f32> {
    let mut out = vec![0.0f32; acc.len()];
    let body = |offset: usize, dst: &mut [f32]| {
        for (i, o) in dst.iter_mut().enumerate() {
            let base = if base_w != 0.0 { base_w * g[offset + i] as f64 } else { 0.0 };
            *o = ((acc[offset + i] + base) / total) as f32;
        }
    };
    if threads <= 1 || acc.len() < MIN_PARALLEL_LEN {
        body(0, &mut out);
        return out;
    }
    let chunk = acc.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, dst) in out.chunks_mut(chunk).enumerate() {
            let body = &body;
            s.spawn(move || {
                let _span = tel.span("agg.worker");
                body(ci * chunk, dst);
            });
        }
    });
    out
}

/// Incremental weighted mean over a stream of [`Update`]s.
///
/// Dense updates fold in via a fused axpy (`acc += w·x`); sparse ternary
/// updates touch only their indices (`acc[idx] += w·±μ`, with the dense
/// base `w·global` folded in once at `finish`). Accumulation is f64 for
/// stability, so thread count never changes the result. Memory is one
/// f64 accumulator — O(P), not O(cohort·P).
pub struct MeanAggregator {
    acc: Vec<f64>,
    /// Σw over sparse adds: their `global +` base, folded in at finish.
    sparse_weight: f64,
    total_weight: f64,
    count: usize,
    /// Required for sparse updates; `None` for the dense-only legacy shim.
    global: Option<Arc<ParamVec>>,
    threads: usize,
    tel: Telemetry,
}

impl MeanAggregator {
    /// Build from a construction context (the registry path).
    pub fn from_ctx(ctx: &AggContext) -> MeanAggregator {
        let len = ctx.global.len();
        let threads = if ctx.use_parallel(len) { ctx.effective_threads() } else { 1 };
        MeanAggregator {
            acc: vec![0.0; len],
            sparse_weight: 0.0,
            total_weight: 0.0,
            count: 0,
            global: Some(ctx.global.clone()),
            threads,
            tel: ctx.tel.clone(),
        }
    }

    /// Dense-only accumulator of a known length (no global model):
    /// sparse updates are rejected. Used by the deprecated batch shim.
    pub fn dense_only(len: usize) -> MeanAggregator {
        MeanAggregator {
            acc: vec![0.0; len],
            sparse_weight: 0.0,
            total_weight: 0.0,
            count: 0,
            global: None,
            threads: 1,
            tel: Telemetry::off(),
        }
    }

    /// Fold a dense vector in without wrapping it in an [`Update`].
    pub fn add_dense(&mut self, x: &[f32], weight: f64) -> Result<()> {
        check_weight(weight)?;
        if x.len() != self.acc.len() {
            return Err(Error::Runtime(format!(
                "aggregate: vector of len {} != P {}",
                x.len(),
                self.acc.len()
            )));
        }
        axpy_into(&mut self.acc, x, weight, self.threads);
        self.count += 1;
        self.total_weight += weight;
        Ok(())
    }

    /// Fold a delta-encoded update (sparse ternary or codec-encoded)
    /// through the shared [`super::fold_delta_update`] path.
    fn add_delta(&mut self, update: &Update, weight: f64) -> Result<()> {
        check_weight(weight)?;
        if self.global.is_none() {
            return Err(Error::Runtime(
                "aggregate: sparse update needs the global model \
                 (dense-only accumulator)"
                    .into(),
            ));
        }
        let p = self.acc.len();
        let folded = super::fold_delta_update(&mut self.acc, p, update, weight, p)?;
        debug_assert!(folded, "add_delta only sees delta-encoded variants");
        self.count += 1;
        self.total_weight += weight;
        self.sparse_weight += weight;
        Ok(())
    }
}

/// Weight sanity shared by every built-in aggregator.
pub(crate) fn check_weight(weight: f64) -> Result<()> {
    if !weight.is_finite() || weight < 0.0 {
        return Err(Error::Runtime(format!(
            "aggregate: bad update weight {weight}"
        )));
    }
    Ok(())
}

/// Validate one sparse ternary update against a P-length contract and
/// fold `weight · ±magnitude` into `acc` at indices below
/// `active_limit` (the full vector for the mean, the backbone split for
/// slice-masked aggregation — deltas at/above the limit are dropped).
#[allow(clippy::too_many_arguments)]
pub(crate) fn fold_ternary(
    acc: &mut [f64],
    p: usize,
    len: usize,
    indices: &[u32],
    signs: &[bool],
    magnitude: f32,
    weight: f64,
    active_limit: usize,
) -> Result<()> {
    if len != p {
        return Err(Error::Runtime(format!(
            "aggregate: sparse update of len {len} != P {p}"
        )));
    }
    if signs.len() != indices.len() {
        return Err(Error::Runtime(format!(
            "aggregate: {} signs for {} indices",
            signs.len(),
            indices.len()
        )));
    }
    let mag = magnitude as f64;
    for (i, &idx) in indices.iter().enumerate() {
        let idx = idx as usize;
        if idx >= p {
            return Err(Error::Runtime(format!(
                "aggregate: sparse index {idx} out of range (P = {p})"
            )));
        }
        if idx < active_limit {
            acc[idx] += weight * if signs[i] { mag } else { -mag };
        }
    }
    Ok(())
}

impl Aggregator for MeanAggregator {
    fn name(&self) -> &'static str {
        "mean"
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        match update {
            Update::Dense(p) => self.add_dense(p, weight),
            // SparseTernary / Encoded fold through the shared delta
            // path; Masked errors there with the canonical message.
            _ => self.add_delta(update, weight),
        }
    }

    fn count(&self) -> usize {
        self.count
    }

    fn total_weight(&self) -> f64 {
        self.total_weight
    }

    fn finish(&mut self) -> Result<ParamVec> {
        if self.count == 0 {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        }
        if self.total_weight <= 0.0 {
            return Err(Error::Runtime("aggregate: zero total weight".into()));
        }
        let g: &[f32] = match &self.global {
            Some(g) => &g.0,
            None => &[],
        };
        let out = finish_into(
            &self.acc,
            g,
            self.sparse_weight,
            self.total_weight,
            self.threads,
            &self.tel,
        );
        // Reset for the next round.
        self.acc.iter_mut().for_each(|v| *v = 0.0);
        self.sparse_weight = 0.0;
        self.total_weight = 0.0;
        self.count = 0;
        Ok(ParamVec(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(global: Vec<f32>) -> AggContext {
        AggContext::new(Arc::new(ParamVec(global)))
    }

    #[test]
    fn dense_weighted_mean_matches_hand_computation() {
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![0.0; 2]));
        agg.add(&Update::Dense(ParamVec(vec![1.0, 2.0])), 1.0).unwrap();
        agg.add(&Update::Dense(ParamVec(vec![3.0, 6.0])), 3.0).unwrap();
        assert_eq!(agg.count(), 2);
        assert!((agg.total_weight() - 4.0).abs() < 1e-12);
        let out = agg.finish().unwrap();
        assert!((out[0] - 2.5).abs() < 1e-7);
        assert!((out[1] - 5.0).abs() < 1e-7);
    }

    #[test]
    fn sparse_adds_fold_the_global_base_in_once() {
        // Two sparse updates over global [1, 1, 1]:
        //   u1 = global + 0.5 at idx 0   (weight 1)
        //   u2 = global − 0.5 at idx 2   (weight 1)
        // mean = global + [0.25, 0, −0.25]
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![1.0; 3]));
        let u1 = Update::SparseTernary {
            len: 3,
            indices: vec![0],
            signs: vec![true],
            magnitude: 0.5,
        };
        let u2 = Update::SparseTernary {
            len: 3,
            indices: vec![2],
            signs: vec![false],
            magnitude: 0.5,
        };
        agg.add(&u1, 1.0).unwrap();
        agg.add(&u2, 1.0).unwrap();
        let out = agg.finish().unwrap();
        assert!((out[0] - 1.25).abs() < 1e-7);
        assert!((out[1] - 1.0).abs() < 1e-7);
        assert!((out[2] - 0.75).abs() < 1e-7);
    }

    #[test]
    fn masked_updates_are_rejected() {
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![0.0; 2]));
        let u = Update::Masked {
            xor_key: 9,
            inner: Box::new(Update::Dense(ParamVec(vec![1.0, 1.0]))),
        };
        let err = agg.add(&u, 1.0).unwrap_err().to_string();
        assert!(err.contains("decryption"), "{err}");
    }

    #[test]
    fn bad_inputs_error_instead_of_panicking() {
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![0.0; 4]));
        // Length mismatch.
        assert!(agg.add(&Update::Dense(ParamVec(vec![0.0; 3])), 1.0).is_err());
        // Out-of-range sparse index (hostile remote upload).
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![9],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(agg.add(&u, 1.0).is_err());
        // Sign/index arity mismatch.
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![1, 2],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(agg.add(&u, 1.0).is_err());
        // Bad weights.
        assert!(agg.add(&Update::Dense(ParamVec(vec![0.0; 4])), -1.0).is_err());
        assert!(agg
            .add(&Update::Dense(ParamVec(vec![0.0; 4])), f64::NAN)
            .is_err());
        // Empty finish.
        assert!(agg.finish().is_err());
    }

    #[test]
    fn zero_total_weight_errors() {
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![0.0; 2]));
        agg.add(&Update::Dense(ParamVec(vec![1.0, 1.0])), 0.0).unwrap();
        assert!(agg.finish().unwrap_err().to_string().contains("zero total"));
    }

    #[test]
    fn finish_resets_for_the_next_round() {
        let mut agg = MeanAggregator::from_ctx(&ctx(vec![0.0; 2]));
        agg.add(&Update::Dense(ParamVec(vec![4.0, 4.0])), 2.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![4.0, 4.0]);
        assert_eq!(agg.count(), 0);
        assert_eq!(agg.total_weight(), 0.0);
        agg.add(&Update::Dense(ParamVec(vec![2.0, 2.0])), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![2.0, 2.0]);
    }

    #[test]
    fn dense_only_accumulator_rejects_sparse() {
        let mut agg = MeanAggregator::dense_only(3);
        let u = Update::SparseTernary {
            len: 3,
            indices: vec![0],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(agg.add(&u, 1.0).is_err());
        agg.add_dense(&[3.0, 0.0, 3.0], 2.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![3.0, 0.0, 3.0]);
    }

    #[test]
    fn chunk_parallel_reduce_is_bit_identical_to_sequential() {
        let p = MIN_PARALLEL_LEN + 37;
        let global: Vec<f32> = (0..p).map(|i| (i as f32 * 0.37).sin()).collect();
        let updates: Vec<(Update, f64)> = (0..9)
            .map(|k| {
                let dense: Vec<f32> =
                    (0..p).map(|i| ((i + k) as f32 * 0.11).cos()).collect();
                (Update::Dense(ParamVec(dense)), (k + 1) as f64)
            })
            .collect();

        let run = |threads: usize| {
            let mut ctx = ctx(global.clone());
            ctx.threads = threads;
            ctx.parallel_threshold = 0;
            ctx.expect_updates = updates.len();
            let mut agg = MeanAggregator::from_ctx(&ctx);
            for (u, w) in &updates {
                agg.add(u, *w).unwrap();
            }
            agg.finish().unwrap()
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(seq.0, par.0, "thread count must not change the result");
    }
}
