//! # easyfl — a low-code federated learning platform
//!
//! Rust + JAX + Pallas reproduction of *"EasyFL: A Low-code Federated
//! Learning Platform For Dummies"* (Zhuang et al., 2021). The platform is
//! a three-layer stack: Pallas kernels (L1) and JAX models (L2) are
//! AOT-compiled to HLO at build time; this crate (L3) is the entire
//! runtime — coordinator, scheduler, simulation, tracking, remote
//! communication and deployment. Python never runs on the training path.
//!
//! ## Quick start (the paper's three lines)
//!
//! ```no_run
//! let session = easyfl::init(easyfl::Config::default()).unwrap();
//! let report = session.run().unwrap();
//! println!("accuracy: {:.2}%", report.final_accuracy * 100.0);
//! ```
//!
//! See `examples/` for heterogeneity simulation, distributed-training
//! optimization (GreedyAda), remote training and the application plugins
//! (FedProx, STC, FedReID).

pub mod algorithms;
pub mod api;
pub mod client;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod deployment;
pub mod flow;
pub mod model;
pub mod runtime;
pub mod scheduler;
pub mod simulation;
pub mod tracking;
pub mod error;
pub mod util;

pub use api::{init, Report, Session};
pub use config::{Allocation, Config, DatasetKind, Partition};
pub use error::{Error, Result};
