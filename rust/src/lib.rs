//! # easyfl — a low-code federated learning platform
//!
//! Rust + JAX + Pallas reproduction of *"EasyFL: A Low-code Federated
//! Learning Platform For Dummies"* (Zhuang et al., 2021). The platform is
//! a three-layer stack: Pallas kernels (L1) and JAX models (L2) are
//! AOT-compiled to HLO at build time; this crate (L3) is the entire
//! runtime — coordinator, scheduler, simulation, tracking, remote
//! communication and deployment. Python never runs on the training path.
//!
//! ## Quick start (the paper's three lines)
//!
//! ```no_run
//! let session = easyfl::init(easyfl::Config::default()).unwrap();
//! let report = session.run().unwrap();
//! println!("accuracy: {:.2}%", report.final_accuracy * 100.0);
//! ```
//!
//! ## Low-code applications: algorithms are configuration
//!
//! Every built-in application resolves by name through the
//! [component registry](registry) — no factory imports, no wiring:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.algorithm = "fedprox".into();   // or "stc", "fedreid", ...
//! cfg.fedprox_mu = 0.1;
//! let report = easyfl::init(cfg).unwrap().run().unwrap();
//! # let _ = report;
//! ```
//!
//! The same holds from JSON config files (`{"algorithm": "stc"}`) and
//! the CLI (`easyfl run --algorithm stc`). Custom algorithms, datasets,
//! partitions and server flows self-register under string names with
//! [`registry::register`]; custom per-session component overrides go
//! through [`api::SessionBuilder`].
//!
//! ## Many jobs, one process
//!
//! [`Platform`] runs concurrent sessions on a bounded worker pool with a
//! shared artifact cache, and [`Sweep`] expands dataset × partition ×
//! algorithm grids into comparative report tables:
//!
//! ```no_run
//! let platform = easyfl::Platform::new(4);
//! let report = easyfl::Sweep::new(easyfl::Config::default())
//!     .algorithms(&["fedavg", "fedprox", "stc"])
//!     .run(&platform)
//!     .unwrap();
//! println!("{}", report.to_table());
//! ```
//!
//! ## Streaming aggregation
//!
//! Every consumer — server rounds, remote ingest, SimNet's FedBuff —
//! reduces uplinks through one incremental [`aggregate::Aggregator`]:
//! dense updates fold in via fused axpy, sparse ternary updates
//! index-wise, chunk-parallel for big cohorts. Memory is O(threads·P)
//! instead of O(cohort·P); `examples/agg_bench.rs` measures the win.
//!
//! ## Compressed transport
//!
//! [`codec`] makes the wire format a config axis: `codec =
//! "top_k_i8(0.05)"` compresses every uplink to the 5% largest-magnitude
//! delta coordinates, i8-quantized with per-chunk scales and a FNV-1a
//! integrity hash. Encoded updates fold into the streaming aggregators
//! index-wise (no dense materialization), SimNet charges the encoded
//! byte size per uplink, and [`CodecSweep`] grids codec × fraction into
//! accuracy / makespan / MB-per-round tables.
//!
//! ## Simulating at scale
//!
//! [`simnet`] is a discrete-event federation simulator on a virtual
//! clock: 100k+ clients with availability churn, dropout, deadline-bound
//! sync rounds or async FedBuff aggregation — hundreds of rounds in
//! seconds, bit-for-bit reproducible per seed. [`SimSweep`] compares
//! {sync, async} × allocation strategies in one report table.
//!
//! ## Hierarchical federation
//!
//! [`hierarchy`] makes the aggregation tree a config axis: `topology =
//! "edges(16)"` interposes edge aggregators between the devices and the
//! cloud, cutting cloud fan-in from O(cohort) to O(edges), with per-tier
//! robust reductions (`edge_agg` / `agg`) and a [`HierSweep`] grid over
//! topology × aggregator.
//!
//! ## Decentralized federation
//!
//! [`gossip`] removes the server entirely: `sim.engine = "gossip"` with
//! a peer topology (`"gossip(8)"` or `"ring"`) runs serverless P2P
//! rounds where every client exchanges deltas with its [`PeerGraph`]
//! neighbors and folds them through the registered aggregator —
//! `bytes_to_cloud` is 0 for the whole run, convergence is measured as
//! consensus distance, and [`GossipSweep`] grids topology × codec
//! against the star/hierarchy baselines.
//!
//! See `examples/` for heterogeneity simulation, distributed-training
//! optimization (GreedyAda), remote training, the application plugins
//! (FedProx, STC, FedReID), and `simnet_scale` for a million-client
//! population simulation.

pub mod aggregate;
pub mod algorithms;
pub mod api;
pub mod client;
pub mod codec;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod deployment;
pub mod error;
pub mod flow;
pub mod gossip;
pub mod hierarchy;
pub mod model;
pub mod obs;
pub mod platform;
pub mod registry;
pub mod runtime;
pub mod scheduler;
pub mod simnet;
pub mod simulation;
pub mod tracking;
pub mod util;

pub use aggregate::{AggContext, Aggregator};
pub use api::{init, Report, Session, SessionBuilder};
pub use codec::{EncodedUpdate, TimedCodec, UpdateCodec};
pub use config::{Allocation, Config, DatasetKind, Partition, SimMode};
pub use error::{Error, Result};
pub use gossip::{GossipEngine, PeerGraph};
pub use hierarchy::{HierPlane, Topology};
pub use obs::{
    ChromeTraceSink, Histogram, MetricsRegistry, NullSink, Span, Telemetry,
    TelemetrySink,
};
pub use platform::{
    CodecSweep, CodecSweepReport, GossipSweep, GossipSweepReport, HierSweep,
    HierSweepReport, JobHandle, JobStatus, Platform, SimSweep, SimSweepReport,
    Sweep, SweepReport,
};
pub use simnet::{SimNet, SimReport};
