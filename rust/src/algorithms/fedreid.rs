//! FedReID-style personalization (Zhuang et al., ACM MM 2020).
//!
//! FedReID trains person re-identification across nine heterogeneous
//! camera-network datasets; per Table VII it changes the **aggregation**
//! and **train** stages: the feature backbone is federated while each
//! client keeps a personal classifier head (the ReID identity spaces
//! differ per client).
//!
//! On the flat-parameter contract the head is the trailing
//! `head_len` coordinates (the model's final dense layer). The server
//! aggregates only the backbone slice — a slice-masked accumulator on
//! the streaming aggregation plane (the `"backbone"` registry entry):
//! the personal-head tail is never averaged, the global keeps its own
//! head, and client heads persist across rounds in a shared
//! [`SharedHeads`] map keyed by client id.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::aggregate::{AggContext, Aggregator};
use crate::coordinator::ClientFlowFactory;
use crate::error::Result;
use crate::flow::client_stages::TrainStats;
use crate::flow::{ClientFlow, ModelPayload, ServerFlow, TrainTask};
use crate::model::{ModelMeta, ParamVec};
use crate::registry::{AlgorithmParts, ComponentRegistry};
use crate::runtime::Engine;

/// Per-client personal head storage, shared across device workers.
pub type SharedHeads = Arc<Mutex<HashMap<usize, Vec<f32>>>>;

/// Flat length of the personal head (final dense layer W + b).
pub fn head_len(meta: &ModelMeta) -> usize {
    let n = meta.layout.len();
    meta.layout[n - 2].len() + meta.layout[n - 1].len()
}

/// Client flow: swap in the personal head before training, store it after.
pub struct FedReidClientFlow {
    heads: SharedHeads,
}

impl ClientFlow for FedReidClientFlow {
    fn name(&self) -> &'static str {
        "fedreid"
    }

    fn decompress(&mut self, payload: &ModelPayload) -> Result<ParamVec> {
        Ok((*payload.params).clone())
    }

    fn train(
        &mut self,
        engine: &Engine,
        task: &TrainTask,
        mut params: ParamVec,
    ) -> Result<(ParamVec, TrainStats)> {
        let meta = engine.meta(&task.model)?;
        let hl = head_len(&meta);
        let split = params.len() - hl;
        // Personalization: restore this client's head if it has one.
        if let Some(head) = self.heads.lock().unwrap().get(&task.client) {
            params[split..].copy_from_slice(head);
        }
        let (new_params, stats) =
            crate::flow::client_stages::local_sgd(
                engine,
                task,
                params,
                |eng, model, p, m, b, lr| eng.train_step(model, p, m, b, lr),
            )?;
        self.heads
            .lock()
            .unwrap()
            .insert(task.client, new_params[split..].to_vec());
        Ok((new_params, stats))
    }
}

/// Server flow: aggregate the backbone, keep the global model's head.
pub struct FedReidServerFlow {
    /// Resolved lazily from artifact metadata on first aggregator
    /// construction when built via [`FedReidServerFlow::lazy`] (the
    /// registry path: no engine exists yet at registration time).
    head_len: Option<usize>,
}

impl FedReidServerFlow {
    pub fn new(head_len: usize) -> Self {
        FedReidServerFlow { head_len: Some(head_len) }
    }

    /// Convenience: read the head length from artifact metadata.
    pub fn from_meta(meta: &ModelMeta) -> Self {
        Self::new(head_len(meta))
    }

    /// Defer head-length resolution to the first `aggregate` call.
    pub fn lazy() -> Self {
        FedReidServerFlow { head_len: None }
    }
}

impl ServerFlow for FedReidServerFlow {
    fn name(&self) -> &'static str {
        "fedreid"
    }

    fn aggregator_name(&self) -> &str {
        "backbone"
    }

    /// The backbone-slice merge as a slice-masked accumulator: resolve
    /// the head boundary (lazily, from artifact metadata) and hand the
    /// protected tail to the `"backbone"` registry aggregator. Client
    /// head slices never enter the reduction; the global keeps its own.
    fn make_aggregator(
        &mut self,
        engine: &Engine,
        model: &str,
        ctx: AggContext,
    ) -> Result<Box<dyn Aggregator>> {
        let hl = match self.head_len {
            Some(hl) => hl,
            None => {
                let hl = head_len(&engine.meta(model)?);
                self.head_len = Some(hl);
                hl
            }
        };
        let ctx = ctx.protected_tail(hl);
        crate::registry::with_global(|r| r.aggregator("backbone", &ctx))
    }
}

/// Factory: all workers share one head map.
pub fn fedreid_client_factory(heads: SharedHeads) -> ClientFlowFactory {
    Arc::new(move || {
        Box::new(FedReidClientFlow { heads: heads.clone() })
    })
}

/// Self-register under the name `"fedreid"`. Each instantiation gets its
/// own head map (sessions must not share personalization state), and the
/// server flow resolves the head boundary lazily from artifact metadata.
pub(crate) fn register(reg: &mut ComponentRegistry) {
    reg.register_algorithm(
        "fedreid",
        Arc::new(|_cfg| {
            let heads: SharedHeads = Arc::new(Mutex::new(HashMap::new()));
            Ok(AlgorithmParts {
                server_flow: Box::new(FedReidServerFlow::lazy()),
                client_factory: fedreid_client_factory(heads),
            })
        }),
    );
    reg.register_server_flow(
        "fedreid",
        Arc::new(|_cfg| Ok(Box::new(FedReidServerFlow::lazy()) as Box<dyn ServerFlow>)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backbone_aggregator_protects_the_head_slice() {
        let mut flow = FedReidServerFlow::new(2);
        assert_eq!(flow.aggregator_name(), "backbone");
        let engine = Engine::new(std::path::Path::new("/nonexistent")).unwrap();
        let global = Arc::new(ParamVec(vec![0.0, 0.0, 7.0, 8.0]));
        let mut agg = flow
            .make_aggregator(&engine, "mlp", AggContext::new(global))
            .unwrap();
        assert_eq!(agg.name(), "backbone");
        agg.add(
            &crate::flow::Update::Dense(ParamVec(vec![2.0, 4.0, 1.0, 1.0])),
            1.0,
        )
        .unwrap();
        let out = agg.finish().unwrap();
        // Backbone merged; the client's head coordinates were ignored and
        // the global head survived.
        assert_eq!(out.0, vec![2.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn shared_heads_type_is_threadsafe() {
        let heads: SharedHeads = Arc::new(Mutex::new(HashMap::new()));
        let h2 = heads.clone();
        std::thread::spawn(move || {
            h2.lock().unwrap().insert(1, vec![1.0, 2.0]);
        })
        .join()
        .unwrap();
        assert_eq!(heads.lock().unwrap()[&1], vec![1.0, 2.0]);
    }
}
