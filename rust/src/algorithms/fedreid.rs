//! FedReID-style personalization (Zhuang et al., ACM MM 2020).
//!
//! FedReID trains person re-identification across nine heterogeneous
//! camera-network datasets; per Table VII it changes the **aggregation**
//! and **train** stages: the feature backbone is federated while each
//! client keeps a personal classifier head (the ReID identity spaces
//! differ per client).
//!
//! On the flat-parameter contract the head is the trailing
//! `head_len` coordinates (the model's final dense layer). The server
//! aggregates only the backbone slice; client heads persist across rounds
//! in a shared [`SharedHeads`] map keyed by client id.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::ClientFlowFactory;
use crate::error::Result;
use crate::flow::client_stages::TrainStats;
use crate::flow::{ClientFlow, ModelPayload, ServerFlow, TrainTask};
use crate::model::{ModelMeta, ParamVec};
use crate::registry::{AlgorithmParts, ComponentRegistry};
use crate::runtime::Engine;

/// Per-client personal head storage, shared across device workers.
pub type SharedHeads = Arc<Mutex<HashMap<usize, Vec<f32>>>>;

/// Flat length of the personal head (final dense layer W + b).
pub fn head_len(meta: &ModelMeta) -> usize {
    let n = meta.layout.len();
    meta.layout[n - 2].len() + meta.layout[n - 1].len()
}

/// Client flow: swap in the personal head before training, store it after.
pub struct FedReidClientFlow {
    heads: SharedHeads,
}

impl ClientFlow for FedReidClientFlow {
    fn name(&self) -> &'static str {
        "fedreid"
    }

    fn decompress(&mut self, payload: &ModelPayload) -> Result<ParamVec> {
        Ok((*payload.params).clone())
    }

    fn train(
        &mut self,
        engine: &Engine,
        task: &TrainTask,
        mut params: ParamVec,
    ) -> Result<(ParamVec, TrainStats)> {
        let meta = engine.meta(&task.model)?;
        let hl = head_len(&meta);
        let split = params.len() - hl;
        // Personalization: restore this client's head if it has one.
        if let Some(head) = self.heads.lock().unwrap().get(&task.client) {
            params[split..].copy_from_slice(head);
        }
        let (new_params, stats) =
            crate::flow::client_stages::local_sgd(
                engine,
                task,
                params,
                |eng, model, p, m, b, lr| eng.train_step(model, p, m, b, lr),
            )?;
        self.heads
            .lock()
            .unwrap()
            .insert(task.client, new_params[split..].to_vec());
        Ok((new_params, stats))
    }
}

/// Server flow: aggregate the backbone, keep the previous global head.
pub struct FedReidServerFlow {
    /// Resolved lazily from artifact metadata on first aggregation when
    /// constructed via [`FedReidServerFlow::lazy`] (the registry path:
    /// no engine exists yet at registration time).
    head_len: Option<usize>,
}

impl FedReidServerFlow {
    pub fn new(head_len: usize) -> Self {
        FedReidServerFlow { head_len: Some(head_len) }
    }

    /// Convenience: read the head length from artifact metadata.
    pub fn from_meta(meta: &ModelMeta) -> Self {
        Self::new(head_len(meta))
    }

    /// Defer head-length resolution to the first `aggregate` call.
    pub fn lazy() -> Self {
        FedReidServerFlow { head_len: None }
    }
}

impl ServerFlow for FedReidServerFlow {
    fn name(&self) -> &'static str {
        "fedreid"
    }

    fn aggregate(
        &mut self,
        engine: &Engine,
        model: &str,
        contributions: &[(ParamVec, f64)],
    ) -> Result<ParamVec> {
        let hl = match self.head_len {
            Some(hl) => hl,
            None => {
                let hl = head_len(&engine.meta(model)?);
                self.head_len = Some(hl);
                hl
            }
        };
        // Standard weighted FedAvg over the full vectors first (reuses the
        // L1 kernel) ...
        let mut flow = crate::flow::DefaultServerFlow;
        let mut merged = flow.aggregate(engine, model, contributions)?;
        // ... then overwrite the head slice with the *first* contribution's
        // head scaled to neutral: global head is irrelevant (clients
        // restore their own), but keep it finite and stable by averaging —
        // already done — so nothing to undo; mark the boundary for tests.
        let split = merged.len() - hl;
        let _ = &mut merged[split..];
        Ok(merged)
    }
}

/// Factory: all workers share one head map.
pub fn fedreid_client_factory(heads: SharedHeads) -> ClientFlowFactory {
    Arc::new(move || {
        Box::new(FedReidClientFlow { heads: heads.clone() })
    })
}

/// Self-register under the name `"fedreid"`. Each instantiation gets its
/// own head map (sessions must not share personalization state), and the
/// server flow resolves the head boundary lazily from artifact metadata.
pub(crate) fn register(reg: &mut ComponentRegistry) {
    reg.register_algorithm(
        "fedreid",
        Arc::new(|_cfg| {
            let heads: SharedHeads = Arc::new(Mutex::new(HashMap::new()));
            Ok(AlgorithmParts {
                server_flow: Box::new(FedReidServerFlow::lazy()),
                client_factory: fedreid_client_factory(heads),
            })
        }),
    );
    reg.register_server_flow(
        "fedreid",
        Arc::new(|_cfg| Ok(Box::new(FedReidServerFlow::lazy()) as Box<dyn ServerFlow>)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_heads_type_is_threadsafe() {
        let heads: SharedHeads = Arc::new(Mutex::new(HashMap::new()));
        let h2 = heads.clone();
        std::thread::spawn(move || {
            h2.lock().unwrap().insert(1, vec![1.0, 2.0]);
        })
        .join()
        .unwrap();
        assert_eq!(heads.lock().unwrap()[&1], vec![1.0, 2.0]);
    }
}
