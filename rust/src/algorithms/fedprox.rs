//! FedProx (Li et al., MLSys 2020) as a one-stage plugin.
//!
//! FedProx adds a proximal term μ/2‖w − w_global‖² to the local objective.
//! Server-side it inherits everything, streaming `"mean"` aggregation
//! included. Per the paper's Table VII it changes **only the client train
//! stage** —
//! and that is literally the whole plugin: `train` dispatches to the AOT
//! `fedprox` entry point (the μ-gradient is fused into the L2 graph), all
//! other stages inherit the FedAvg defaults. The paper's LOC argument
//! (Table V: ~380 LOC original vs tens here) is reproduced by this file.

use std::sync::Arc;

use crate::coordinator::ClientFlowFactory;
use crate::error::Result;
use crate::registry::{AlgorithmParts, ComponentRegistry};
use crate::flow::client_stages::{local_sgd, TrainStats};
use crate::flow::{ClientFlow, TrainTask};
use crate::model::ParamVec;
use crate::runtime::Engine;

/// Client flow overriding the train stage with the proximal step.
pub struct FedProxClientFlow {
    /// Proximal coefficient μ.
    pub mu: f32,
}

impl ClientFlow for FedProxClientFlow {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn train(
        &mut self,
        engine: &Engine,
        task: &TrainTask,
        params: ParamVec,
    ) -> Result<(ParamVec, TrainStats)> {
        let global = task.payload.params.clone();
        let mu = self.mu;
        local_sgd(engine, task, params, move |eng, model, p, m, b, lr| {
            eng.fedprox_step(model, p, &global, m, b, lr, mu)
        })
    }
}

/// Factory for the device pool.
pub fn fedprox_client_factory(mu: f32) -> ClientFlowFactory {
    Arc::new(move || Box::new(FedProxClientFlow { mu }))
}

/// Self-register under the name `"fedprox"`; μ comes from
/// `Config::fedprox_mu`, so selecting FedProx is pure configuration.
pub(crate) fn register(reg: &mut ComponentRegistry) {
    reg.register_algorithm(
        "fedprox",
        Arc::new(|cfg| {
            Ok(AlgorithmParts {
                server_flow: Box::new(crate::flow::DefaultServerFlow),
                client_factory: fedprox_client_factory(cfg.fedprox_mu as f32),
            })
        }),
    );
}
