//! FedAvg (McMahan et al. 2017) — the platform default.
//!
//! Nothing to override: FedAvg *is* the set of default stages, including
//! the streaming `"mean"` aggregator on the aggregation plane (weighted
//! mean, one fused axpy per arriving update). This module only provides
//! the canonical factory and a named marker type.

use std::sync::Arc;

use crate::coordinator::ClientFlowFactory;
use crate::flow::{DefaultClientFlow, DefaultServerFlow, ServerFlow};
use crate::registry::{AlgorithmParts, ComponentRegistry};

/// Marker for the default algorithm.
pub struct FedAvg;

impl FedAvg {
    /// The default server flow.
    pub fn server_flow() -> Box<dyn ServerFlow> {
        Box::new(DefaultServerFlow)
    }
}

/// Factory: one default client flow per device worker.
pub fn fedavg_client_factory() -> ClientFlowFactory {
    Arc::new(|| Box::new(DefaultClientFlow))
}

/// Self-register under the name `"fedavg"`.
pub(crate) fn register(reg: &mut ComponentRegistry) {
    reg.register_algorithm(
        "fedavg",
        Arc::new(|_cfg| {
            Ok(AlgorithmParts {
                server_flow: FedAvg::server_flow(),
                client_factory: fedavg_client_factory(),
            })
        }),
    );
}
