//! FedAvg (McMahan et al. 2017) — the platform default.
//!
//! Nothing to override: FedAvg *is* the set of default stages. This module
//! only provides the canonical factory and a named marker type.

use std::sync::Arc;

use crate::coordinator::ClientFlowFactory;
use crate::flow::{DefaultClientFlow, DefaultServerFlow, ServerFlow};

/// Marker for the default algorithm.
pub struct FedAvg;

impl FedAvg {
    /// The default server flow.
    pub fn server_flow() -> Box<dyn ServerFlow> {
        Box::new(DefaultServerFlow)
    }
}

/// Factory: one default client flow per device worker.
pub fn fedavg_client_factory() -> ClientFlowFactory {
    Arc::new(|| Box::new(DefaultClientFlow))
}
