//! STC — Sparse Ternary Compression (Sattler et al., TNNLS 2019).
//!
//! STC uploads only the top-k fraction of the local update's coordinates,
//! ternarized to {−μ, 0, +μ} where μ is the mean magnitude of the kept
//! coordinates. Per Table VII it changes the client **compression** stage
//! and the matching server **decompression** stage; training and
//! aggregation stay stock FedAvg. The paper integrated STC "with around 80
//! lines of code" versus several hundred in the original release — this
//! file is the equivalent demonstration.

use std::sync::Arc;

use crate::coordinator::ClientFlowFactory;
use crate::error::Result;
use crate::flow::{ClientFlow, ServerFlow, Update};
use crate::model::ParamVec;
use crate::registry::{AlgorithmParts, ComponentRegistry};

/// Client flow: dense update → sparse ternary delta.
pub struct STCClientFlow {
    /// Fraction of coordinates kept (paper uses p = 1/400; we default 1%).
    pub sparsity: f64,
}

impl STCClientFlow {
    pub fn new(sparsity: f64) -> Self {
        assert!(sparsity > 0.0 && sparsity <= 1.0);
        STCClientFlow { sparsity }
    }
}

/// Top-k ternary compression of `new − global`.
pub fn stc_compress(new: &ParamVec, global: &ParamVec, sparsity: f64) -> Update {
    let p = new.len();
    let k = ((p as f64 * sparsity).ceil() as usize).clamp(1, p);
    let mut delta: Vec<(u32, f32)> = new
        .iter()
        .zip(global.iter())
        .enumerate()
        .map(|(i, (n, g))| (i as u32, n - g))
        .collect();
    // Partial select of the k largest |delta| (O(P) expected).
    delta.select_nth_unstable_by(k - 1, |a, b| {
        b.1.abs().partial_cmp(&a.1.abs()).unwrap()
    });
    delta.truncate(k);
    let magnitude =
        delta.iter().map(|(_, d)| d.abs()).sum::<f32>() / k as f32;
    let mut indices = Vec::with_capacity(k);
    let mut signs = Vec::with_capacity(k);
    for (i, d) in delta {
        indices.push(i);
        signs.push(d >= 0.0);
    }
    Update::SparseTernary { len: p, indices, signs, magnitude }
}

impl ClientFlow for STCClientFlow {
    fn name(&self) -> &'static str {
        "stc"
    }

    fn compress(&mut self, new_params: ParamVec, global: &ParamVec) -> Result<Update> {
        Ok(stc_compress(&new_params, global, self.sparsity))
    }
}

/// Server flow: on the streaming aggregation plane the sparse ternary
/// delta is applied **index-wise** by the `"mean"` aggregator — k
/// touched coordinates per update, never a dense `to_dense` round-trip.
/// This type exists to carry the algorithm name and to make the stage
/// substitution explicit; every stage inherits the FedAvg defaults.
#[derive(Default)]
pub struct STCServerFlow;

impl ServerFlow for STCServerFlow {
    fn name(&self) -> &'static str {
        "stc"
    }
}

/// Factory for the device pool.
pub fn stc_client_factory(sparsity: f64) -> ClientFlowFactory {
    Arc::new(move || Box::new(STCClientFlow::new(sparsity)))
}

/// Self-register under the name `"stc"`; the kept fraction comes from
/// `Config::stc_sparsity`.
pub(crate) fn register(reg: &mut ComponentRegistry) {
    reg.register_algorithm(
        "stc",
        Arc::new(|cfg| {
            Ok(AlgorithmParts {
                server_flow: Box::new(STCServerFlow),
                client_factory: stc_client_factory(cfg.stc_sparsity),
            })
        }),
    );
    reg.register_server_flow(
        "stc",
        Arc::new(|_cfg| Ok(Box::new(STCServerFlow) as Box<dyn ServerFlow>)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_topk_and_reconstructs() {
        let global = ParamVec(vec![0.0; 100]);
        let mut new = global.clone();
        new[7] = 5.0;
        new[42] = -4.0;
        new[13] = 0.001; // below the cut
        let u = stc_compress(&new, &global, 0.02); // k = 2
        match &u {
            Update::SparseTernary { indices, magnitude, .. } => {
                let mut idx = indices.clone();
                idx.sort_unstable();
                assert_eq!(idx, vec![7, 42]);
                assert!((magnitude - 4.5).abs() < 1e-6);
            }
            _ => panic!("expected sparse ternary"),
        }
        let dense = u.to_dense(&global).unwrap();
        assert!((dense[7] - 4.5).abs() < 1e-6);
        assert!((dense[42] + 4.5).abs() < 1e-6);
        assert_eq!(dense[13], 0.0);
    }

    #[test]
    fn compression_ratio_matches_sparsity() {
        let global = ParamVec(vec![0.0; 10_000]);
        let new = ParamVec((0..10_000).map(|i| (i as f32).sin()).collect());
        let u = stc_compress(&new, &global, 0.01);
        let dense_bytes = 10_000 * 4;
        assert!(
            u.wire_bytes() < dense_bytes / 50,
            "ratio too weak: {} vs {dense_bytes}",
            u.wire_bytes()
        );
    }

    #[test]
    fn full_sparsity_recovers_signs_everywhere() {
        let global = ParamVec(vec![1.0; 8]);
        let new = ParamVec(vec![2.0, 0.0, 2.0, 0.0, 2.0, 0.0, 2.0, 0.0]);
        let u = stc_compress(&new, &global, 1.0);
        let dense = u.to_dense(&global).unwrap();
        // All deltas are ±1, magnitude 1: perfect ternary reconstruction.
        assert_eq!(dense.0, new.0);
    }
}
