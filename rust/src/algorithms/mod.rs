//! Algorithm plugins (paper §V-B, §VIII-F).
//!
//! Each plugin overrides exactly the training-flow stages the paper's
//! Table VII attributes to it:
//!
//! | plugin  | stages changed                         |
//! |---------|----------------------------------------|
//! | FedAvg  | — (the defaults)                       |
//! | FedProx | client *train*                         |
//! | STC     | client *compression*, server *decompression* |
//! | FedReID | server *aggregation*, client *train* (personal head) |
//! | Masked  | client *encryption*, server *decompression* (demo) |

pub mod fedavg;
pub mod fedprox;
pub mod fedreid;
pub mod stc;

pub use fedavg::{fedavg_client_factory, FedAvg};
pub use fedprox::{fedprox_client_factory, FedProxClientFlow};
pub use fedreid::{fedreid_client_factory, FedReidServerFlow, SharedHeads};
pub use stc::{stc_client_factory, stc_compress, STCClientFlow, STCServerFlow};

/// Every built-in algorithm self-registers into the component registry;
/// `Config::algorithm = "<name>"` is then all it takes to select one.
pub(crate) fn register_builtins(reg: &mut crate::registry::ComponentRegistry) {
    fedavg::register(reg);
    fedprox::register(reg);
    stc::register(reg);
    fedreid::register(reg);
}
