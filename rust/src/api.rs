//! The interface layer (paper §IV, Table II): the low-code API.
//!
//! The paper's quick start is three lines; so is ours:
//!
//! ```no_run
//! let session = easyfl::init(easyfl::Config::default()).unwrap();   // init(configs)
//! let report = session.run().unwrap();                              // run()
//! println!("accuracy {:.1}%", report.final_accuracy * 100.0);
//! ```
//!
//! `register_dataset`, `register_model`, `register_server` and
//! `register_client` swap any module for a custom one, mirroring Table II.

use std::sync::Arc;

use crate::algorithms::fedavg_client_factory;
use crate::config::Config;
use crate::coordinator::{ClientFlowFactory, Server};
use crate::data::registry::DataSource;
use crate::data::FedDataset;
use crate::error::Result;
use crate::flow::{DefaultServerFlow, ServerFlow};
use crate::tracking::Tracker;

/// Outcome of a training run — the numbers the paper's evaluation reports.
#[derive(Debug, Clone)]
pub struct Report {
    /// Test accuracy after the final evaluated round.
    pub final_accuracy: f64,
    /// Best test accuracy over all rounds.
    pub best_accuracy: f64,
    /// Final-round average training loss.
    pub final_train_loss: f64,
    /// Mean simulated round time (T_total / R).
    pub avg_round_ms: f64,
    /// Total communication volume.
    pub comm_bytes: usize,
    pub rounds: usize,
}

/// An initialized EasyFL session (paper: the state `init(configs)` sets up).
pub struct Session {
    cfg: Config,
    dataset: Option<Arc<dyn DataSource>>,
    server_flow: Option<Box<dyn ServerFlow>>,
    client_factory: ClientFlowFactory,
    tracker: Option<Arc<Tracker>>,
}

/// `init(configs)` — Table II row 1.
pub fn init(cfg: Config) -> Result<Session> {
    cfg.validate()?;
    Ok(Session {
        cfg,
        dataset: None,
        server_flow: None,
        client_factory: fedavg_client_factory(),
        tracker: None,
    })
}

impl Session {
    /// `register_dataset(train, test)` — plug a custom federated dataset.
    pub fn register_dataset(mut self, source: Arc<dyn DataSource>) -> Session {
        self.dataset = Some(source);
        self
    }

    /// `register_model(model)` — select a different AOT model artifact.
    pub fn register_model(mut self, model: &str) -> Session {
        self.cfg.model = model.to_string();
        self
    }

    /// `register_server(server)` — replace server-side flow stages.
    pub fn register_server(mut self, flow: Box<dyn ServerFlow>) -> Session {
        self.server_flow = Some(flow);
        self
    }

    /// `register_client(client)` — replace client-side flow stages.
    pub fn register_client(mut self, factory: ClientFlowFactory) -> Session {
        self.client_factory = factory;
        self
    }

    /// Attach a pre-built tracker (remote tracking, shared stores).
    pub fn with_tracker(mut self, tracker: Arc<Tracker>) -> Session {
        self.tracker = Some(tracker);
        self
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Build the server without running (examples and remote mode).
    pub fn build_server(self) -> Result<Server> {
        let data: Arc<dyn DataSource> = match self.dataset {
            Some(d) => d,
            None => Arc::new(FedDataset::from_config(&self.cfg)?),
        };
        let flow = self.server_flow.unwrap_or_else(|| Box::new(DefaultServerFlow));
        let tracker = self.tracker.unwrap_or_else(|| {
            let id = format!(
                "task-{}-{}-{}",
                self.cfg.dataset.name(),
                self.cfg.partition.name(),
                self.cfg.seed
            );
            match &self.cfg.tracking_dir {
                Some(dir) => Arc::new(Tracker::persistent(&id, dir.clone())),
                None => Arc::new(Tracker::new(&id)),
            }
        });
        Server::new(self.cfg, data, flow, self.client_factory, tracker)
    }

    /// `run(callback)` — train all rounds and report.
    pub fn run(self) -> Result<Report> {
        self.run_with(|_server, _round| {})
    }

    /// `run` with a per-round callback (Table II's optional callback).
    pub fn run_with<F>(self, mut callback: F) -> Result<Report>
    where
        F: FnMut(&Server, usize),
    {
        let mut server = self.build_server()?;
        let rounds = server.cfg.rounds;
        for round in 0..rounds {
            server.run_round(round)?;
            callback(&server, round);
        }
        let tracker = server.tracker();
        tracker.finish()?;
        let curve = tracker.loss_curve();
        Ok(Report {
            final_accuracy: tracker.final_accuracy().unwrap_or(0.0),
            best_accuracy: tracker.best_accuracy().unwrap_or(0.0),
            final_train_loss: curve.last().map(|(_, l, _)| *l).unwrap_or(0.0),
            avg_round_ms: tracker.avg_round_ms(),
            comm_bytes: tracker.total_comm_bytes(),
            rounds,
        })
    }
}
