//! The interface layer (paper §IV, Table II): the low-code API.
//!
//! The paper's quick start is three lines; so is ours — and, since the
//! component registry landed, so is every built-in application:
//!
//! ```no_run
//! let session = easyfl::init(easyfl::Config::default()).unwrap();   // init(configs)
//! let report = session.run().unwrap();                              // run()
//! println!("accuracy {:.1}%", report.final_accuracy * 100.0);
//! ```
//!
//! Selecting FedProx (or STC, or FedReID) is configuration, not wiring:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.algorithm = "fedprox".into();          // registry lookup at init
//! cfg.fedprox_mu = 0.1;
//! let report = easyfl::init(cfg).unwrap().run().unwrap();
//! ```
//!
//! Custom components plug in through [`SessionBuilder`], the
//! non-consuming successor of the old `register_*` methods (mirroring
//! Table II): `dataset`, `model`, `server_flow`, `client_factory`,
//! `tracker`. For many concurrent sessions, see [`crate::platform`].

use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::{ClientFlowFactory, Server};
use crate::data::registry::DataSource;
use crate::data::FedDataset;
use crate::error::Result;
use crate::flow::ServerFlow;
use crate::registry;
use crate::tracking::Tracker;

/// Outcome of a training run — the numbers the paper's evaluation reports.
#[derive(Debug, Clone)]
pub struct Report {
    /// Test accuracy after the final evaluated round.
    pub final_accuracy: f64,
    /// Best test accuracy over all rounds.
    pub best_accuracy: f64,
    /// Final-round average training loss.
    pub final_train_loss: f64,
    /// Mean simulated round time (T_total / R).
    pub avg_round_ms: f64,
    /// Total communication volume.
    pub comm_bytes: usize,
    pub rounds: usize,
    /// True when the run produced evaluation metrics; false means the
    /// accuracy fields are placeholder zeros (e.g. `eval_every = 0`) and
    /// a warning was recorded with the tracker.
    pub converged: bool,
}

/// Builder for an EasyFL session: configuration plus optional component
/// overrides. Non-consuming — methods take `&mut self`, so a builder can
/// be threaded through helper functions before [`SessionBuilder::build`].
pub struct SessionBuilder {
    cfg: Config,
    dataset: Option<Arc<dyn DataSource>>,
    server_flow: Option<Box<dyn ServerFlow>>,
    client_factory: Option<ClientFlowFactory>,
    tracker: Option<Arc<Tracker>>,
}

impl SessionBuilder {
    pub fn new(cfg: Config) -> SessionBuilder {
        SessionBuilder {
            cfg,
            dataset: None,
            server_flow: None,
            client_factory: None,
            tracker: None,
        }
    }

    /// Select a registered algorithm by name (`Config::algorithm`).
    pub fn algorithm(&mut self, name: &str) -> &mut Self {
        self.cfg.algorithm = name.to_string();
        self
    }

    /// Plug a custom federated dataset (paper: `register_dataset`).
    pub fn dataset(&mut self, source: Arc<dyn DataSource>) -> &mut Self {
        self.dataset = Some(source);
        self
    }

    /// Select a different AOT model artifact (paper: `register_model`).
    pub fn model(&mut self, model: &str) -> &mut Self {
        self.cfg.model = model.to_string();
        self
    }

    /// Replace server-side flow stages (paper: `register_server`).
    pub fn server_flow(&mut self, flow: Box<dyn ServerFlow>) -> &mut Self {
        self.server_flow = Some(flow);
        self
    }

    /// Replace client-side flow stages (paper: `register_client`).
    pub fn client_factory(&mut self, factory: ClientFlowFactory) -> &mut Self {
        self.client_factory = Some(factory);
        self
    }

    /// Attach a pre-built tracker (remote tracking, shared stores).
    pub fn tracker(&mut self, tracker: Arc<Tracker>) -> &mut Self {
        self.tracker = Some(tracker);
        self
    }

    /// Access the configuration as currently staged.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Validate the config, resolve the algorithm (and, if requested, the
    /// data source) through the component registry, and produce a
    /// ready-to-run [`Session`]. Component overrides staged on the
    /// builder take precedence over the algorithm's own parts.
    ///
    /// The builder can be reused; staged overrides are moved into the
    /// first session built.
    pub fn build(&mut self) -> Result<Session> {
        self.cfg.validate()?;
        let parts = registry::with_global(|r| r.algorithm(&self.cfg))?;
        let dataset = match (self.dataset.take(), self.cfg.data_source.clone()) {
            (Some(d), _) => Some(d),
            (None, Some(name)) => {
                // Keep cfg.dataset in sync when the source names a built-in
                // kind, so "auto" model pairing follows the actual data.
                if let Ok(kind) = crate::config::DatasetKind::parse(&name) {
                    self.cfg.dataset = kind;
                }
                Some(registry::with_global(|r| r.dataset(&name, &self.cfg))?)
            }
            (None, None) => None,
        };
        Ok(Session {
            cfg: self.cfg.clone(),
            dataset,
            server_flow: self.server_flow.take().unwrap_or(parts.server_flow),
            client_factory: self
                .client_factory
                .take()
                .unwrap_or(parts.client_factory),
            tracker: self.tracker.take(),
        })
    }
}

/// An initialized EasyFL session (paper: the state `init(configs)` sets
/// up) — every component resolved, ready to `run`.
pub struct Session {
    cfg: Config,
    dataset: Option<Arc<dyn DataSource>>,
    server_flow: Box<dyn ServerFlow>,
    client_factory: ClientFlowFactory,
    tracker: Option<Arc<Tracker>>,
}

/// `init(configs)` — Table II row 1. Resolves `cfg.algorithm` (and
/// `cfg.data_source`, if set) through the component registry; unknown
/// names fail here with the catalog of registered names.
pub fn init(cfg: Config) -> Result<Session> {
    SessionBuilder::new(cfg).build()
}

impl Session {
    /// `register_dataset(train, test)` — plug a custom federated dataset.
    #[deprecated(since = "0.2.0", note = "use SessionBuilder::dataset")]
    pub fn register_dataset(mut self, source: Arc<dyn DataSource>) -> Session {
        self.dataset = Some(source);
        self
    }

    /// `register_model(model)` — select a different AOT model artifact.
    #[deprecated(since = "0.2.0", note = "use SessionBuilder::model")]
    pub fn register_model(mut self, model: &str) -> Session {
        self.cfg.model = model.to_string();
        self
    }

    /// `register_server(server)` — replace server-side flow stages.
    #[deprecated(since = "0.2.0", note = "use SessionBuilder::server_flow")]
    pub fn register_server(mut self, flow: Box<dyn ServerFlow>) -> Session {
        self.server_flow = flow;
        self
    }

    /// `register_client(client)` — replace client-side flow stages.
    #[deprecated(since = "0.2.0", note = "use SessionBuilder::client_factory")]
    pub fn register_client(mut self, factory: ClientFlowFactory) -> Session {
        self.client_factory = factory;
        self
    }

    /// Attach a pre-built tracker (remote tracking, shared stores).
    #[deprecated(since = "0.2.0", note = "use SessionBuilder::tracker")]
    pub fn with_tracker(mut self, tracker: Arc<Tracker>) -> Session {
        self.tracker = Some(tracker);
        self
    }

    /// Access the effective configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The session's tracker (created on demand if none was attached).
    fn resolve_tracker(&mut self) -> Arc<Tracker> {
        if let Some(t) = &self.tracker {
            return t.clone();
        }
        let id = format!(
            "task-{}-{}-{}-{}",
            self.cfg.algorithm,
            self.cfg.dataset.name(),
            self.cfg.partition.name(),
            self.cfg.seed
        );
        let t = match &self.cfg.tracking_dir {
            Some(dir) => Arc::new(Tracker::persistent(&id, dir.clone())),
            None => Arc::new(Tracker::new(&id)),
        };
        self.tracker = Some(t.clone());
        t
    }

    /// Build the server without running (examples and remote mode).
    pub fn build_server(mut self) -> Result<Server> {
        let tracker = self.resolve_tracker();
        tracker.set_config("algorithm", self.cfg.algorithm.clone());
        let data: Arc<dyn DataSource> = match self.dataset {
            Some(d) => d,
            None => Arc::new(FedDataset::from_config(&self.cfg)?),
        };
        Server::new(
            self.cfg,
            data,
            self.server_flow,
            self.client_factory,
            tracker,
        )
    }

    /// `run(callback)` — train all rounds and report.
    pub fn run(self) -> Result<Report> {
        self.run_with(|_server, _round| {})
    }

    /// `run` with a per-round callback (Table II's optional callback).
    pub fn run_with<F>(self, mut callback: F) -> Result<Report>
    where
        F: FnMut(&Server, usize),
    {
        let mut server = self.build_server()?;
        let rounds = server.cfg.rounds;
        for round in 0..rounds {
            server.run_round(round)?;
            callback(&server, round);
        }
        let tracker = server.tracker();
        // Assemble the report (which may record warnings) before finish()
        // persists the task, so warnings land in the saved JSON.
        let report = report_from_tracker(&tracker, rounds);
        tracker.finish()?;
        Ok(report)
    }
}

/// Assemble a [`Report`] from a finished tracker. Missing evaluation
/// metrics are surfaced as `converged = false` plus a tracker warning
/// instead of being silently zeroed.
pub(crate) fn report_from_tracker(tracker: &Tracker, rounds: usize) -> Report {
    let curve = tracker.loss_curve();
    let final_accuracy = tracker.final_accuracy();
    if final_accuracy.is_none() {
        tracker.warn(
            "no test accuracy was recorded (eval_every = 0 or no evaluated \
             rounds); Report accuracy fields default to 0.0",
        );
    }
    Report {
        final_accuracy: final_accuracy.unwrap_or(0.0),
        best_accuracy: tracker.best_accuracy().unwrap_or(0.0),
        final_train_loss: curve.last().map(|(_, l, _)| *l).unwrap_or(0.0),
        avg_round_ms: tracker.avg_round_ms(),
        comm_bytes: tracker.total_comm_bytes(),
        rounds,
        converged: final_accuracy.is_some(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracking::RoundMetrics;

    #[test]
    fn init_rejects_unknown_algorithm_with_catalog() {
        let mut cfg = Config::default();
        cfg.algorithm = "no-such-algo".into();
        let err = init(cfg).unwrap_err().to_string();
        assert!(err.contains("no-such-algo"), "{err}");
        assert!(err.contains("fedavg"), "{err}");
        assert!(err.contains("fedprox"), "{err}");
    }

    #[test]
    fn builder_is_non_consuming_and_reusable() {
        let mut b = SessionBuilder::new(Config::default());
        b.algorithm("stc").model("mlp");
        assert_eq!(b.config().algorithm, "stc");
        let s1 = b.build().unwrap();
        assert_eq!(s1.config().algorithm, "stc");
        // Second build still resolves (overrides were drained, algorithm
        // parts resolve fresh from the registry).
        let s2 = b.build().unwrap();
        assert_eq!(s2.config().model, "mlp");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_compile_and_chain() {
        let session = init(Config::default())
            .unwrap()
            .register_model("mlp")
            .with_tracker(Arc::new(Tracker::new("shim")));
        assert_eq!(session.config().model, "mlp");
    }

    #[test]
    fn missing_eval_metrics_warn_instead_of_silently_zeroing() {
        let t = Tracker::new("no-eval");
        t.record_round(RoundMetrics {
            round: 0,
            train_loss: 1.0,
            round_ms: 10.0,
            comm_bytes: 100,
            ..RoundMetrics::default()
        });
        let report = report_from_tracker(&t, 1);
        assert!(!report.converged);
        assert_eq!(report.final_accuracy, 0.0);
        assert_eq!(t.warnings().len(), 1);
        assert!(t.warnings()[0].contains("no test accuracy"));

        let t2 = Tracker::new("with-eval");
        t2.record_round(RoundMetrics {
            round: 0,
            test_accuracy: Some(0.5),
            ..RoundMetrics::default()
        });
        let report = report_from_tracker(&t2, 1);
        assert!(report.converged);
        assert_eq!(report.final_accuracy, 0.5);
        assert!(t2.warnings().is_empty());
    }
}
