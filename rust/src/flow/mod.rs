//! Training-flow abstraction (paper §V-B, Fig 3).
//!
//! The FL round is decomposed into granular stages — server: *selection →
//! compression → distribution → decompression → aggregation*; client:
//! *download → decompression → train → compression → encryption → upload*.
//! Each stage is a trait method with a FedAvg default, so a new algorithm
//! overrides exactly the stages it changes (Table VII: ~30% of surveyed
//! papers change one stage, ~57% change two).

pub mod client_stages;
pub mod server_stages;

pub use client_stages::{run_client_round, ClientFlow, DefaultClientFlow, TrainStats, TrainTask};
pub use server_stages::{DefaultServerFlow, ModelPayload, ServerFlow};

use crate::model::ParamVec;

/// Register the default (FedAvg) server flow under its name. Algorithm
/// modules register their own specialized flows alongside.
pub(crate) fn register_builtins(reg: &mut crate::registry::ComponentRegistry) {
    reg.register_server_flow(
        "fedavg",
        std::sync::Arc::new(|_cfg| {
            Ok(Box::new(DefaultServerFlow) as Box<dyn ServerFlow>)
        }),
    );
}

/// A client's upload: the unit the compression/encryption stages shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Update {
    /// Full new parameter vector (FedAvg default).
    Dense(ParamVec),
    /// Sparse ternary delta w.r.t. the distributed global params (STC):
    /// `new = global + sign · magnitude` at `indices`.
    SparseTernary {
        len: usize,
        indices: Vec<u32>,
        /// Sign bit per index (true ⇒ +magnitude).
        signs: Vec<bool>,
        magnitude: f32,
    },
    /// Opaque encrypted payload wrapping another update (encryption
    /// stage demo); the server must de-obfuscate before decompression.
    Masked { xor_key: u64, inner: Box<Update> },
    /// Codec-compressed sparse delta with an integrity content hash
    /// (see [`crate::codec`]): `new = global + delta` at the kept
    /// indices, values possibly quantized.
    Encoded(crate::codec::EncodedUpdate),
}

impl Update {
    /// Bytes this update costs on the wire (communication-cost metric).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Update::Dense(p) => p.len() * 4,
            Update::SparseTernary { indices, signs, .. } => {
                // u32 index + 1 bit sign each, plus magnitude + header.
                indices.len() * 4 + signs.len().div_ceil(8) + 4 + 8
            }
            Update::Masked { inner, .. } => 8 + inner.wire_bytes(),
            // Codec-encoded payloads carry their exact serialized size.
            Update::Encoded(e) => e.encoded_len,
        }
    }

    /// Bytes actually shipped on the uplink — the per-variant size the
    /// simulator charges for upload delay and `comm_bytes` accounting
    /// (alias of [`Update::wire_bytes`], named for the costing call
    /// sites).
    pub fn encoded_len(&self) -> usize {
        self.wire_bytes()
    }

    /// Reconstruct the dense parameter vector this update encodes.
    ///
    /// Masked payloads are an error: decoding one without unmasking
    /// would silently drop the `xor_key` and hand ciphertext semantics
    /// to the aggregator. Plugins with a decryption stage must unwrap
    /// the inner update first (see
    /// [`ServerFlow::decode_update`](server_stages::ServerFlow::decode_update)).
    pub fn to_dense(&self, global: &ParamVec) -> crate::error::Result<ParamVec> {
        use crate::error::Error;
        match self {
            Update::Dense(p) => Ok(p.clone()),
            Update::SparseTernary { len, indices, signs, magnitude } => {
                // Validate like the streaming aggregator does: a
                // malformed (or hostile remote) update must error, not
                // panic the coordinator.
                if *len != global.len() {
                    return Err(Error::Runtime(format!(
                        "sparse update of len {len} != P {}",
                        global.len()
                    )));
                }
                if signs.len() != indices.len() {
                    return Err(Error::Runtime(format!(
                        "sparse update has {} signs for {} indices",
                        signs.len(),
                        indices.len()
                    )));
                }
                let mut out = global.clone();
                for (i, &idx) in indices.iter().enumerate() {
                    let idx = idx as usize;
                    if idx >= out.len() {
                        return Err(Error::Runtime(format!(
                            "sparse index {idx} out of range (P = {})",
                            out.len()
                        )));
                    }
                    let delta = if signs[i] { *magnitude } else { -*magnitude };
                    out[idx] += delta;
                }
                Ok(out)
            }
            Update::Masked { .. } => Err(crate::error::Error::Runtime(
                "masked update cannot be decoded without unmasking; \
                 register a server plugin with a decryption stage"
                    .into(),
            )),
            // Integrity-verified sparse decode (hash mismatch is a
            // typed Error::Integrity, malformed payloads error like the
            // sparse-ternary arms above).
            Update::Encoded(e) => e.to_dense(global),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_wire_bytes_and_roundtrip() {
        let g = ParamVec(vec![1.0; 10]);
        let u = Update::Dense(ParamVec(vec![2.0; 10]));
        assert_eq!(u.wire_bytes(), 40);
        assert_eq!(u.to_dense(&g).unwrap().0, vec![2.0; 10]);
    }

    #[test]
    fn masked_update_refuses_silent_decoding() {
        let g = ParamVec(vec![0.0; 4]);
        let u = Update::Masked {
            xor_key: 0xDEAD_BEEF,
            inner: Box::new(Update::Dense(ParamVec(vec![1.0; 4]))),
        };
        let err = u.to_dense(&g).unwrap_err().to_string();
        assert!(err.contains("unmasking"), "{err}");
    }

    #[test]
    fn malformed_sparse_updates_error_instead_of_panicking() {
        let g = ParamVec(vec![0.0; 4]);
        // Out-of-range index (hostile remote upload).
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![9],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(u.to_dense(&g).unwrap_err().to_string().contains("out of range"));
        // Length contract violation.
        let u = Update::SparseTernary {
            len: 5,
            indices: vec![0],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(u.to_dense(&g).is_err());
        // Sign/index arity mismatch.
        let u = Update::SparseTernary {
            len: 4,
            indices: vec![0, 1],
            signs: vec![true],
            magnitude: 1.0,
        };
        assert!(u.to_dense(&g).is_err());
    }

    #[test]
    fn encoded_len_is_the_per_variant_wire_size() {
        let dense = Update::Dense(ParamVec(vec![0.0; 10]));
        assert_eq!(dense.encoded_len(), 40);
        let sparse = Update::SparseTernary {
            len: 10,
            indices: vec![1, 2],
            signs: vec![true, false],
            magnitude: 0.5,
        };
        assert_eq!(sparse.encoded_len(), sparse.wire_bytes());
        assert!(sparse.encoded_len() < dense.encoded_len());
    }

    #[test]
    fn sparse_ternary_applies_signed_magnitude() {
        let g = ParamVec(vec![0.0; 6]);
        let u = Update::SparseTernary {
            len: 6,
            indices: vec![1, 4],
            signs: vec![true, false],
            magnitude: 0.5,
        };
        let d = u.to_dense(&g).unwrap();
        assert_eq!(d.0, vec![0.0, 0.5, 0.0, 0.0, -0.5, 0.0]);
        assert!(u.wire_bytes() < 40, "sparse must beat dense for k≪P");
    }
}
