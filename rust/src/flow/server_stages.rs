//! Server-side stages: selection → compression → distribution →
//! decode → streaming aggregation (paper Fig 3, top row).
//!
//! Since the aggregation plane landed, the uplink side is streaming: the
//! round loop calls [`ServerFlow::decode_update`] on each arriving
//! update and feeds it straight into the [`Aggregator`] built by
//! [`ServerFlow::make_aggregator`] — no per-client dense
//! materialization. The old batch `decompress`/`aggregate` methods are
//! kept as deprecated shims implemented on top of the new plane.

use std::borrow::Cow;
use std::sync::Arc;

use super::Update;
use crate::aggregate::{AggContext, Aggregator, MeanAggregator};
use crate::error::{Error, Result};
use crate::model::ParamVec;
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// The broadcast the distribution stage ships to each selected client.
#[derive(Clone)]
pub struct ModelPayload {
    pub params: Arc<ParamVec>,
    /// Serialized size on the wire (after the compression stage).
    pub wire_bytes: usize,
    pub round: usize,
}

/// The server half of the training-flow abstraction.
pub trait ServerFlow: Send {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    /// Selection stage: pick the round's cohort.
    fn select(
        &mut self,
        num_clients: usize,
        per_round: usize,
        _round: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        rng.choose_indices(num_clients, per_round.min(num_clients))
    }

    /// Compression stage for the downlink broadcast.
    fn compress_model(&mut self, params: Arc<ParamVec>, round: usize) -> ModelPayload {
        let wire_bytes = params.len() * 4;
        ModelPayload { params, wire_bytes, round }
    }

    /// Decode stage for one uplink update: de-obfuscate/validate it
    /// before it streams into the aggregator. Plugins with an encryption
    /// stage override this to unmask; the default refuses masked
    /// payloads. Returns `Cow::Borrowed` on the (common) pass-through
    /// path so nothing is copied.
    fn decode_update<'u>(&mut self, update: &'u Update) -> Result<Cow<'u, Update>> {
        if matches!(update, Update::Masked { .. }) {
            return Err(Error::Runtime(
                "default server flow cannot handle encrypted updates; \
                 register a server plugin with a decryption stage"
                    .into(),
            ));
        }
        Ok(Cow::Borrowed(update))
    }

    /// Registered aggregator this flow reduces with (see
    /// [`crate::aggregate`]). Algorithms pick theirs by name; the
    /// default is the streaming weighted mean.
    fn aggregator_name(&self) -> &str {
        "mean"
    }

    /// Aggregation stage, streaming: build the round's accumulator. The
    /// default resolves the config's `agg` override when one is carried
    /// in `ctx` ([`AggContext::agg_override`]) — the pure-config path to
    /// a Byzantine-robust reduction — and otherwise the flow's own
    /// [`ServerFlow::aggregator_name`], both through the component
    /// registry. An unknown name is a typed [`Error`] listing every
    /// registered aggregator, never a panic. Flows needing model
    /// metadata (e.g. FedReID's head boundary) override this and enrich
    /// `ctx` from `engine`; such flows pin their reduction and ignore
    /// the config override.
    fn make_aggregator(
        &mut self,
        engine: &Engine,
        model: &str,
        ctx: AggContext,
    ) -> Result<Box<dyn Aggregator>> {
        let _ = (engine, model);
        let name = match &ctx.agg_override {
            Some(name) => name.clone(),
            None => self.aggregator_name().to_string(),
        };
        crate::registry::with_global(|r| r.aggregator(&name, &ctx))
    }

    /// Decompression stage for one uplink update (legacy batch path).
    ///
    /// **The runtime no longer calls this.** `Server::run_round`, remote
    /// ingest and SimNet all stream through [`ServerFlow::decode_update`]
    /// + [`ServerFlow::make_aggregator`]; a flow that overrides only this
    /// method will see its override silently unused — move the logic
    /// (e.g. unmasking) into `decode_update`.
    #[deprecated(
        since = "0.3.0",
        note = "materializes a dense vector per client and is no longer \
                called by the runtime; stream updates through \
                decode_update + make_aggregator instead"
    )]
    fn decompress(&mut self, update: Update, global: &ParamVec) -> Result<ParamVec> {
        self.decode_update(&update)?.to_dense(global)
    }

    /// Aggregation stage over fully materialized contributions (legacy
    /// batch path). `contributions` are (dense params, weight); weights
    /// are normalized so callers can pass raw sample counts. The shim
    /// streams through a [`MeanAggregator`], so it computes exactly the
    /// weighted mean the old kernel call produced.
    ///
    /// **The runtime no longer calls this.** A flow that overrides only
    /// this method (a robust mean, say) will see its override silently
    /// unused — register the reduction with
    /// `registry::register_aggregator` and point
    /// [`ServerFlow::aggregator_name`] / [`ServerFlow::make_aggregator`]
    /// at it instead.
    #[deprecated(
        since = "0.3.0",
        note = "needs O(cohort × P) memory and is no longer called by \
                the runtime; stream updates through make_aggregator \
                instead"
    )]
    fn aggregate(
        &mut self,
        engine: &Engine,
        model: &str,
        contributions: &[(ParamVec, f64)],
    ) -> Result<ParamVec> {
        let _ = (engine, model);
        let Some(((first, _), _)) = contributions.split_first() else {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        };
        let mut agg = MeanAggregator::dense_only(first.len());
        for (p, w) in contributions {
            agg.add_dense(p, *w)?;
        }
        agg.finish()
    }
}

/// FedAvg defaults, stateless.
#[derive(Default)]
pub struct DefaultServerFlow;

impl ServerFlow for DefaultServerFlow {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn selection_is_distinct_and_bounded() {
        let mut f = DefaultServerFlow;
        let mut rng = Rng::new(5);
        let sel = f.select(100, 10, 0, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        // per_round > population clamps.
        assert_eq!(f.select(3, 10, 0, &mut rng).len(), 3);
    }

    #[test]
    fn prop_selection_uniformly_covers_population() {
        prop::check("selection-covers", 41, 10, |rng| {
            let mut f = DefaultServerFlow;
            let mut seen = vec![false; 30];
            for round in 0..200 {
                for c in f.select(30, 5, round, rng) {
                    seen[c] = true;
                }
            }
            crate::prop_assert!(
                seen.iter().all(|&s| s),
                "some client never selected in 200 rounds"
            );
            Ok(())
        });
    }

    #[test]
    fn masked_update_rejected_by_default_decode() {
        let mut f = DefaultServerFlow;
        let u = Update::Masked {
            xor_key: 7,
            inner: Box::new(Update::Dense(ParamVec(vec![1.0; 4]))),
        };
        assert!(f.decode_update(&u).is_err());
        // Non-masked updates pass through without a copy.
        let u = Update::Dense(ParamVec(vec![1.0; 4]));
        assert!(matches!(f.decode_update(&u).unwrap(), Cow::Borrowed(_)));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_batch_shims_ride_the_streaming_plane() {
        let mut f = DefaultServerFlow;
        let g = ParamVec(vec![0.0; 4]);
        // decompress = decode + to_dense.
        let u = Update::Masked {
            xor_key: 7,
            inner: Box::new(Update::Dense(ParamVec(vec![1.0; 4]))),
        };
        assert!(f.decompress(u, &g).is_err());
        let d = f.decompress(Update::Dense(ParamVec(vec![2.0; 4])), &g).unwrap();
        assert_eq!(d.0, vec![2.0; 4]);
        // aggregate = streamed weighted mean.
        let engine = Engine::new(std::path::Path::new("/nonexistent")).unwrap();
        let contributions = vec![
            (ParamVec(vec![1.0, 2.0]), 1.0),
            (ParamVec(vec![3.0, 6.0]), 3.0),
        ];
        let out = f.aggregate(&engine, "mlp", &contributions).unwrap();
        assert!((out[0] - 2.5).abs() < 1e-6);
        assert!((out[1] - 5.0).abs() < 1e-6);
        assert!(f.aggregate(&engine, "mlp", &[]).is_err());
    }

    #[test]
    fn default_flow_builds_the_mean_aggregator_from_the_registry() {
        let mut f = DefaultServerFlow;
        assert_eq!(f.aggregator_name(), "mean");
        let engine = Engine::new(std::path::Path::new("/nonexistent")).unwrap();
        let ctx = AggContext::new(Arc::new(ParamVec::zeros(4)));
        let mut agg = f.make_aggregator(&engine, "mlp", ctx).unwrap();
        assert_eq!(agg.name(), "mean");
        agg.add(&Update::Dense(ParamVec(vec![2.0; 4])), 1.0).unwrap();
        assert_eq!(agg.finish().unwrap().0, vec![2.0; 4]);
    }

    #[test]
    fn config_agg_override_selects_the_registered_reduction() {
        let mut f = DefaultServerFlow;
        let engine = Engine::new(std::path::Path::new("/nonexistent")).unwrap();
        let mut ctx = AggContext::new(Arc::new(ParamVec::zeros(4)));
        ctx.agg_override = Some("median".into());
        let agg = f.make_aggregator(&engine, "mlp", ctx).unwrap();
        assert_eq!(agg.name(), "median");
    }

    #[test]
    fn unknown_aggregator_name_is_a_typed_error_listing_registrations() {
        let mut f = DefaultServerFlow;
        let engine = Engine::new(std::path::Path::new("/nonexistent")).unwrap();
        let mut ctx = AggContext::new(Arc::new(ParamVec::zeros(4)));
        ctx.agg_override = Some("zorp".into());
        let err = f.make_aggregator(&engine, "mlp", ctx).unwrap_err();
        assert!(matches!(err, crate::error::Error::Config(_)), "{err:?}");
        let msg = err.to_string();
        assert!(msg.contains("unknown aggregator"), "{msg}");
        assert!(msg.contains("\"zorp\""), "{msg}");
        for name in ["mean", "backbone", "trimmed_mean", "median", "norm_clip"]
        {
            assert!(msg.contains(name), "{msg} should list {name}");
        }
    }

    #[test]
    fn payload_wire_bytes_is_dense_size() {
        let mut f = DefaultServerFlow;
        let p = Arc::new(ParamVec(vec![0.0; 100]));
        let pl = f.compress_model(p, 3);
        assert_eq!(pl.wire_bytes, 400);
        assert_eq!(pl.round, 3);
    }
}
