//! Server-side stages: selection → compression → distribution →
//! decompression → aggregation (paper Fig 3, top row).

use std::sync::Arc;

use super::Update;
use crate::error::{Error, Result};
use crate::model::ParamVec;
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// The broadcast the distribution stage ships to each selected client.
#[derive(Clone)]
pub struct ModelPayload {
    pub params: Arc<ParamVec>,
    /// Serialized size on the wire (after the compression stage).
    pub wire_bytes: usize,
    pub round: usize,
}

/// The server half of the training-flow abstraction.
pub trait ServerFlow: Send {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    /// Selection stage: pick the round's cohort.
    fn select(
        &mut self,
        num_clients: usize,
        per_round: usize,
        _round: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        rng.choose_indices(num_clients, per_round.min(num_clients))
    }

    /// Compression stage for the downlink broadcast.
    fn compress_model(&mut self, params: Arc<ParamVec>, round: usize) -> ModelPayload {
        let wire_bytes = params.len() * 4;
        ModelPayload { params, wire_bytes, round }
    }

    /// Decompression stage for one uplink update.
    fn decompress(&mut self, update: Update, global: &ParamVec) -> Result<ParamVec> {
        if matches!(update, Update::Masked { .. }) {
            return Err(Error::Runtime(
                "default server flow cannot handle encrypted updates; \
                 register a server plugin with a decryption stage"
                    .into(),
            ));
        }
        Ok(update.to_dense(global))
    }

    /// Aggregation stage: weighted FedAvg via the L1 Pallas kernel.
    ///
    /// `contributions` are (dense params, weight); weights are normalized
    /// here so callers can pass raw sample counts.
    fn aggregate(
        &mut self,
        engine: &Engine,
        model: &str,
        contributions: &[(ParamVec, f64)],
    ) -> Result<ParamVec> {
        if contributions.is_empty() {
            return Err(Error::Runtime("aggregate: empty cohort".into()));
        }
        let total: f64 = contributions.iter().map(|(_, w)| w).sum();
        if total <= 0.0 {
            return Err(Error::Runtime("aggregate: zero total weight".into()));
        }
        let vectors: Vec<&[f32]> =
            contributions.iter().map(|(p, _)| &p.0[..]).collect();
        let weights: Vec<f32> = contributions
            .iter()
            .map(|(_, w)| (w / total) as f32)
            .collect();
        engine.aggregate(model, &vectors, &weights)
    }
}

/// FedAvg defaults, stateless.
#[derive(Default)]
pub struct DefaultServerFlow;

impl ServerFlow for DefaultServerFlow {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn selection_is_distinct_and_bounded() {
        let mut f = DefaultServerFlow;
        let mut rng = Rng::new(5);
        let sel = f.select(100, 10, 0, &mut rng);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        // per_round > population clamps.
        assert_eq!(f.select(3, 10, 0, &mut rng).len(), 3);
    }

    #[test]
    fn prop_selection_uniformly_covers_population() {
        prop::check("selection-covers", 41, 10, |rng| {
            let mut f = DefaultServerFlow;
            let mut seen = vec![false; 30];
            for round in 0..200 {
                for c in f.select(30, 5, round, rng) {
                    seen[c] = true;
                }
            }
            crate::prop_assert!(
                seen.iter().all(|&s| s),
                "some client never selected in 200 rounds"
            );
            Ok(())
        });
    }

    #[test]
    fn masked_update_rejected_by_default_flow() {
        let mut f = DefaultServerFlow;
        let g = ParamVec(vec![0.0; 4]);
        let u = Update::Masked {
            xor_key: 7,
            inner: Box::new(Update::Dense(ParamVec(vec![1.0; 4]))),
        };
        assert!(f.decompress(u, &g).is_err());
    }

    #[test]
    fn payload_wire_bytes_is_dense_size() {
        let mut f = DefaultServerFlow;
        let p = Arc::new(ParamVec(vec![0.0; 100]));
        let pl = f.compress_model(p, 3);
        assert_eq!(pl.wire_bytes, 400);
        assert_eq!(pl.round, 3);
    }
}
