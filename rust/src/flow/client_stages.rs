//! Client-side stages: download → decompression → train → compression →
//! encryption → upload (paper Fig 3, bottom row).

use std::sync::Arc;

use super::server_stages::ModelPayload;
use super::Update;
use crate::data::LocalData;
use crate::error::Result;
use crate::model::ParamVec;
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Everything a client needs for one round of local work.
#[derive(Clone)]
pub struct TrainTask {
    pub client: usize,
    pub round: usize,
    pub model: String,
    pub payload: ModelPayload,
    pub data: Arc<LocalData>,
    pub lr: f32,
    pub local_epochs: usize,
    pub batch_size: usize,
    /// Per-(client, round) RNG seed for batch-order shuffling.
    pub seed: u64,
}

/// Training statistics of the local run (last epoch).
#[derive(Debug, Clone, Default)]
pub struct TrainStats {
    pub sum_loss: f64,
    pub correct: f64,
    pub num_samples: usize,
    pub steps: usize,
}

impl TrainStats {
    pub fn avg_loss(&self) -> f64 {
        if self.num_samples == 0 {
            0.0
        } else {
            self.sum_loss / self.num_samples as f64
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.num_samples == 0 {
            0.0
        } else {
            self.correct / self.num_samples as f64
        }
    }
}

/// The client half of the training-flow abstraction.
///
/// Every method has the FedAvg default; algorithm plugins override the
/// stages they change (FedProx: `train`; STC: `compress`; secure
/// aggregation: `encrypt`).
pub trait ClientFlow: Send {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    /// Decompression stage: payload → local working parameters.
    fn decompress(&mut self, payload: &ModelPayload) -> Result<ParamVec> {
        Ok((*payload.params).clone())
    }

    /// Train stage: E local epochs of minibatch SGD (momentum in-graph).
    fn train(
        &mut self,
        engine: &Engine,
        task: &TrainTask,
        params: ParamVec,
    ) -> Result<(ParamVec, TrainStats)> {
        local_sgd(engine, task, params, |eng, model, p, m, b, lr| {
            let out = eng.train_step(model, p, m, b, lr)?;
            Ok(out)
        })
    }

    /// Compression stage: new params → wire update.
    fn compress(
        &mut self,
        new_params: ParamVec,
        _global: &ParamVec,
    ) -> Result<Update> {
        Ok(Update::Dense(new_params))
    }

    /// Encryption stage (identity by default).
    fn encrypt(&mut self, update: Update) -> Result<Update> {
        Ok(update)
    }
}

/// FedAvg defaults, stateless.
#[derive(Default)]
pub struct DefaultClientFlow;

impl ClientFlow for DefaultClientFlow {}

/// Generic local-SGD loop used by the default and FedProx train stages.
///
/// `step` runs one minibatch update; epochs reshuffle batch order with the
/// task seed so runs are reproducible.
pub fn local_sgd<F>(
    engine: &Engine,
    task: &TrainTask,
    mut params: ParamVec,
    mut step: F,
) -> Result<(ParamVec, TrainStats)>
where
    F: FnMut(
        &Engine,
        &str,
        &ParamVec,
        &ParamVec,
        &crate::runtime::Batch,
        f32,
    ) -> Result<crate::runtime::StepOut>,
{
    let batches = task.data.batches(task.batch_size);
    let mut momentum = ParamVec::zeros(params.len());
    let mut rng = Rng::new(task.seed);
    let mut stats = TrainStats::default();
    for epoch in 0..task.local_epochs {
        let mut order: Vec<usize> = (0..batches.len()).collect();
        rng.shuffle(&mut order);
        if epoch + 1 == task.local_epochs {
            stats = TrainStats::default();
        }
        for &bi in &order {
            let out = step(
                engine,
                &task.model,
                &params,
                &momentum,
                &batches[bi],
                task.lr,
            )?;
            params = out.params;
            momentum = out.momentum;
            stats.sum_loss += out.sum_loss;
            stats.correct += out.correct;
            stats.steps += 1;
        }
    }
    stats.num_samples = task.data.num_samples;
    Ok((params, stats))
}

/// Run the full client round: all stages in paper order.
/// Returns (update, stats).
pub fn run_client_round(
    flow: &mut dyn ClientFlow,
    engine: &Engine,
    task: &TrainTask,
) -> Result<(Update, TrainStats)> {
    // download happens in the transport (local: Arc clone; remote: RPC).
    let params = flow.decompress(&task.payload)?;
    let (new_params, stats) = flow.train(engine, task, params)?;
    let update = flow.compress(new_params, &task.payload.params)?;
    let update = flow.encrypt(update)?;
    // upload happens in the transport.
    Ok((update, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        let s = TrainStats { sum_loss: 10.0, correct: 8.0, num_samples: 16, steps: 4 };
        assert!((s.avg_loss() - 0.625).abs() < 1e-12);
        assert!((s.accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(TrainStats::default().avg_loss(), 0.0);
    }
}
