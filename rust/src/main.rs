//! easyfl — command-line launcher.
//!
//! Subcommands mirror the paper's execution APIs (Table II):
//!   run       standalone / distributed training (`easyfl.run()`)
//!   simulate  discrete-event federation simulation (SimNet, 100k+ clients)
//!   sweep     dataset × partition × algorithm grid on a job platform
//!   jobs      concurrent multi-job demo with live status
//!   server    remote-training coordinator (`easyfl.start_server(args)`)
//!   client    remote client service (`easyfl.start_client(args)`)
//!   registry  service-discovery registry (§VII)
//!   deploy    process-container deployment of a full federation (§VII)
//!   info      artifact/platform inventory + registered components

use std::sync::Arc;
use std::time::Duration;

use easyfl::comm::{ClientService, RemoteCoordinator, Registry};
use easyfl::config::{Allocation, Config, DatasetKind, Partition, SimMode};
use easyfl::deployment::Deployment;
use easyfl::platform::{
    CodecSweep, GossipSweep, HierSweep, Platform, RobustSweep, SimSweep, Sweep,
};
use easyfl::tracking::Tracker;
use easyfl::util::args::{usage, Args, Opt};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(String::as_str) {
        Some("run") => dispatch(cmd_run(&argv[1..])),
        Some("simulate") => dispatch(cmd_simulate(&argv[1..])),
        Some("sweep") => dispatch(cmd_sweep(&argv[1..])),
        Some("jobs") => dispatch(cmd_jobs(&argv[1..])),
        Some("server") => dispatch(cmd_server(&argv[1..])),
        Some("client") => dispatch(cmd_client(&argv[1..])),
        Some("registry") => dispatch(cmd_registry(&argv[1..])),
        Some("deploy") => dispatch(cmd_deploy(&argv[1..])),
        Some("info") => dispatch(cmd_info(&argv[1..])),
        _ => {
            eprintln!(
                "easyfl — low-code federated learning platform\n\n\
                 USAGE: easyfl <run|simulate|sweep|jobs|server|client|registry|deploy|info> [options]\n\
                 Run a subcommand with --help for its options."
            );
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(result: easyfl::Result<()>) -> i32 {
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn common_opts() -> Vec<Opt> {
    vec![
        Opt { name: "dataset", help: "femnist | shakespeare | cifar10", default: Some("femnist"), is_flag: false },
        Opt { name: "partition", help: "iid | realistic | dir(a) | class(n)", default: Some("realistic"), is_flag: false },
        Opt { name: "rounds", help: "training rounds R", default: Some("10"), is_flag: false },
        Opt { name: "clients-per-round", help: "selected clients C", default: Some("10"), is_flag: false },
        Opt { name: "num-clients", help: "federation size (0 = natural)", default: Some("0"), is_flag: false },
        Opt { name: "local-epochs", help: "local epochs E", default: Some("10"), is_flag: false },
        Opt { name: "batch-size", help: "minibatch size B (must match AOT)", default: Some("32"), is_flag: false },
        Opt { name: "lr", help: "learning rate (0 = dataset default)", default: Some("0"), is_flag: false },
        Opt { name: "devices", help: "simulated parallel devices M", default: Some("1"), is_flag: false },
        Opt { name: "allocation", help: "greedyada | random | slowest", default: Some("greedyada"), is_flag: false },
        Opt { name: "unbalanced", help: "simulate unbalanced data", default: None, is_flag: true },
        Opt { name: "system-het", help: "simulate system heterogeneity", default: None, is_flag: true },
        Opt { name: "virtual-clock", help: "no real straggler sleeps", default: None, is_flag: true },
        Opt { name: "time-scale", help: "wait-time compression factor", default: Some("0.05"), is_flag: false },
        Opt { name: "data-amount", help: "fraction of client data used", default: Some("1.0"), is_flag: false },
        Opt { name: "max-samples", help: "per-client sample cap (0 = off)", default: Some("0"), is_flag: false },
        Opt { name: "test-samples", help: "server test split size", default: Some("512"), is_flag: false },
        Opt { name: "eval-every", help: "evaluate every n rounds", default: Some("1"), is_flag: false },
        Opt { name: "seed", help: "base RNG seed", default: Some("42"), is_flag: false },
        Opt { name: "artifacts", help: "AOT artifact directory", default: Some("artifacts"), is_flag: false },
        Opt { name: "algorithm", help: "registered algorithm name (fedavg | fedprox | stc | fedreid | ...)", default: Some("fedavg"), is_flag: false },
        Opt { name: "fedprox-mu", help: "FedProx μ", default: Some("0.01"), is_flag: false },
        Opt { name: "stc-sparsity", help: "STC kept fraction", default: Some("0.01"), is_flag: false },
        Opt { name: "agg", help: "aggregator override (mean | trimmed_mean | median | norm_clip | ...)", default: None, is_flag: false },
        Opt { name: "agg-trim-frac", help: "trimmed_mean: fraction trimmed per end", default: Some("0.1"), is_flag: false },
        Opt { name: "agg-clip-norm", help: "norm_clip: L2 delta threshold (0 = adaptive quantile)", default: Some("10"), is_flag: false },
        Opt { name: "agg-sketch", help: "streaming quantile sketches for trimmed_mean/median (O(P) memory)", default: None, is_flag: true },
        Opt { name: "topology", help: "flat | edges(n) | clusters(file)", default: None, is_flag: false },
        Opt { name: "edge-agg", help: "edge-tier aggregator for hierarchical topologies", default: None, is_flag: false },
        Opt { name: "codec", help: "update codec: identity | top_k(f) | top_k_f16(f) | top_k_i8(f)", default: None, is_flag: false },
        Opt { name: "codec-error-feedback", help: "carry dropped top_k* coordinates into the next round", default: None, is_flag: true },
        Opt { name: "ingest", help: "gather transport: reactor | threads", default: None, is_flag: false },
        Opt { name: "tracking-dir", help: "persist metrics JSON here", default: None, is_flag: false },
        Opt { name: "telemetry", help: "enable span/histogram telemetry (metrics only)", default: None, is_flag: true },
        Opt { name: "trace-sample", help: "keep-fraction for per-item spans in (0, 1]", default: None, is_flag: false },
        Opt { name: "trace-out", help: "write Chrome trace-event JSONL here (implies --telemetry)", default: None, is_flag: false },
        Opt { name: "metrics-out", help: "write counter/histogram snapshot JSON here (implies --telemetry)", default: None, is_flag: false },
        Opt { name: "config", help: "JSON config file (flags override it)", default: None, is_flag: false },
        Opt { name: "help", help: "show help", default: None, is_flag: true },
    ]
}

fn parse_config(a: &Args) -> easyfl::Result<Config> {
    let mut cfg = match a.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    cfg.dataset = DatasetKind::parse(a.get("dataset").unwrap_or("femnist"))?;
    cfg.model = cfg.dataset.default_model().to_string();
    cfg.partition = Partition::parse(a.get("partition").unwrap_or("realistic"))?;
    cfg.rounds = a.get_usize("rounds")?;
    cfg.clients_per_round = a.get_usize("clients-per-round")?;
    cfg.num_clients = a.get_usize("num-clients")?;
    cfg.local_epochs = a.get_usize("local-epochs")?;
    cfg.batch_size = a.get_usize("batch-size")?;
    let lr = a.get_f64("lr")?;
    cfg.lr = if lr > 0.0 {
        lr
    } else if cfg.dataset == DatasetKind::Shakespeare {
        0.8
    } else {
        0.01
    };
    cfg.num_devices = a.get_usize("devices")?;
    cfg.allocation = Allocation::parse(a.get("allocation").unwrap_or("greedyada"))?;
    cfg.unbalanced = a.has_flag("unbalanced");
    cfg.system_heterogeneity = a.has_flag("system-het");
    cfg.virtual_clock = a.has_flag("virtual-clock");
    cfg.time_scale = a.get_f64("time-scale")?;
    cfg.data_amount = a.get_f64("data-amount")?;
    cfg.max_samples = a.get_usize("max-samples")?;
    cfg.test_samples = a.get_usize("test-samples")?;
    cfg.eval_every = a.get_usize("eval-every")?;
    cfg.seed = a.get_usize("seed")? as u64;
    cfg.artifacts_dir = a.get("artifacts").unwrap_or("artifacts").into();
    cfg.algorithm = a.get("algorithm").unwrap_or("fedavg").to_string();
    cfg.fedprox_mu = a.get_f64("fedprox-mu")?;
    cfg.stc_sparsity = a.get_f64("stc-sparsity")?;
    if let Some(agg) = a.get("agg") {
        cfg.agg = Some(agg.to_string());
    }
    cfg.agg_trim_frac = a.get_f64("agg-trim-frac")?;
    cfg.agg_clip_norm = a.get_f64("agg-clip-norm")?;
    // Flags only ever turn the sketch / error-feedback paths on, so a
    // --config file's choice survives an absent flag.
    if a.has_flag("agg-sketch") {
        cfg.agg_sketch = true;
    }
    if a.has_flag("codec-error-feedback") {
        cfg.codec_error_feedback = true;
    }
    if let Some(ingest) = a.get("ingest") {
        cfg.ingest = ingest.to_string();
    }
    // No baked-in defaults for the hierarchy knobs: absent flags must
    // not clobber a topology/edge_agg selected in a --config file.
    if let Some(topology) = a.get("topology") {
        cfg.topology = topology.to_string();
    }
    if let Some(edge_agg) = a.get("edge-agg") {
        cfg.edge_agg = Some(edge_agg.to_string());
    }
    // Same contract for the codec: an absent flag keeps a --config file's
    // choice; an explicit flag wins.
    if let Some(codec) = a.get("codec") {
        cfg.codec = Some(codec.to_string());
    }
    if let Some(dir) = a.get("tracking-dir") {
        cfg.tracking_dir = Some(dir.into());
    }
    // Telemetry: flags only ever turn it on, so a --config file's
    // trace/metrics outputs survive an absent flag.
    if a.has_flag("telemetry") {
        cfg.telemetry = true;
    }
    if let Some(path) = a.get("trace-out") {
        cfg.trace_out = Some(path.into());
    }
    if let Some(path) = a.get("metrics-out") {
        cfg.metrics_out = Some(path.into());
    }
    if let Some(sample) = a.get("trace-sample") {
        cfg.trace_sample = sample.parse().map_err(|_| {
            easyfl::Error::Config(format!("bad --trace-sample {sample:?}"))
        })?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(argv: &[String]) -> easyfl::Result<()> {
    let opts = common_opts();
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("run", "Standalone / distributed FL training.", &opts));
        return Ok(());
    }
    let cfg = parse_config(&a)?;
    // The registry resolves cfg.algorithm into flows — no wiring here.
    let session = easyfl::init(cfg)?;
    let report = session.run_with(|server, _round| {
        let t = server.tracker();
        if let Some((r, loss, acc)) = t.loss_curve().last() {
            println!(
                "round {r:>3}  train-loss {loss:.4}  test-acc {}",
                acc.map(|a| format!("{:.2}%", a * 100.0))
                    .unwrap_or_else(|| "-".into())
            );
        }
    })?;
    println!(
        "\nfinal accuracy {:.2}% | best {:.2}% | avg round {:.0} ms | comm {:.1} MiB",
        report.final_accuracy * 100.0,
        report.best_accuracy * 100.0,
        report.avg_round_ms,
        report.comm_bytes as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "sim-mode", help: "sync | async (FedBuff)", default: Some("sync"), is_flag: false },
        Opt { name: "availability", help: "always-on | diurnal(duty) | flaky(on_ms,off_ms)", default: Some("always-on"), is_flag: false },
        Opt { name: "cost-model", help: "mobile-wan | ideal | datacenter", default: Some("mobile-wan"), is_flag: false },
        Opt { name: "dropout", help: "per-selection dropout probability (flags override --config)", default: Some("0"), is_flag: false },
        Opt { name: "deadline-ms", help: "sync round deadline (virtual ms)", default: Some("60000"), is_flag: false },
        Opt { name: "over-select", help: "sync over-selection factor c ≥ 1", default: Some("1.3"), is_flag: false },
        Opt { name: "async-buffer", help: "async: aggregate every B arrivals (0 = C)", default: Some("0"), is_flag: false },
        Opt { name: "async-concurrency", help: "async: concurrent trainers (0 = 2C)", default: Some("0"), is_flag: false },
        Opt { name: "staleness-alpha", help: "async staleness discount exponent", default: Some("0.5"), is_flag: false },
        Opt { name: "model-bytes", help: "update size in bytes (0 = cost model)", default: Some("0"), is_flag: false },
        Opt { name: "base-compute-ms", help: "fastest-tier round compute (0 = cost model)", default: Some("0"), is_flag: false },
        Opt { name: "sim-sweep", help: "run {sync,async} × {greedyada,random} grid", default: None, is_flag: true },
        Opt { name: "adversary", help: "sign-flip | scaled-noise(factor) | zero-update", default: Some("sign-flip"), is_flag: false },
        Opt { name: "adversary-frac", help: "Byzantine population fraction in [0,1)", default: Some("0"), is_flag: false },
        Opt { name: "robust-sweep", help: "run aggregator × adversary-fraction resilience grid", default: None, is_flag: true },
        Opt { name: "robust-aggs", help: "comma list of aggregators for --robust-sweep", default: Some("mean,trimmed_mean,median,norm_clip"), is_flag: false },
        Opt { name: "adv-fracs", help: "comma list of fractions for --robust-sweep", default: Some("0,0.1,0.3"), is_flag: false },
        Opt { name: "edge-bandwidth", help: "edge→cloud backhaul bytes/ms (0 = cost model)", default: None, is_flag: false },
        Opt { name: "churn", help: "elastic membership: none | grow(n) | shrink(n) | flux(j,l)", default: None, is_flag: false },
        Opt { name: "checkpoint-every", help: "write a round-boundary checkpoint every n rounds (0 = off)", default: None, is_flag: false },
        Opt { name: "checkpoint-dir", help: "directory for round checkpoints", default: None, is_flag: false },
        Opt { name: "resume-from", help: "resume from this checkpoint file", default: None, is_flag: false },
        Opt { name: "chaos", help: "comma list of faults: kill_server_at_round(r) | partition_edge(c) | drop_frames(f) | corrupt_checkpoint", default: None, is_flag: false },
        Opt { name: "hier-sweep", help: "run topology × tier-aggregator fan-in grid", default: None, is_flag: true },
        Opt { name: "topologies", help: "comma list of topologies for --hier-sweep", default: Some("flat,edges(4),edges(16)"), is_flag: false },
        Opt { name: "hier-aggs", help: "comma list of tier aggregators for --hier-sweep", default: Some("mean"), is_flag: false },
        Opt { name: "engine", help: "round engine: server | gossip (needs a peer topology)", default: None, is_flag: false },
        Opt { name: "gossip-k", help: "shortcut: --topology gossip(k) + --engine gossip", default: None, is_flag: false },
        Opt { name: "gossip-rounds", help: "gossip round budget (0 = --rounds)", default: None, is_flag: false },
        Opt { name: "gossip-sweep", help: "run peer-topology × codec grid vs star/edge baselines", default: None, is_flag: true },
        Opt { name: "gossip-topologies", help: "comma list of topologies for --gossip-sweep", default: Some("gossip(4),gossip(8),ring,flat,edges(16)"), is_flag: false },
        Opt { name: "codec-sweep", help: "run codec × fraction transport grid", default: None, is_flag: true },
        Opt { name: "codecs", help: "comma list of codecs for --codec-sweep", default: Some("identity,top_k,top_k_f16,top_k_i8"), is_flag: false },
        Opt { name: "codec-fracs", help: "comma list of kept fractions for --codec-sweep", default: Some("0.05,0.2"), is_flag: false },
        Opt { name: "bench-out", help: "write events/sec benchmark JSON here", default: None, is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!(
            "{}",
            usage(
                "simulate",
                "Discrete-event federation simulation on a virtual clock \
                 (100k+ clients in seconds).",
                &opts
            )
        );
        return Ok(());
    }
    let mut cfg = parse_config(&a)?;
    cfg.sim.mode = SimMode::parse(a.get("sim-mode").unwrap_or("sync"))?;
    cfg.sim.availability = a.get("availability").unwrap_or("always-on").into();
    cfg.sim.cost_model = a.get("cost-model").unwrap_or("mobile-wan").into();
    cfg.sim.dropout = a.get_f64("dropout")?;
    cfg.sim.deadline_ms = a.get_f64("deadline-ms")?;
    cfg.sim.over_select = a.get_f64("over-select")?;
    cfg.sim.async_buffer = a.get_usize("async-buffer")?;
    cfg.sim.async_concurrency = a.get_usize("async-concurrency")?;
    cfg.sim.staleness_alpha = a.get_f64("staleness-alpha")?;
    cfg.sim.model_bytes = a.get_usize("model-bytes")?;
    cfg.sim.base_compute_ms = a.get_f64("base-compute-ms")?;
    cfg.sim.adversary = a.get("adversary").unwrap_or("sign-flip").into();
    cfg.sim.adversary_frac = a.get_f64("adversary-frac")?;
    if a.get("edge-bandwidth").is_some() {
        cfg.sim.edge_bandwidth = a.get_f64("edge-bandwidth")?;
    }
    // Crash-safe knobs: absent flags keep a --config file's choice.
    if let Some(churn) = a.get("churn") {
        cfg.sim.churn = churn.to_string();
    }
    if a.get("checkpoint-every").is_some() {
        cfg.checkpoint_every = a.get_usize("checkpoint-every")?;
    }
    if let Some(dir) = a.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.into());
    }
    if let Some(path) = a.get("resume-from") {
        cfg.resume_from = Some(path.into());
    }
    if let Some(faults) = a.get("chaos") {
        cfg.chaos = faults
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    // Decentralized knobs: absent flags keep a --config file's choice.
    if let Some(engine) = a.get("engine") {
        cfg.sim.engine = engine.to_string();
    }
    if a.get("gossip-k").is_some() {
        let k = a.get_usize("gossip-k")?;
        cfg.topology = format!("gossip({k})");
        cfg.sim.engine = "gossip".into();
    }
    if a.get("gossip-rounds").is_some() {
        cfg.sim.gossip_rounds = a.get_usize("gossip-rounds")?;
    }
    cfg.validate()?;

    if a.has_flag("hier-sweep") {
        let topologies = list_opt(&a, "topologies", "flat,edges(4),edges(16)");
        let topo_refs: Vec<&str> =
            topologies.iter().map(String::as_str).collect();
        let aggs = list_opt(&a, "hier-aggs", "mean");
        let agg_refs: Vec<&str> = aggs.iter().map(String::as_str).collect();
        let platform = Platform::new(4);
        let report = HierSweep::new(cfg)
            .topologies(&topo_refs)
            .aggregators(&agg_refs)
            .run(&platform)?;
        print!("{}", report.to_table());
        return Ok(());
    }

    if a.has_flag("gossip-sweep") {
        let topologies = list_opt(
            &a,
            "gossip-topologies",
            "gossip(4),gossip(8),ring,flat,edges(16)",
        );
        let topo_refs: Vec<&str> =
            topologies.iter().map(String::as_str).collect();
        let mut sweep = GossipSweep::new(cfg).topologies(&topo_refs);
        // An explicit --codecs list grids the wire format too; otherwise
        // the sweep stays on the base config's codec.
        if a.get("codecs").is_some() {
            let codecs = list_opt(&a, "codecs", "identity");
            let codec_refs: Vec<&str> =
                codecs.iter().map(String::as_str).collect();
            sweep = sweep.codecs(&codec_refs);
        }
        let platform = Platform::new(4);
        let report = sweep.run(&platform)?;
        print!("{}", report.to_table());
        return Ok(());
    }

    if a.has_flag("codec-sweep") {
        let codecs = list_opt(&a, "codecs", "identity,top_k,top_k_f16,top_k_i8");
        let codec_refs: Vec<&str> = codecs.iter().map(String::as_str).collect();
        let fracs = list_opt(&a, "codec-fracs", "0.05,0.2")
            .iter()
            .map(|s| {
                s.parse::<f64>().map_err(|_| {
                    easyfl::Error::Config(format!("bad codec fraction {s:?}"))
                })
            })
            .collect::<easyfl::Result<Vec<f64>>>()?;
        let platform = Platform::new(4);
        let report = CodecSweep::new(cfg)
            .codecs(&codec_refs)
            .fractions(&fracs)
            .run(&platform)?;
        print!("{}", report.to_table());
        return Ok(());
    }

    if a.has_flag("robust-sweep") {
        let aggs = list_opt(&a, "robust-aggs", "mean,trimmed_mean,median,norm_clip");
        let agg_refs: Vec<&str> = aggs.iter().map(String::as_str).collect();
        let fracs = list_opt(&a, "adv-fracs", "0,0.1,0.3")
            .iter()
            .map(|s| {
                s.parse::<f64>().map_err(|_| {
                    easyfl::Error::Config(format!("bad adversary fraction {s:?}"))
                })
            })
            .collect::<easyfl::Result<Vec<f64>>>()?;
        let platform = Platform::new(4);
        let report = RobustSweep::new(cfg)
            .aggregators(&agg_refs)
            .fractions(&fracs)
            .run(&platform)?;
        print!("{}", report.to_table());
        return Ok(());
    }

    if a.has_flag("sim-sweep") {
        let platform = Platform::new(4);
        let report = SimSweep::new(cfg)
            .modes(&[SimMode::Sync, SimMode::Async])
            .allocations(&[Allocation::GreedyAda, Allocation::Random])
            .run(&platform)?;
        print!("{}", report.to_table());
        return Ok(());
    }

    let report = easyfl::simnet::simulate(&cfg)?;
    println!(
        "simnet {} | {} clients ({}) | {} rounds",
        report.mode, report.num_clients, report.availability, report.rounds
    );
    println!(
        "  makespan  {:.1} s virtual ({:.0} ms wall, {:.0} events/s)",
        report.makespan_ms / 1000.0,
        report.wall_ms,
        report.events_per_sec()
    );
    println!(
        "  cohorts   selected {} | reported {} | dropped {} | participation {:.1}%",
        report.selected,
        report.reported,
        report.dropped,
        report.participation * 100.0
    );
    println!(
        "  training  final acc {:.2}% | loss {:.3} | avg staleness {:.2} | comm {:.1} MiB",
        report.final_accuracy * 100.0,
        report.final_train_loss,
        report.avg_staleness,
        report.comm_bytes as f64 / (1024.0 * 1024.0)
    );
    if report.mode == "gossip" {
        println!(
            "  gossip    {} | P2P traffic {:.1} MiB | bytes to cloud {} \
             (serverless) | consensus {:.4}",
            report.topology,
            report.comm_bytes as f64 / (1024.0 * 1024.0),
            report.bytes_to_cloud,
            report.consensus_distance
        );
    } else if report.topology != "flat" {
        println!(
            "  hierarchy {} | bytes to cloud {:.1} MiB (uplinks stop at \
             the edge tier)",
            report.topology,
            report.bytes_to_cloud as f64 / (1024.0 * 1024.0)
        );
    }
    if report.adversary_frac > 0.0 {
        println!(
            "  byzantine {} @ {:.0}% | aggregator {} | envelope dev {:.4}",
            report.adversary,
            report.adversary_frac * 100.0,
            report.aggregator,
            report.envelope_deviation
        );
    }
    if report.faults_injected > 0 || report.cancelled {
        println!(
            "  chaos     {} fault(s) injected{}",
            report.faults_injected,
            if report.cancelled { " | run stopped at a boundary" } else { "" }
        );
    }
    println!("  trace digest {:#018x} (same seed ⇒ same digest)", report.trace_digest);

    if let Some(path) = a.get("bench-out") {
        std::fs::write(path, report.bench_json())?;
        println!("  benchmark written to {path}");
    }
    Ok(())
}

fn list_opt(a: &Args, name: &str, default: &str) -> Vec<String> {
    a.get(name)
        .unwrap_or(default)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn cmd_sweep(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "datasets", help: "comma list of datasets to sweep", default: Some("femnist"), is_flag: false },
        Opt { name: "partitions", help: "comma list of partition specs", default: Some("iid"), is_flag: false },
        Opt { name: "algorithms", help: "comma list of algorithm names", default: Some("fedavg,fedprox,stc"), is_flag: false },
        Opt { name: "workers", help: "concurrent platform workers", default: Some("4"), is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!(
            "{}",
            usage(
                "sweep",
                "Grid over datasets × partitions × algorithms on a job platform.",
                &opts
            )
        );
        return Ok(());
    }
    let base = parse_config(&a)?;
    let datasets = list_opt(&a, "datasets", "femnist")
        .iter()
        .map(|s| DatasetKind::parse(s))
        .collect::<easyfl::Result<Vec<_>>>()?;
    let partitions = list_opt(&a, "partitions", "iid")
        .iter()
        .map(|s| easyfl::registry::parse_partition(s))
        .collect::<easyfl::Result<Vec<_>>>()?;
    let algorithms = list_opt(&a, "algorithms", "fedavg,fedprox,stc");
    let algo_refs: Vec<&str> = algorithms.iter().map(String::as_str).collect();

    let platform = Platform::new(a.get_usize("workers")?);
    let sweep = Sweep::new(base)
        .datasets(&datasets)
        .partitions(&partitions)
        .algorithms(&algo_refs);
    let n = sweep.configs().len();
    println!(
        "sweeping {n} configurations on {} workers...\n",
        platform.num_workers()
    );
    let report = sweep.run(&platform)?;
    print!("{}", report.to_table());
    Ok(())
}

fn cmd_jobs(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "algorithms", help: "one concurrent job per algorithm", default: Some("fedavg,fedprox,stc"), is_flag: false },
        Opt { name: "workers", help: "concurrent platform workers", default: Some("2"), is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!(
            "{}",
            usage(
                "jobs",
                "Submit concurrent jobs and watch their status live.",
                &opts
            )
        );
        return Ok(());
    }
    let base = parse_config(&a)?;
    let platform = Platform::new(a.get_usize("workers")?);
    let mut handles = Vec::new();
    for algo in list_opt(&a, "algorithms", "fedavg,fedprox,stc") {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        handles.push(platform.submit(cfg)?);
    }
    loop {
        let mut line = String::new();
        for h in &handles {
            line.push_str(&format!(
                "{}: {:?} {:>3.0}%  ",
                h.label(),
                h.status(),
                h.progress() * 100.0
            ));
        }
        println!("{line}");
        // Park on the first unfinished job's condvar (bounded so the
        // status line still refreshes); an idle platform burns no CPU.
        match handles.iter().find(|h| !h.status().is_terminal()) {
            Some(h) => {
                h.wait_timeout(Duration::from_millis(500));
            }
            None => break,
        }
    }
    for h in handles {
        let label = h.label().to_string();
        match h.join() {
            Ok(rep) => println!(
                "{label}: acc {:.2}% | avg round {:.0} ms | comm {:.1} MiB",
                rep.final_accuracy * 100.0,
                rep.avg_round_ms,
                rep.comm_bytes as f64 / (1024.0 * 1024.0)
            ),
            Err(e) => println!("{label}: failed: {e}"),
        }
    }
    Ok(())
}

fn cmd_registry(argv: &[String]) -> easyfl::Result<()> {
    let opts = vec![
        Opt { name: "port", help: "listen port", default: Some("7400"), is_flag: false },
        Opt { name: "ttl-secs", help: "lease TTL", default: Some("10"), is_flag: false },
        Opt { name: "help", help: "show help", default: None, is_flag: true },
    ];
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("registry", "Service-discovery registry (§VII).", &opts));
        return Ok(());
    }
    let addr = format!("127.0.0.1:{}", a.get_usize("port")?);
    let server =
        Registry::serve(&addr, Duration::from_secs(a.get_usize("ttl-secs")? as u64))?;
    println!("registry listening on {}", server.addr());
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_client(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "port", help: "listen port (0 = ephemeral)", default: Some("0"), is_flag: false },
        Opt { name: "registry", help: "registry address to register with", default: None, is_flag: false },
        Opt { name: "client-index", help: "dataset client index served", default: Some("0"), is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("client", "Remote client service (start_client).", &opts));
        return Ok(());
    }
    let cfg = parse_config(&a)?;
    // The registry resolves --algorithm into the client-side flow.
    let parts = easyfl::registry::with_global(|r| r.algorithm(&cfg))?;
    let index = a.get_usize("client-index")?;
    let bind = format!("127.0.0.1:{}", a.get_usize("port")?);
    let service = ClientService::start(
        &cfg,
        index,
        &bind,
        a.get("registry"),
        parts.client_factory,
    )?;
    println!("client-{index} serving on {}", service.addr());
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_server(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "registry", help: "registry address for discovery", default: Some("127.0.0.1:7400"), is_flag: false },
        Opt { name: "min-clients", help: "wait for at least this many", default: Some("1"), is_flag: false },
        Opt { name: "wait-secs", help: "discovery timeout", default: Some("30"), is_flag: false },
        Opt { name: "metrics-bind", help: "serve the live metrics snapshot at this address", default: None, is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("server", "Remote-training coordinator (start_server).", &opts));
        return Ok(());
    }
    let cfg = parse_config(&a)?;
    // The registry resolves --algorithm into the server-side flow.
    let parts = easyfl::registry::with_global(|r| r.algorithm(&cfg))?;
    let tracker = Arc::new(Tracker::new("remote-task"));
    let mut coord = RemoteCoordinator::new(cfg, parts.server_flow, tracker.clone())?;
    if let Some(bind) = a.get("metrics-bind") {
        let addr = coord.serve_metrics(bind)?;
        println!("metrics endpoint on {addr}");
    }
    let registry = a.get("registry").unwrap().to_string();
    let min_clients = a.get_usize("min-clients")?;
    let deadline = std::time::Instant::now()
        + Duration::from_secs(a.get_usize("wait-secs")? as u64);
    loop {
        let n = coord.discover(&registry)?;
        if n >= min_clients {
            println!("discovered {n} clients");
            break;
        }
        if std::time::Instant::now() > deadline {
            return Err(easyfl::Error::Comm(format!(
                "only {n}/{min_clients} clients discovered before timeout"
            )));
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    coord.run()?;
    println!(
        "remote training done: final acc {:.2}%, avg round {:.0} ms",
        tracker.final_accuracy().unwrap_or(0.0) * 100.0,
        tracker.avg_round_ms()
    );
    Ok(())
}

fn cmd_deploy(argv: &[String]) -> easyfl::Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "clients", help: "client services to deploy", default: Some("4"), is_flag: false },
        Opt { name: "base-port", help: "first port to allocate", default: Some("7500"), is_flag: false },
    ]);
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("deploy", "Deploy a full federation as process containers.", &opts));
        return Ok(());
    }
    let mut cfg = parse_config(&a)?;
    let n = a.get_usize("clients")?;
    if cfg.num_clients == 0 {
        cfg.num_clients = n.max(cfg.clients_per_round);
    }
    cfg.clients_per_round = cfg.clients_per_round.min(n);

    let mut deployment = Deployment::new(a.get_usize("base-port")? as u16);
    let sw = std::time::Instant::now();
    let registry_addr = deployment.deploy_registry()?;
    println!("registry up at {registry_addr} ({:.1?})", sw.elapsed());
    for i in 0..n {
        deployment.deploy_client(&cfg, i, &registry_addr)?;
    }
    deployment.wait_all_ready(Duration::from_secs(30))?;
    println!("{n} clients deployed + ready in {:.1?}", sw.elapsed());

    let tracker = Arc::new(Tracker::new("deploy-task"));
    let parts = easyfl::registry::with_global(|r| r.algorithm(&cfg))?;
    let mut coord = RemoteCoordinator::new(cfg, parts.server_flow, tracker.clone())?;
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while coord.discover(&registry_addr)? < n {
        if std::time::Instant::now() > deadline {
            return Err(easyfl::Error::Deploy("clients never registered".into()));
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    coord.run()?;
    let avg_dist: f64 = {
        let j = tracker.to_json();
        let rounds = j.get("rounds").as_arr().map(|r| r.len()).unwrap_or(0);
        if rounds == 0 {
            0.0
        } else {
            j.get("rounds")
                .as_arr()
                .unwrap()
                .iter()
                .filter_map(|r| r.get("distribution_ms").as_f64())
                .sum::<f64>()
                / rounds as f64
        }
    };
    println!(
        "deployed training done: final acc {:.2}% | avg distribution latency {avg_dist:.1} ms",
        tracker.final_accuracy().unwrap_or(0.0) * 100.0,
    );
    Ok(())
}

fn cmd_info(argv: &[String]) -> easyfl::Result<()> {
    let opts = vec![
        Opt { name: "artifacts", help: "artifact directory", default: Some("artifacts"), is_flag: false },
        Opt { name: "help", help: "show help", default: None, is_flag: true },
    ];
    let a = Args::parse(argv, &opts)?;
    if a.has_flag("help") {
        println!("{}", usage("info", "Show artifact inventory.", &opts));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(a.get("artifacts").unwrap());
    let engine = easyfl::runtime::Engine::new(&dir)?;
    println!("easyfl platform — artifact inventory ({})", dir.display());
    for model in ["mlp", "cnn", "charcnn"] {
        match engine.meta(model) {
            Ok(m) => println!(
                "  {model:<8} P={:<8} B={} K={} classes={} input={:?} ({:?})",
                m.param_count, m.batch, m.agg_k, m.classes, m.input_shape, m.input_dtype
            ),
            Err(e) => println!("  {model:<8} unavailable: {e}"),
        }
    }
    let (algos, datasets, partitions, flows) =
        easyfl::registry::with_global(|r| r.names());
    let (availability, cost_models, adversaries, churn) =
        easyfl::registry::with_global(|r| r.sim_names());
    let aggregators =
        easyfl::registry::with_global(|r| r.aggregator_names());
    let topologies =
        easyfl::registry::with_global(|r| r.topology_names());
    let codecs = easyfl::registry::with_global(|r| r.codec_names());
    let faults = easyfl::registry::with_global(|r| r.fault_names());
    println!("\nregistered components:");
    println!("  algorithms:   {}", algos.join(", "));
    println!("  data sources: {}", datasets.join(", "));
    println!("  partitions:   {}", partitions.join(", "));
    println!("  server flows: {}", flows.join(", "));
    println!("  aggregators:  {}", aggregators.join(", "));
    println!("  topologies:   {}", topologies.join(", "));
    println!("  codecs:       {}", codecs.join(", "));
    println!("  availability: {}", availability.join(", "));
    println!("  cost models:  {}", cost_models.join(", "));
    println!("  adversaries:  {}", adversaries.join(", "));
    println!("  churn models: {}", churn.join(", "));
    println!("  faults:       {}", faults.join(", "));
    Ok(())
}
