//! Federation topology: how clients map onto aggregation tiers.
//!
//! A [`Topology`] describes the shape of the federation's aggregation
//! tree. `flat` is the classic server⇄clients star every prior layer
//! assumed; `edges(n)` interposes `n` edge aggregators between the
//! devices and the cloud (clients are assigned round-robin by id, so the
//! mapping is deterministic and balanced without any state); and
//! `clusters(file)` loads an explicit client→edge map from a JSON array
//! for deployments whose grouping follows real geography.
//!
//! Topologies are registered under spec heads in the component registry
//! (`register_topology`), exactly like partitions and availability
//! models, so a config selects one by string:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.topology = "edges(16)".into();
//! cfg.edge_agg = Some("median".into()); // robust reduce at the edge tier
//! let report = easyfl::simnet::simulate(&cfg).unwrap();
//! # let _ = report;
//! ```

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape of the aggregation tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    /// Single-tier server⇄clients star (the pre-hierarchy default).
    Flat,
    /// Two-tier tree with `n` edge aggregators; client `c` reports to
    /// edge `c % n`.
    Edges { n: usize },
    /// Explicit client→edge map (client `c` uses `map[c % map.len()]`).
    Clusters {
        /// Source path, kept for `name()` round-tripping.
        path: String,
        /// Per-client edge assignment.
        map: Arc<Vec<usize>>,
        /// Number of edges (`max(map) + 1`).
        edges: usize,
    },
    /// Seed-deterministic k-regular peer graph for the serverless
    /// gossip engine (`sim.engine = "gossip"`, see [`crate::gossip`]).
    /// Not an aggregation *tree*: there is no edge tier and no cloud.
    Gossip {
        /// Uniform peer degree (2 ≤ k < population).
        k: usize,
    },
    /// Degree-2 cycle for the ring all-reduce gossip variant.
    Ring,
}

impl Topology {
    /// Parse a topology spec: `"flat"`, `"edges(16)"`, `"clusters(path)"`.
    pub fn parse(spec: &str) -> Result<Topology> {
        let head = crate::registry::spec_head(spec);
        let inner = crate::registry::spec_inner(spec);
        match head.as_str() {
            "flat" | "star" => Ok(Topology::Flat),
            "edges" => {
                let n: usize = inner
                    .unwrap_or("")
                    .parse()
                    .map_err(|_| {
                        Error::Config(format!(
                            "edges(n) needs an edge count, got {spec:?}"
                        ))
                    })?;
                if n == 0 {
                    return Err(Error::Config(
                        "edges(n) needs n ≥ 1 (use \"flat\" for no edge \
                         tier)"
                            .into(),
                    ));
                }
                Ok(Topology::Edges { n })
            }
            "clusters" => {
                let path = inner.filter(|p| !p.is_empty()).ok_or_else(|| {
                    Error::Config(format!(
                        "clusters(file) needs a JSON map path, got {spec:?}"
                    ))
                })?;
                Self::load_clusters(path)
            }
            "gossip" => {
                let k: usize = inner.unwrap_or("").parse().map_err(|_| {
                    Error::Config(format!(
                        "gossip(k) needs a peer degree, got {spec:?}"
                    ))
                })?;
                if k < 2 {
                    return Err(Error::Config(
                        "gossip(k) needs k ≥ 2 (use \"ring\" for the \
                         degree-2 cycle)"
                            .into(),
                    ));
                }
                Ok(Topology::Gossip { k })
            }
            "ring" => {
                if inner.is_some() {
                    return Err(Error::Config(format!(
                        "ring takes no argument (got {spec:?}); use \
                         gossip(k) for higher degrees"
                    )));
                }
                Ok(Topology::Ring)
            }
            other => Err(Error::Config(format!(
                "unknown topology {other:?} (flat | edges(n) | \
                 clusters(file) | gossip(k) | ring)"
            ))),
        }
    }

    /// Load an explicit cluster map: a JSON array of edge ids, one per
    /// client (`[0, 0, 1, 2, 1, ...]`).
    pub fn load_clusters(path: &str) -> Result<Topology> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("clusters({path}): {e}"))
        })?;
        let v = Json::parse(&text)?;
        let arr = v.as_arr().ok_or_else(|| {
            Error::Config(format!(
                "clusters({path}): expected a JSON array of edge ids"
            ))
        })?;
        let mut map = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let id = e.as_usize().ok_or_else(|| {
                Error::Config(format!(
                    "clusters({path}): entry {i} is not an edge id"
                ))
            })?;
            map.push(id);
        }
        if map.is_empty() {
            return Err(Error::Config(format!(
                "clusters({path}): empty cluster map"
            )));
        }
        let edges = map.iter().copied().max().unwrap_or(0) + 1;
        Ok(Topology::Clusters { path: path.to_string(), map: Arc::new(map), edges })
    }

    /// Canonical spec string (parse ∘ name is the identity).
    pub fn name(&self) -> String {
        match self {
            Topology::Flat => "flat".into(),
            Topology::Edges { n } => format!("edges({n})"),
            Topology::Clusters { path, .. } => format!("clusters({path})"),
            Topology::Gossip { k } => format!("gossip({k})"),
            Topology::Ring => "ring".into(),
        }
    }

    /// True for the serverless peer-graph shapes (`gossip(k)` / `ring`),
    /// which require `sim.engine = "gossip"` and never build a
    /// hierarchy plane.
    pub fn is_peer(&self) -> bool {
        matches!(self, Topology::Gossip { .. } | Topology::Ring)
    }

    /// Uniform peer degree for peer-graph shapes (`None` for trees).
    pub fn peer_degree(&self) -> Option<usize> {
        match self {
            Topology::Gossip { k } => Some(*k),
            Topology::Ring => Some(2),
            _ => None,
        }
    }

    /// True for the single-tier star — the hierarchy plane degrades to
    /// the plain streaming aggregator and every pre-hierarchy timeline
    /// stays bit-identical.
    pub fn is_flat(&self) -> bool {
        matches!(self, Topology::Flat)
    }

    /// Number of edge aggregators (1 for flat: the cloud itself).
    pub fn num_edges(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Edges { n } => *n,
            Topology::Clusters { edges, .. } => *edges,
            // Peer shapes have no edge tier; the gossip engine rejects
            // any path that would ask (SimNet validates at construction).
            Topology::Gossip { .. } | Topology::Ring => 1,
        }
    }

    /// Edge a client reports to. Deterministic — cluster assignment is
    /// part of the experiment definition, not of its random state.
    pub fn cluster_of(&self, client: usize) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Edges { n } => client % n,
            Topology::Clusters { map, .. } => map[client % map.len()],
            Topology::Gossip { .. } | Topology::Ring => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_round_trip() {
        assert_eq!(Topology::parse("flat").unwrap(), Topology::Flat);
        assert_eq!(Topology::parse("FLAT").unwrap(), Topology::Flat);
        assert_eq!(
            Topology::parse("edges(16)").unwrap(),
            Topology::Edges { n: 16 }
        );
        assert_eq!(Topology::parse("edges(16)").unwrap().name(), "edges(16)");
        assert!(Topology::parse("edges(0)").is_err());
        assert!(Topology::parse("edges").is_err());
        assert!(Topology::parse("clusters()").is_err());
        assert!(Topology::parse("clusters(/no/such/file.json)").is_err());
        assert_eq!(
            Topology::parse("gossip(8)").unwrap(),
            Topology::Gossip { k: 8 }
        );
        assert_eq!(Topology::parse("gossip(8)").unwrap().name(), "gossip(8)");
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert_eq!(Topology::parse("ring").unwrap().name(), "ring");
        // Ring is degree-2 by definition; degrees are gossip(k)'s axis.
        assert!(Topology::parse("ring(4)").is_err());
        assert!(Topology::parse("gossip").is_err());
        assert!(Topology::parse("gossip(1)").is_err());
    }

    #[test]
    fn peer_shapes_expose_degree_and_never_a_tree() {
        let g = Topology::parse("gossip(6)").unwrap();
        assert!(g.is_peer());
        assert!(!g.is_flat());
        assert_eq!(g.peer_degree(), Some(6));
        let r = Topology::parse("ring").unwrap();
        assert!(r.is_peer());
        assert_eq!(r.peer_degree(), Some(2));
        assert_eq!(Topology::Flat.peer_degree(), None);
        assert!(!Topology::parse("edges(4)").unwrap().is_peer());
    }

    #[test]
    fn edges_assign_round_robin_and_balanced() {
        let t = Topology::parse("edges(4)").unwrap();
        assert_eq!(t.num_edges(), 4);
        assert!(!t.is_flat());
        let mut counts = [0usize; 4];
        for c in 0..100 {
            counts[t.cluster_of(c)] += 1;
        }
        assert_eq!(counts, [25, 25, 25, 25]);
    }

    #[test]
    fn cluster_maps_load_from_json() {
        let dir = std::env::temp_dir();
        let path = dir.join("easyfl_test_clusters.json");
        std::fs::write(&path, "[0, 0, 1, 2, 1]").unwrap();
        let t = Topology::load_clusters(path.to_str().unwrap()).unwrap();
        assert_eq!(t.num_edges(), 3);
        assert_eq!(t.cluster_of(2), 1);
        assert_eq!(t.cluster_of(3), 2);
        // Clients beyond the map wrap around.
        assert_eq!(t.cluster_of(5), 0);
        assert_eq!(t.cluster_of(7), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flat_is_one_trivial_cluster() {
        let t = Topology::Flat;
        assert!(t.is_flat());
        assert_eq!(t.num_edges(), 1);
        assert_eq!(t.cluster_of(12345), 0);
    }
}
