//! The two-tier reduction plane: edge aggregators + cloud reducer.
//!
//! Every consumer (server rounds, remote ingest, SimNet's adversary
//! plane) reduces a round through one [`HierPlane`]:
//!
//! ```text
//!   clients ──add──▶ EdgeAggregator (per cluster, streaming Aggregator)
//!                        │ finish → EdgePartial {params, cohort mass}
//!                        ▼
//!                   CloudReducer (folds partials weighted by mass)
//!                        │ finish → new global parameters
//! ```
//!
//! For a flat topology the plane *is* the round's single aggregator —
//! behavior, errors and bit patterns are exactly the pre-hierarchy path.
//!
//! **Mean/mean exactness.** When every tier reduces with the plain
//! `"mean"`, the plane switches to a raw-moment fast path: each edge
//! keeps the f64 weighted sum `Σ wᵢxᵢ` (the same fused math as
//! [`crate::aggregate::MeanAggregator`], never normalized per edge), and
//! the cloud sums the raw moments and divides once by the global weight.
//! The only difference from the flat reduction is f64 addition grouping,
//! so a single-edge hierarchy is bit-identical to flat and multi-edge
//! trees agree to f64 rounding (≪ 1e-12 relative) before the final f32
//! cast. Robust tiers (`median` at the edge, `trimmed_mean` at the
//! cloud, any registered name) take the generic path: each edge finishes
//! to dense parameters that fold into the cloud aggregator weighted by
//! the edge's cohort mass.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::aggregate::mean::{axpy_into, check_weight};
use crate::aggregate::{AggContext, Aggregator};
use crate::error::{Error, Result};
use crate::flow::{ServerFlow, Update};
use crate::model::ParamVec;
use crate::obs::Telemetry;
use crate::registry;
use crate::runtime::Engine;
use crate::util::clock::Stopwatch;

use super::Topology;

// ------------------------------------------------------- exact partial

/// Un-normalized weighted-sum accumulator: the raw f64 moment a `"mean"`
/// edge ships to the cloud. Mirrors [`crate::aggregate::MeanAggregator`]
/// operation-for-operation (fused axpy for dense adds, index-wise folds
/// for sparse ternary with the `w·global` base applied once at finish),
/// so a single-edge hierarchy reproduces the flat mean bit-for-bit.
struct MeanPartial {
    acc: Vec<f64>,
    sparse_weight: f64,
    weight: f64,
    count: usize,
    global: Arc<ParamVec>,
    /// Chunk-parallel worker count for dense folds (1 = sequential);
    /// the axpy is element-wise, so the count never changes the bits.
    threads: usize,
}

impl MeanPartial {
    fn new(global: Arc<ParamVec>, threads: usize) -> MeanPartial {
        MeanPartial {
            acc: vec![0.0; global.len()],
            sparse_weight: 0.0,
            weight: 0.0,
            count: 0,
            global,
            threads,
        }
    }

    fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        check_weight(weight)?;
        let p = self.acc.len();
        match update {
            Update::Dense(x) => {
                if x.len() != p {
                    return Err(Error::Runtime(format!(
                        "aggregate: vector of len {} != P {p}",
                        x.len()
                    )));
                }
                axpy_into(&mut self.acc, x, weight, self.threads);
            }
            // Delta-encoded updates (sparse ternary / codec-encoded)
            // fold through the shared delta path; Masked errors there
            // with the canonical message.
            _ => {
                crate::aggregate::fold_delta_update(
                    &mut self.acc,
                    p,
                    update,
                    weight,
                    p,
                )?;
                self.sparse_weight += weight;
            }
        }
        self.count += 1;
        self.weight += weight;
        Ok(())
    }

    /// Take the raw moment `Σ wᵢxᵢ` (sparse base folded in, exactly like
    /// the mean's `finish`) and the cohort mass, resetting for reuse.
    fn finish_raw(&mut self) -> (Vec<f64>, f64) {
        let mut s = std::mem::take(&mut self.acc);
        if self.sparse_weight != 0.0 {
            for (v, g) in s.iter_mut().zip(self.global.iter()) {
                *v += self.sparse_weight * *g as f64;
            }
        }
        let w = self.weight;
        self.acc = vec![0.0; self.global.len()];
        self.sparse_weight = 0.0;
        self.weight = 0.0;
        self.count = 0;
        (s, w)
    }
}

// ------------------------------------------------------- edge partial

/// What one edge ships up to the cloud when its window closes.
pub struct EdgePartial {
    /// Cluster id of the producing edge.
    pub cluster: usize,
    /// Clients the edge reduced this window.
    pub clients: usize,
    /// Edge cohort mass: Σ raw client weights — the weight the cloud
    /// fold gives this partial.
    pub weight: f64,
    /// Dense-partial wire size (one P-vector of f32, regardless of how
    /// compressed the device uplinks were) — the bytes-to-cloud unit.
    pub wire_bytes: usize,
    payload: Payload,
}

enum Payload {
    /// Raw f64 moment from the exact mean path (pre-division).
    Raw(Vec<f64>),
    /// Reduced parameters from a generic (robust) edge aggregator.
    Dense(ParamVec),
}

// ---------------------------------------------------- edge aggregator

/// One edge of the hierarchy: consumes its cluster's client outcomes
/// through the streaming [`Aggregator`] machinery and emits an
/// [`EdgePartial`] when the round closes.
pub struct EdgeAggregator {
    cluster: usize,
    inner: EdgeInner,
}

enum EdgeInner {
    Exact(MeanPartial),
    Boxed(Box<dyn Aggregator>),
}

impl EdgeAggregator {
    /// Exact mean edge (raw-moment fast path).
    fn exact(cluster: usize, global: Arc<ParamVec>, threads: usize) -> EdgeAggregator {
        EdgeAggregator {
            cluster,
            inner: EdgeInner::Exact(MeanPartial::new(global, threads)),
        }
    }

    /// Generic edge around any registered aggregator.
    fn boxed(cluster: usize, agg: Box<dyn Aggregator>) -> EdgeAggregator {
        EdgeAggregator { cluster, inner: EdgeInner::Boxed(agg) }
    }

    pub fn cluster(&self) -> usize {
        self.cluster
    }

    /// Updates folded in since the last finish.
    pub fn count(&self) -> usize {
        match &self.inner {
            EdgeInner::Exact(m) => m.count,
            EdgeInner::Boxed(a) => a.count(),
        }
    }

    /// Fold one client update in with its raw weight.
    pub fn add(&mut self, update: &Update, weight: f64) -> Result<()> {
        match &mut self.inner {
            EdgeInner::Exact(m) => m.add(update, weight),
            EdgeInner::Boxed(a) => a.add(update, weight),
        }
    }

    /// Close the edge's window into a partial for the cloud fold.
    pub fn finish(&mut self) -> Result<EdgePartial> {
        let cluster = self.cluster;
        match &mut self.inner {
            EdgeInner::Exact(m) => {
                let clients = m.count;
                let wire_bytes = m.global.len() * 4;
                let (raw, weight) = m.finish_raw();
                Ok(EdgePartial {
                    cluster,
                    clients,
                    weight,
                    wire_bytes,
                    payload: Payload::Raw(raw),
                })
            }
            EdgeInner::Boxed(a) => {
                let clients = a.count();
                let weight = a.total_weight();
                let params = a.finish()?;
                Ok(EdgePartial {
                    cluster,
                    clients,
                    weight,
                    wire_bytes: params.len() * 4,
                    payload: Payload::Dense(params),
                })
            }
        }
    }
}

// ------------------------------------------------------ cloud reducer

/// The top of the tree: folds [`EdgePartial`]s weighted by edge cohort
/// mass into the round's new global parameters.
pub struct CloudReducer {
    inner: CloudInner,
}

enum CloudInner {
    /// Exact path: sum of raw edge moments, one division at the end.
    Exact { acc: Vec<f64>, weight: f64, folded: usize },
    /// Generic path: any registered aggregator over dense partials.
    Boxed(Box<dyn Aggregator>),
}

impl CloudReducer {
    fn exact(p: usize) -> CloudReducer {
        CloudReducer {
            inner: CloudInner::Exact { acc: vec![0.0; p], weight: 0.0, folded: 0 },
        }
    }

    fn boxed(agg: Box<dyn Aggregator>) -> CloudReducer {
        CloudReducer { inner: CloudInner::Boxed(agg) }
    }

    /// Fold one edge partial in, weighted by its cohort mass.
    pub fn fold(&mut self, partial: EdgePartial) -> Result<()> {
        match (&mut self.inner, partial.payload) {
            (CloudInner::Exact { acc, weight, folded }, Payload::Raw(s)) => {
                if s.len() != acc.len() {
                    return Err(Error::Runtime(format!(
                        "hierarchy: edge partial of len {} != P {}",
                        s.len(),
                        acc.len()
                    )));
                }
                for (a, v) in acc.iter_mut().zip(s.iter()) {
                    *a += v;
                }
                *weight += partial.weight;
                *folded += 1;
                Ok(())
            }
            (CloudInner::Boxed(agg), Payload::Dense(p)) => {
                agg.add(&Update::Dense(p), partial.weight)
            }
            _ => Err(Error::Runtime(
                "hierarchy: mixed exact/generic edge partials in one cloud \
                 fold"
                    .into(),
            )),
        }
    }

    /// Complete the reduction: the round's new global parameters.
    pub fn finish(&mut self) -> Result<ParamVec> {
        match &mut self.inner {
            CloudInner::Exact { acc, weight, folded } => {
                if *folded == 0 {
                    return Err(Error::Runtime("aggregate: empty cohort".into()));
                }
                if *weight <= 0.0 {
                    return Err(Error::Runtime(
                        "aggregate: zero total weight".into(),
                    ));
                }
                let w = *weight;
                let out: Vec<f32> =
                    acc.iter().map(|v| (*v / w) as f32).collect();
                acc.iter_mut().for_each(|v| *v = 0.0);
                *weight = 0.0;
                *folded = 0;
                Ok(ParamVec(out))
            }
            CloudInner::Boxed(agg) => agg.finish(),
        }
    }
}

// -------------------------------------------------------- hier plane

/// Per-round fan-in numbers the callers surface (bytes-to-cloud is the
/// headline the `hier_scale` benchmark and [`crate::platform::HierSweep`]
/// report).
#[derive(Debug, Clone, Copy, Default)]
pub struct HierStats {
    /// False for a flat plane (single tier, pre-hierarchy behavior).
    pub tiered: bool,
    /// Edges that actually reduced ≥ 1 client this round.
    pub active_edges: usize,
    /// Bytes crossing the edge→cloud backhaul: one dense partial per
    /// active edge. 0 for flat planes, whose device uplinks terminate at
    /// the cloud directly (the caller's uplink sum is the fan-in there).
    pub bytes_to_cloud: usize,
}

/// The round's whole aggregation tree behind one streaming interface:
/// `add` routes a client update to its cluster's edge, `finish` closes
/// every edge and folds the partials at the cloud.
pub struct HierPlane {
    mode: PlaneMode,
    /// Probe handle inherited from the construction context: per-edge
    /// reduces and the cloud fold emit spans + latency histograms
    /// through it. Off (one branch per probe) unless the owner attached
    /// a live handle via [`AggContext::telemetry`].
    tel: Telemetry,
}

enum PlaneMode {
    Flat(Box<dyn Aggregator>),
    Tiered {
        topology: Topology,
        edges: BTreeMap<usize, EdgeAggregator>,
        cloud: CloudReducer,
    },
}

impl HierPlane {
    /// Build the plane through a [`ServerFlow`]'s `make_aggregator`
    /// (server rounds, remote ingest) — flow-pinned reductions like
    /// FedReID's backbone apply at every tier. `cohort` is the round's
    /// selected clients; only their clusters get edge aggregators.
    pub fn from_flow(
        flow: &mut dyn ServerFlow,
        engine: &Engine,
        model: &str,
        topology: &Topology,
        ctx: AggContext,
        cohort: &[usize],
    ) -> Result<HierPlane> {
        if topology.is_flat() {
            let tel = ctx.tel.clone();
            let agg = flow.make_aggregator(engine, model, ctx)?;
            return Ok(HierPlane { mode: PlaneMode::Flat(agg), tel });
        }
        Self::tiered(topology, ctx, cohort, &mut |c| {
            flow.make_aggregator(engine, model, c)
        })
    }

    /// Build the plane straight from the component registry (SimNet's
    /// adversary plane, tests): tier names resolve like the default
    /// flow's `make_aggregator` — `ctx.edge_agg` (falling back to
    /// `ctx.agg_override`, then `"mean"`) at the edges, `ctx.agg_override`
    /// (then `"mean"`) at the cloud.
    pub fn from_registry(
        topology: &Topology,
        ctx: AggContext,
        cohort: &[usize],
    ) -> Result<HierPlane> {
        let mut build = |c: AggContext| -> Result<Box<dyn Aggregator>> {
            let name =
                c.agg_override.clone().unwrap_or_else(|| "mean".to_string());
            registry::with_global(|r| r.aggregator(&name, &c))
        };
        if topology.is_flat() {
            let tel = ctx.tel.clone();
            let agg = build(ctx)?;
            return Ok(HierPlane { mode: PlaneMode::Flat(agg), tel });
        }
        Self::tiered(topology, ctx, cohort, &mut build)
    }

    fn tiered(
        topology: &Topology,
        ctx: AggContext,
        cohort: &[usize],
        build: &mut dyn FnMut(AggContext) -> Result<Box<dyn Aggregator>>,
    ) -> Result<HierPlane> {
        let clusters: BTreeSet<usize> =
            cohort.iter().map(|&c| topology.cluster_of(c)).collect();
        if clusters.is_empty() {
            return Err(Error::Runtime("hierarchy: empty cohort".into()));
        }
        let mut edge_ctx = ctx.clone();
        edge_ctx.agg_override =
            ctx.edge_agg.clone().or_else(|| ctx.agg_override.clone());
        edge_ctx.expect_updates =
            ctx.expect_updates.div_ceil(clusters.len());
        let mut cloud_ctx = ctx.clone();
        cloud_ctx.expect_updates = clusters.len();

        // Probe one edge + the cloud: if both tiers reduce with the plain
        // mean (and no slice masking is in play), switch to the exact
        // raw-moment path; otherwise keep the probes and build the rest.
        let mut probe_edge = Some(build(edge_ctx.clone())?);
        let probe_cloud = build(cloud_ctx)?;
        let exact = probe_edge.as_ref().map(|a| a.name()) == Some("mean")
            && probe_cloud.name() == "mean"
            && ctx.protected_tail == 0;

        let mut edges = BTreeMap::new();
        let cloud = if exact {
            // Same chunk-parallel gate the flat MeanAggregator honors,
            // judged on the per-edge expected cohort.
            let threads = if edge_ctx.use_parallel(ctx.global.len()) {
                edge_ctx.effective_threads()
            } else {
                1
            };
            for &c in &clusters {
                edges.insert(
                    c,
                    EdgeAggregator::exact(c, ctx.global.clone(), threads),
                );
            }
            CloudReducer::exact(ctx.global.len())
        } else {
            for &c in &clusters {
                let agg = match probe_edge.take() {
                    Some(agg) => agg,
                    None => build(edge_ctx.clone())?,
                };
                edges.insert(c, EdgeAggregator::boxed(c, agg));
            }
            CloudReducer::boxed(probe_cloud)
        };
        Ok(HierPlane {
            mode: PlaneMode::Tiered { topology: topology.clone(), edges, cloud },
            tel: ctx.tel.clone(),
        })
    }

    /// True when an edge tier sits between the clients and the cloud.
    pub fn is_tiered(&self) -> bool {
        matches!(self.mode, PlaneMode::Tiered { .. })
    }

    /// Edge aggregators built for this round (0 for flat planes).
    pub fn num_edges(&self) -> usize {
        match &self.mode {
            PlaneMode::Flat(_) => 0,
            PlaneMode::Tiered { edges, .. } => edges.len(),
        }
    }

    /// Route one client's decoded update to its tier.
    pub fn add(&mut self, client: usize, update: &Update, weight: f64) -> Result<()> {
        match &mut self.mode {
            PlaneMode::Flat(agg) => agg.add(update, weight),
            PlaneMode::Tiered { topology, edges, .. } => {
                let cluster = topology.cluster_of(client);
                let edge = edges.get_mut(&cluster).ok_or_else(|| {
                    Error::Runtime(format!(
                        "hierarchy: client {client} (edge {cluster}) was not \
                         in the round's cohort"
                    ))
                })?;
                edge.add(update, weight)
            }
        }
    }

    /// Close every edge, fold the partials at the cloud, and return the
    /// new global parameters with the round's fan-in stats.
    pub fn finish(&mut self) -> Result<(ParamVec, HierStats)> {
        match &mut self.mode {
            PlaneMode::Flat(agg) => {
                Ok((agg.finish()?, HierStats::default()))
            }
            PlaneMode::Tiered { edges, cloud, .. } => {
                let mut stats = HierStats { tiered: true, ..HierStats::default() };
                for edge in edges.values_mut() {
                    if edge.count() == 0 {
                        continue;
                    }
                    let cluster = edge.cluster();
                    let clients = edge.count();
                    let span = self.tel.span_with("hier.edge_reduce", || {
                        vec![
                            ("edge", cluster.to_string()),
                            ("clients", clients.to_string()),
                        ]
                    });
                    let sw = Stopwatch::start();
                    let partial = edge.finish()?;
                    stats.active_edges += 1;
                    stats.bytes_to_cloud += partial.wire_bytes;
                    cloud.fold(partial)?;
                    self.tel.observe_ms("hier.edge_reduce_ms", sw.elapsed_ms());
                    drop(span);
                }
                if stats.active_edges == 0 {
                    return Err(Error::Runtime("aggregate: empty cohort".into()));
                }
                let _span = self.tel.span("hier.cloud_finish");
                let sw = Stopwatch::start();
                let out = cloud.finish()?;
                self.tel.observe_ms("hier.cloud_finish_ms", sw.elapsed_ms());
                self.tel.counter(
                    "hier.bytes_to_cloud",
                    stats.bytes_to_cloud as u64,
                );
                Ok((out, stats))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::MeanAggregator;
    use crate::util::rng::Rng;

    fn dense(v: Vec<f32>) -> Update {
        Update::Dense(ParamVec(v))
    }

    fn ctx_for(global: Arc<ParamVec>, expect: usize) -> AggContext {
        AggContext::new(global).expect_updates(expect)
    }

    /// Random cohort of dense updates + integer weights.
    fn cohort(rng: &mut Rng, k: usize, p: usize) -> Vec<(usize, Update, f64)> {
        (0..k)
            .map(|c| {
                let v: Vec<f32> =
                    (0..p).map(|_| (rng.uniform() as f32) * 2.0 - 1.0).collect();
                (c, dense(v), 1.0 + rng.below(50) as f64)
            })
            .collect()
    }

    #[test]
    fn flat_plane_is_the_plain_aggregator() {
        let global = Arc::new(ParamVec::zeros(4));
        let mut plane = HierPlane::from_registry(
            &Topology::Flat,
            ctx_for(global.clone(), 2),
            &[0, 1],
        )
        .unwrap();
        assert!(!plane.is_tiered());
        plane.add(0, &dense(vec![2.0; 4]), 1.0).unwrap();
        plane.add(1, &dense(vec![4.0; 4]), 1.0).unwrap();
        let (out, stats) = plane.finish().unwrap();
        assert_eq!(out.0, vec![3.0; 4]);
        assert!(!stats.tiered);
        assert_eq!(stats.bytes_to_cloud, 0);
    }

    #[test]
    fn single_edge_hierarchy_is_bit_identical_to_flat_mean() {
        let p = 64;
        let mut rng = Rng::new(11);
        let global = Arc::new(ParamVec::zeros(p));
        let updates = cohort(&mut rng, 12, p);

        let mut flat = MeanAggregator::from_ctx(&ctx_for(global.clone(), 12));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 1 },
            ctx_for(global.clone(), 12),
            &updates.iter().map(|(c, _, _)| *c).collect::<Vec<_>>(),
        )
        .unwrap();
        assert!(plane.is_tiered());
        for (c, u, w) in &updates {
            flat.add(u, *w).unwrap();
            plane.add(*c, u, *w).unwrap();
        }
        let want = flat.finish().unwrap();
        let (got, stats) = plane.finish().unwrap();
        assert_eq!(stats.active_edges, 1);
        assert_eq!(stats.bytes_to_cloud, p * 4);
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.to_bits(), w.to_bits(), "{g} vs {w}");
        }
    }

    #[test]
    fn multi_edge_mean_matches_flat_mean() {
        let p = 128;
        let mut rng = Rng::new(23);
        let global = Arc::new(ParamVec::zeros(p));
        let updates = cohort(&mut rng, 30, p);
        let clients: Vec<usize> = updates.iter().map(|(c, _, _)| *c).collect();

        let mut flat = MeanAggregator::from_ctx(&ctx_for(global.clone(), 30));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 5 },
            ctx_for(global.clone(), 30),
            &clients,
        )
        .unwrap();
        for (c, u, w) in &updates {
            flat.add(u, *w).unwrap();
            plane.add(*c, u, *w).unwrap();
        }
        let want = flat.finish().unwrap();
        let (got, stats) = plane.finish().unwrap();
        assert_eq!(stats.active_edges, 5);
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                ((g - w) as f64).abs() < 1e-6,
                "coordinate {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn robust_edges_take_the_generic_path() {
        let global = Arc::new(ParamVec::zeros(2));
        let mut ctx = ctx_for(global, 6);
        ctx.edge_agg = Some("median".into());
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 2 },
            ctx,
            &[0, 1, 2, 3, 4, 5],
        )
        .unwrap();
        // Edge 0 (clients 0,2,4): one hostile outlier — the median holds.
        plane.add(0, &dense(vec![1.0, 1.0]), 1.0).unwrap();
        plane.add(2, &dense(vec![1e9, -1e9]), 1.0).unwrap();
        plane.add(4, &dense(vec![1.0, 1.0]), 1.0).unwrap();
        // Edge 1 (clients 1,3,5): clean.
        plane.add(1, &dense(vec![3.0, 3.0]), 1.0).unwrap();
        plane.add(3, &dense(vec![3.0, 3.0]), 1.0).unwrap();
        plane.add(5, &dense(vec![3.0, 3.0]), 1.0).unwrap();
        let (out, stats) = plane.finish().unwrap();
        assert_eq!(stats.active_edges, 2);
        // Cloud mean of the two edge medians (equal masses): (1+3)/2.
        for v in out.iter() {
            assert!((v - 2.0).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn clients_outside_the_cohort_are_rejected() {
        let global = Arc::new(ParamVec::zeros(2));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 8 },
            ctx_for(global, 2),
            &[0, 1],
        )
        .unwrap();
        // Client 2 maps to edge 2, which was never built.
        let err = plane
            .add(2, &dense(vec![1.0, 1.0]), 1.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cohort"), "{err}");
    }

    #[test]
    fn empty_plane_finish_is_an_error() {
        let global = Arc::new(ParamVec::zeros(2));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 2 },
            ctx_for(global, 4),
            &[0, 1, 2, 3],
        )
        .unwrap();
        let err = plane.finish().unwrap_err().to_string();
        assert!(err.contains("empty cohort"), "{err}");
    }

    #[test]
    fn sparse_updates_fold_through_the_exact_path() {
        let global = Arc::new(ParamVec(vec![1.0; 4]));
        let sparse = Update::SparseTernary {
            len: 4,
            indices: vec![0, 2],
            signs: vec![true, false],
            magnitude: 0.5,
        };
        let mut flat = MeanAggregator::from_ctx(&ctx_for(global.clone(), 2));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 2 },
            ctx_for(global.clone(), 2),
            &[0, 1],
        )
        .unwrap();
        for (c, u, w) in
            [(0usize, sparse.clone(), 2.0), (1usize, dense(vec![2.0; 4]), 1.0)]
        {
            flat.add(&u, w).unwrap();
            plane.add(c, &u, w).unwrap();
        }
        let want = flat.finish().unwrap();
        let (got, _) = plane.finish().unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            assert!(((g - w) as f64).abs() < 1e-7, "{g} vs {w}");
        }
    }

    #[test]
    fn encoded_updates_fold_through_the_exact_path() {
        // A codec-compressed upload reduces identically through the
        // tiered plane and the flat mean — the shared delta fold is the
        // single implementation both sides call.
        let global = Arc::new(ParamVec(vec![1.0; 8]));
        let codec = crate::codec::parse("top_k(0.5)").unwrap();
        let new = ParamVec(vec![1.5, 1.0, 0.25, 1.0, 1.0, 3.0, 1.0, 0.0]);
        let encoded = codec.encode(new, &global).unwrap();
        let mut flat = MeanAggregator::from_ctx(&ctx_for(global.clone(), 2));
        let mut plane = HierPlane::from_registry(
            &Topology::Edges { n: 2 },
            ctx_for(global.clone(), 2),
            &[0, 1],
        )
        .unwrap();
        for (c, u, w) in
            [(0usize, encoded, 2.0), (1usize, dense(vec![2.0; 8]), 1.0)]
        {
            flat.add(&u, w).unwrap();
            plane.add(c, &u, w).unwrap();
        }
        let want = flat.finish().unwrap();
        let (got, _) = plane.finish().unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            assert!(((g - w) as f64).abs() < 1e-7, "{g} vs {w}");
        }
    }
}
