//! Hierarchical client→edge→cloud aggregation (the multi-tier plane).
//!
//! Every pillar below this module — heterogeneity simulation,
//! distributed-training optimization, deployment — assumed a flat
//! server⇄clients star. Real edge federations are multi-tier: devices
//! report to a nearby edge aggregator, edges report to the cloud. This
//! module makes the tree shape a pluggable, config-selected component
//! like everything else:
//!
//! * [`Topology`] — `flat` / `edges(n)` / `clusters(file)` specs behind
//!   the registry's `register_topology` hook, selected by
//!   `Config.topology` (plus the serverless peer shapes `gossip(k)` /
//!   `ring`, which skip the tree entirely and select the
//!   [`crate::gossip`] engine);
//! * [`EdgeAggregator`] — consumes one cluster's client outcomes through
//!   the streaming [`crate::aggregate::Aggregator`] trait, so robust
//!   reductions apply *per tier* (`Config.edge_agg` picks the edge
//!   reduction, `Config.agg` the cloud one — `median` at the edges with
//!   `trimmed_mean` at the cloud is pure config);
//! * [`CloudReducer`] — folds edge partials weighted by edge cohort
//!   mass; with `mean` at every tier the tree reduction is equivalent to
//!   the flat mean (bit-identical for a single edge, f64-rounding-close
//!   otherwise — property-tested);
//! * [`HierPlane`] — the per-round composition the server rounds, remote
//!   ingest and SimNet's adversary plane all reduce through.
//!
//! The payoff is fan-in: a 10k-client cohort behind `edges(16)` ships 16
//! dense partials to the cloud instead of a full cohort of uplinks —
//! `examples/hier_scale.rs` measures ≥ 5x fewer bytes-to-cloud, and
//! [`crate::platform::HierSweep`] grids topology × aggregator with
//! accuracy / makespan / bytes-to-cloud columns. Three lines:
//!
//! ```no_run
//! let mut cfg = easyfl::Config::default();
//! cfg.topology = "edges(16)".into();
//! let report = easyfl::simnet::simulate(&cfg).unwrap();
//! # let _ = report;
//! ```

pub mod plane;
pub mod topology;

pub use plane::{CloudReducer, EdgeAggregator, EdgePartial, HierPlane, HierStats};
pub use topology::Topology;

use std::sync::Arc;

use crate::registry::ComponentRegistry;

/// Install the built-in topologies (called by
/// [`ComponentRegistry::with_builtins`]).
pub(crate) fn register_builtins(reg: &mut ComponentRegistry) {
    for name in ["flat", "edges", "clusters", "gossip", "ring"] {
        reg.register_topology(name, Arc::new(Topology::parse));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_topologies_resolve_through_the_registry() {
        let reg = ComponentRegistry::with_builtins();
        assert_eq!(reg.topology("flat").unwrap(), Topology::Flat);
        assert_eq!(
            reg.topology("edges(8)").unwrap(),
            Topology::Edges { n: 8 }
        );
        let err = reg.topology("torus(3)").unwrap_err().to_string();
        assert!(err.contains("torus"), "{err}");
        assert!(err.contains("edges"), "{err}");
        assert_eq!(
            reg.topology("gossip(8)").unwrap(),
            Topology::Gossip { k: 8 }
        );
        assert_eq!(reg.topology("ring").unwrap(), Topology::Ring);
        let names = reg.topology_names();
        for t in ["flat", "edges", "clusters", "gossip", "ring"] {
            assert!(names.iter().any(|n| n == t), "missing topology {t}");
        }
    }

    #[test]
    fn custom_topologies_register_and_resolve() {
        let mut reg = ComponentRegistry::with_builtins();
        reg.register_topology(
            "paired",
            Arc::new(|_| Ok(Topology::Edges { n: 2 })),
        );
        assert_eq!(
            reg.topology("paired").unwrap(),
            Topology::Edges { n: 2 }
        );
    }
}
