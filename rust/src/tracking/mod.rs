//! Tracking manager (paper §V-C): the three-level metric hierarchy.
//!
//! A training **task** contains **rounds**; a round contains per-**client**
//! metrics — the exact structure the paper contrasts with flat log files.
//! The store is thread-safe, persists to JSON, and exposes the query
//! helpers the evaluation section uses (round time, accuracy, comm cost).

pub mod store;

pub use store::{ClientMetrics, RoundMetrics, TaskMetrics, Tracker};
