//! The hierarchical metric store.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::obs::Telemetry;
use crate::util::json::{obj, Json};

/// Client-level metrics for one round (paper: "client metrics of a round").
#[derive(Debug, Clone, Default)]
pub struct ClientMetrics {
    pub client: usize,
    pub num_samples: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    /// Real compute time (HLO execution) in ms.
    pub compute_ms: f64,
    /// Simulated straggler wait in ms.
    pub wait_ms: f64,
    /// Total (compute + wait) — what the scheduler profiles.
    pub round_ms: f64,
    /// Bytes uploaded to the server (after compression).
    pub upload_bytes: usize,
    /// Simulated device class name.
    pub device: String,
}

/// Round-level metrics (paper: "round metrics of a task").
#[derive(Debug, Clone, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub test_loss: Option<f64>,
    pub test_accuracy: Option<f64>,
    /// End-to-end round time (simulated clock).
    pub round_ms: f64,
    /// Server→client distribution latency.
    pub distribution_ms: f64,
    pub comm_bytes: usize,
    /// Bytes that crossed into the cloud aggregator this round: every
    /// client uplink for a flat topology, one dense partial per active
    /// edge for a hierarchical one (see [`crate::hierarchy`]).
    pub bytes_to_cloud: usize,
    pub clients: Vec<ClientMetrics>,
    /// Selections accounted to this round: the sync cohort size (incl.
    /// over-selection), or the selections resolved in an async window —
    /// always ≥ `reported`.
    pub selected: usize,
    /// Clients whose updates were aggregated.
    pub reported: usize,
    /// Clients that dropped out or missed the deadline.
    pub dropped: usize,
    /// Mean staleness of aggregated updates (async engines; 0 for sync).
    pub avg_staleness: f64,
    /// Median per-client round time this round (ms). Averages hide the
    /// straggler tail the deadline actually fights; the quantile triple
    /// shows it. 0 when no per-client times were measured.
    pub client_ms_p50: f64,
    /// 95th-percentile per-client round time (ms).
    pub client_ms_p95: f64,
    /// 99th-percentile per-client round time (ms).
    pub client_ms_p99: f64,
}

/// Task-level metrics (paper: "metrics of the whole training").
#[derive(Debug, Clone, Default)]
pub struct TaskMetrics {
    pub task_id: String,
    /// Free-form configuration summary stored with the task.
    pub config: BTreeMap<String, String>,
    pub rounds: Vec<RoundMetrics>,
    /// Non-fatal anomalies surfaced during the run (missing metrics,
    /// degraded behavior) — kept with the task instead of being lost.
    pub warnings: Vec<String>,
}

/// Thread-safe tracker with optional JSON persistence.
pub struct Tracker {
    task: Mutex<TaskMetrics>,
    dir: Option<PathBuf>,
    /// Warning dedupe ledger: message → (index in `warnings`, count).
    warn_counts: Mutex<BTreeMap<String, (usize, usize)>>,
    /// Probe handle warnings are emitted through (instant event +
    /// counter). Off by default: the stderr fallback keeps interactive
    /// runs informed.
    tel: Mutex<Telemetry>,
}

impl Tracker {
    /// In-memory tracker.
    pub fn new(task_id: &str) -> Tracker {
        Tracker {
            task: Mutex::new(TaskMetrics {
                task_id: task_id.to_string(),
                ..TaskMetrics::default()
            }),
            dir: None,
            warn_counts: Mutex::new(BTreeMap::new()),
            tel: Mutex::new(Telemetry::off()),
        }
    }

    /// Tracker that persists `<dir>/<task_id>.json` on `finish()`.
    pub fn persistent(task_id: &str, dir: PathBuf) -> Tracker {
        let mut t = Tracker::new(task_id);
        t.dir = Some(dir);
        t
    }

    /// Attach a config key/value to the task level.
    pub fn set_config(&self, key: &str, value: String) {
        self.task.lock().unwrap().config.insert(key.to_string(), value);
    }

    /// Record a completed round.
    pub fn record_round(&self, round: RoundMetrics) {
        self.task.lock().unwrap().rounds.push(round);
    }

    /// Attach a live telemetry handle: warnings then surface as instant
    /// trace events + a `warnings` counter instead of stderr.
    pub fn set_telemetry(&self, tel: Telemetry) {
        *self.tel.lock().unwrap() = tel;
    }

    /// Record a non-fatal anomaly with the task. Identical repeats are
    /// deduplicated in place with a count (`"msg (xN)"`), and all I/O —
    /// the telemetry sink, or the stderr fallback when telemetry is off —
    /// happens *after* the task mutex is released, so a slow terminal
    /// never serializes the workers that hit the same anomaly.
    pub fn warn(&self, msg: impl Into<String>) {
        let msg = msg.into();
        let (first, task_id) = {
            let mut t = self.task.lock().unwrap();
            let mut counts = self.warn_counts.lock().unwrap();
            let first = match counts.entry(msg.clone()) {
                Entry::Vacant(e) => {
                    e.insert((t.warnings.len(), 1));
                    t.warnings.push(msg.clone());
                    true
                }
                Entry::Occupied(mut e) => {
                    let (idx, n) = e.get_mut();
                    *n += 1;
                    t.warnings[*idx] = format!("{msg} (x{n})");
                    false
                }
            };
            (first, t.task_id.clone())
        };
        let tel = self.tel.lock().unwrap().clone();
        if first {
            if !tel.warn(&msg) {
                eprintln!("[easyfl:{task_id}] warning: {msg}");
            }
        } else {
            // Repeats only bump the counter; the trace stays readable.
            tel.counter("warnings", 1);
        }
    }

    /// Warnings recorded so far.
    pub fn warnings(&self) -> Vec<String> {
        self.task.lock().unwrap().warnings.clone()
    }

    // ------------------------------------------------------- queries

    pub fn num_rounds(&self) -> usize {
        self.task.lock().unwrap().rounds.len()
    }

    /// Latest test accuracy (the paper's headline per-task number).
    pub fn final_accuracy(&self) -> Option<f64> {
        self.task
            .lock()
            .unwrap()
            .rounds
            .iter()
            .rev()
            .find_map(|r| r.test_accuracy)
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.task
            .lock()
            .unwrap()
            .rounds
            .iter()
            .filter_map(|r| r.test_accuracy)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Mean round time, T_round = T_total / R (paper §VIII-B).
    pub fn avg_round_ms(&self) -> f64 {
        let t = self.task.lock().unwrap();
        if t.rounds.is_empty() {
            return 0.0;
        }
        t.rounds.iter().map(|r| r.round_ms).sum::<f64>() / t.rounds.len() as f64
    }

    pub fn total_comm_bytes(&self) -> usize {
        self.task.lock().unwrap().rounds.iter().map(|r| r.comm_bytes).sum()
    }

    /// Total cloud fan-in over the task (see
    /// [`RoundMetrics::bytes_to_cloud`]).
    pub fn total_bytes_to_cloud(&self) -> usize {
        self.task
            .lock()
            .unwrap()
            .rounds
            .iter()
            .map(|r| r.bytes_to_cloud)
            .sum()
    }

    /// (round, train_loss, test_accuracy) series for loss curves.
    pub fn loss_curve(&self) -> Vec<(usize, f64, Option<f64>)> {
        self.task
            .lock()
            .unwrap()
            .rounds
            .iter()
            .map(|r| (r.round, r.train_loss, r.test_accuracy))
            .collect()
    }

    /// Per-client round times of a given round (Fig 6 reproduction).
    pub fn client_round_times(&self, round: usize) -> Vec<f64> {
        self.task
            .lock()
            .unwrap()
            .rounds
            .iter()
            .find(|r| r.round == round)
            .map(|r| r.clients.iter().map(|c| c.round_ms).collect())
            .unwrap_or_default()
    }

    // ------------------------------------------------- serialization

    /// Full task → JSON (the remote tracking service sends this shape).
    pub fn to_json(&self) -> Json {
        let t = self.task.lock().unwrap();
        let rounds: Vec<Json> = t
            .rounds
            .iter()
            .map(|r| {
                let clients: Vec<Json> = r
                    .clients
                    .iter()
                    .map(|c| {
                        obj([
                            ("client", Json::Num(c.client as f64)),
                            ("num_samples", Json::Num(c.num_samples as f64)),
                            ("train_loss", Json::Num(c.train_loss)),
                            ("train_accuracy", Json::Num(c.train_accuracy)),
                            ("compute_ms", Json::Num(c.compute_ms)),
                            ("wait_ms", Json::Num(c.wait_ms)),
                            ("round_ms", Json::Num(c.round_ms)),
                            ("upload_bytes", Json::Num(c.upload_bytes as f64)),
                            ("device", Json::Str(c.device.clone())),
                        ])
                    })
                    .collect();
                obj([
                    ("round", Json::Num(r.round as f64)),
                    ("train_loss", Json::Num(r.train_loss)),
                    ("train_accuracy", Json::Num(r.train_accuracy)),
                    (
                        "test_loss",
                        r.test_loss.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    (
                        "test_accuracy",
                        r.test_accuracy.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("round_ms", Json::Num(r.round_ms)),
                    ("distribution_ms", Json::Num(r.distribution_ms)),
                    ("comm_bytes", Json::Num(r.comm_bytes as f64)),
                    ("bytes_to_cloud", Json::Num(r.bytes_to_cloud as f64)),
                    ("clients", Json::Arr(clients)),
                    ("selected", Json::Num(r.selected as f64)),
                    ("reported", Json::Num(r.reported as f64)),
                    ("dropped", Json::Num(r.dropped as f64)),
                    ("avg_staleness", Json::Num(r.avg_staleness)),
                    ("client_ms_p50", Json::Num(r.client_ms_p50)),
                    ("client_ms_p95", Json::Num(r.client_ms_p95)),
                    ("client_ms_p99", Json::Num(r.client_ms_p99)),
                ])
            })
            .collect();
        obj([
            ("task_id", Json::Str(t.task_id.clone())),
            (
                "config",
                Json::Obj(
                    t.config
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            ("rounds", Json::Arr(rounds)),
            (
                "warnings",
                Json::Arr(
                    t.warnings.iter().cloned().map(Json::Str).collect(),
                ),
            ),
        ])
    }

    /// Rebuild a tracker from its JSON form (remote tracking ingest).
    pub fn from_json(v: &Json) -> Result<Tracker> {
        let task_id = v.req_str("task_id")?;
        let tracker = Tracker::new(&task_id);
        if let Some(cfg) = v.get("config").as_obj() {
            for (k, val) in cfg {
                if let Some(s) = val.as_str() {
                    tracker.set_config(k, s.to_string());
                }
            }
        }
        for w in v.get("warnings").as_arr().unwrap_or(&[]) {
            if let Some(s) = w.as_str() {
                tracker.task.lock().unwrap().warnings.push(s.to_string());
            }
        }
        for r in v.get("rounds").as_arr().unwrap_or(&[]) {
            let clients = r
                .get("clients")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|c| {
                    Ok(ClientMetrics {
                        client: c.req_usize("client")?,
                        num_samples: c.req_usize("num_samples")?,
                        train_loss: c.req_f64("train_loss")?,
                        train_accuracy: c.req_f64("train_accuracy")?,
                        compute_ms: c.req_f64("compute_ms")?,
                        wait_ms: c.req_f64("wait_ms")?,
                        round_ms: c.req_f64("round_ms")?,
                        upload_bytes: c.req_usize("upload_bytes")?,
                        device: c.req_str("device").unwrap_or_default(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            tracker.record_round(RoundMetrics {
                round: r.req_usize("round")?,
                train_loss: r.req_f64("train_loss")?,
                train_accuracy: r.req_f64("train_accuracy")?,
                test_loss: r.get("test_loss").as_f64(),
                test_accuracy: r.get("test_accuracy").as_f64(),
                round_ms: r.req_f64("round_ms")?,
                distribution_ms: r.req_f64("distribution_ms")?,
                comm_bytes: r.req_usize("comm_bytes")?,
                // Absent in pre-hierarchy recordings: default 0.
                bytes_to_cloud: r.get("bytes_to_cloud").as_usize().unwrap_or(0),
                clients,
                // Participation fields default for pre-SimNet task JSON.
                selected: r.get("selected").as_usize().unwrap_or(0),
                reported: r.get("reported").as_usize().unwrap_or(0),
                dropped: r.get("dropped").as_usize().unwrap_or(0),
                avg_staleness: r.get("avg_staleness").as_f64().unwrap_or(0.0),
                // Quantiles default 0 for pre-telemetry recordings.
                client_ms_p50: r.get("client_ms_p50").as_f64().unwrap_or(0.0),
                client_ms_p95: r.get("client_ms_p95").as_f64().unwrap_or(0.0),
                client_ms_p99: r.get("client_ms_p99").as_f64().unwrap_or(0.0),
            });
        }
        Ok(tracker)
    }

    /// Persist to `<dir>/<task_id>.json` if a directory was configured.
    pub fn finish(&self) -> Result<Option<PathBuf>> {
        let Some(dir) = &self.dir else { return Ok(None) };
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!(
            "{}.json",
            self.task.lock().unwrap().task_id
        ));
        std::fs::write(&path, self.to_json().to_pretty())
            .map_err(|e| Error::Tracking(e.to_string()))?;
        Ok(Some(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(n: usize, acc: f64) -> RoundMetrics {
        RoundMetrics {
            round: n,
            train_loss: 2.0 / (n + 1) as f64,
            train_accuracy: acc - 0.05,
            test_accuracy: Some(acc),
            test_loss: Some(1.0),
            round_ms: 100.0 + n as f64,
            distribution_ms: 5.0,
            comm_bytes: 1000,
            bytes_to_cloud: 600,
            selected: 12,
            reported: 10,
            dropped: 2,
            avg_staleness: 0.5,
            client_ms_p50: 95.0,
            client_ms_p95: 180.0,
            client_ms_p99: 240.0,
            clients: vec![ClientMetrics {
                client: 7,
                num_samples: 50,
                train_loss: 1.5,
                train_accuracy: acc,
                compute_ms: 80.0,
                wait_ms: 20.0,
                round_ms: 100.0,
                upload_bytes: 500,
                device: "mid".into(),
            }],
        }
    }

    #[test]
    fn hierarchy_and_queries() {
        let t = Tracker::new("task-1");
        t.set_config("dataset", "femnist".into());
        t.record_round(round(0, 0.50));
        t.record_round(round(1, 0.60));
        t.record_round(round(2, 0.58));
        assert_eq!(t.num_rounds(), 3);
        assert_eq!(t.final_accuracy(), Some(0.58));
        assert_eq!(t.best_accuracy(), Some(0.60));
        assert!((t.avg_round_ms() - 101.0).abs() < 1e-9);
        assert_eq!(t.total_comm_bytes(), 3000);
        assert_eq!(t.total_bytes_to_cloud(), 1800);
        assert_eq!(t.client_round_times(1), vec![100.0]);
        assert_eq!(t.loss_curve().len(), 3);
    }

    #[test]
    fn json_roundtrip_preserves_all_levels() {
        let t = Tracker::new("task-2");
        t.set_config("model", "mlp".into());
        t.record_round(round(0, 0.42));
        let j = t.to_json();
        let back = Tracker::from_json(&j).unwrap();
        assert_eq!(back.num_rounds(), 1);
        assert_eq!(back.final_accuracy(), Some(0.42));
        assert_eq!(back.to_json(), j);
    }

    #[test]
    fn persistence_writes_file() {
        let dir = std::env::temp_dir().join("easyfl_tracking_test");
        let _ = std::fs::remove_dir_all(&dir);
        let t = Tracker::persistent("task-3", dir.clone());
        t.record_round(round(0, 0.9));
        let path = t.finish().unwrap().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("task-3"));
        assert!(text.contains("test_accuracy"));
    }

    #[test]
    fn warnings_persist_and_roundtrip() {
        let t = Tracker::new("task-w");
        t.warn("no test accuracy recorded");
        assert_eq!(t.warnings(), vec!["no test accuracy recorded"]);
        let j = t.to_json();
        let back = Tracker::from_json(&j).unwrap();
        assert_eq!(back.warnings(), t.warnings());
        assert_eq!(back.to_json(), j);
    }

    #[test]
    fn empty_tracker_queries() {
        let t = Tracker::new("empty");
        assert_eq!(t.final_accuracy(), None);
        assert_eq!(t.avg_round_ms(), 0.0);
        assert!(t.client_round_times(0).is_empty());
    }

    #[test]
    fn repeated_warnings_dedupe_with_a_count() {
        let t = Tracker::new("task-dd");
        t.warn("deadline missed");
        t.warn("deadline missed");
        t.warn("deadline missed");
        t.warn("other anomaly");
        assert_eq!(
            t.warnings(),
            vec!["deadline missed (x3)", "other anomaly"]
        );
    }

    #[test]
    fn warnings_route_through_telemetry_when_attached() {
        use crate::obs::NullSink;
        use crate::util::clock::VirtualClock;
        use std::sync::Arc;

        let t = Tracker::new("task-tel");
        let tel = Telemetry::new(
            Arc::new(VirtualClock::new()),
            Arc::new(NullSink),
            None,
        );
        t.set_telemetry(tel.clone());
        t.warn("slow edge");
        t.warn("slow edge");
        // First emission + one deduped repeat both count.
        assert_eq!(tel.counter_value("warnings"), 2);
        assert_eq!(t.warnings(), vec!["slow edge (x2)"]);
    }

    #[test]
    fn client_quantiles_roundtrip_and_default_for_old_json() {
        let t = Tracker::new("task-q");
        t.record_round(round(0, 0.5));
        let j = t.to_json();
        let back = Tracker::from_json(&j).unwrap();
        assert_eq!(back.to_json(), j);
        // Pre-telemetry task JSON (no quantile keys) still parses.
        let old = Json::parse(
            r#"{"task_id": "legacy", "rounds": [{
                "round": 0, "train_loss": 1.0, "train_accuracy": 0.5,
                "round_ms": 100.0, "distribution_ms": 5.0,
                "comm_bytes": 10}]}"#,
        )
        .unwrap();
        let legacy = Tracker::from_json(&old).unwrap();
        let j = legacy.to_json();
        let r = &j.get("rounds").as_arr().unwrap()[0];
        assert_eq!(r.get("client_ms_p50").as_f64(), Some(0.0));
        assert_eq!(r.get("client_ms_p99").as_f64(), Some(0.0));
    }

    #[test]
    fn malformed_rounds_are_rejected() {
        // Missing required round fields must error, not default.
        let cases = [
            // No task_id at all.
            r#"{"rounds": []}"#,
            // Round missing round_ms.
            r#"{"task_id": "x", "rounds": [{
                "round": 0, "train_loss": 1.0, "train_accuracy": 0.5,
                "distribution_ms": 5.0, "comm_bytes": 10}]}"#,
            // Round missing the round index.
            r#"{"task_id": "x", "rounds": [{
                "train_loss": 1.0, "train_accuracy": 0.5,
                "round_ms": 100.0, "distribution_ms": 5.0,
                "comm_bytes": 10}]}"#,
            // Client entry missing num_samples.
            r#"{"task_id": "x", "rounds": [{
                "round": 0, "train_loss": 1.0, "train_accuracy": 0.5,
                "round_ms": 100.0, "distribution_ms": 5.0,
                "comm_bytes": 10,
                "clients": [{"client": 1, "train_loss": 1.0,
                             "train_accuracy": 0.5, "compute_ms": 1.0,
                             "wait_ms": 0.0, "round_ms": 1.0,
                             "upload_bytes": 5}]}]}"#,
        ];
        for src in cases {
            let j = Json::parse(src).unwrap();
            assert!(Tracker::from_json(&j).is_err(), "{src}");
        }
    }
}
