//! Configuration system behind `easyfl::init(configs)` (paper §IV-B).
//!
//! A [`Config`] carries everything the simulation manager, data manager,
//! scheduler and server need. Users construct it from defaults, a JSON
//! file, or builder-style mutation; `validate` enforces the invariants the
//! paper's `init` API promises ("default configurations if not specified").

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Which dataset the data manager simulates (paper Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 62-class handwritten characters, 3550 natural writers.
    Femnist,
    /// Next-character prediction, 1129 natural speakers.
    Shakespeare,
    /// 10-class images, flexible client count.
    Cifar10,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "femnist" => Ok(Self::Femnist),
            "shakespeare" => Ok(Self::Shakespeare),
            "cifar10" | "cifar-10" | "cifar" => Ok(Self::Cifar10),
            other => Err(Error::Config(format!("unknown dataset {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Femnist => "femnist",
            Self::Shakespeare => "shakespeare",
            Self::Cifar10 => "cifar10",
        }
    }

    /// Default model artifact for the dataset (paper Table III pairing).
    pub fn default_model(self) -> &'static str {
        match self {
            Self::Femnist => "mlp",
            Self::Shakespeare => "charcnn",
            Self::Cifar10 => "cnn",
        }
    }
}

/// Statistical-heterogeneity partition method (paper §V-A).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Independent and identically distributed split.
    Iid,
    /// Per-writer realistic non-IID (FEMNIST/Shakespeare style).
    Realistic,
    /// Dirichlet process Dir(alpha) over class proportions.
    Dirichlet(f64),
    /// Each client holds exactly `n` of the classes.
    ByClass(usize),
}

impl Partition {
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.to_ascii_lowercase();
        if s == "iid" {
            Ok(Self::Iid)
        } else if s == "realistic" {
            Ok(Self::Realistic)
        } else if let Some(a) = s.strip_prefix("dir(").and_then(|r| r.strip_suffix(')')) {
            a.parse()
                .map(Self::Dirichlet)
                .map_err(|_| Error::Config(format!("bad dirichlet alpha {a:?}")))
        } else if let Some(n) = s.strip_prefix("class(").and_then(|r| r.strip_suffix(')')) {
            n.parse()
                .map(Self::ByClass)
                .map_err(|_| Error::Config(format!("bad class count {n:?}")))
        } else {
            Err(Error::Config(format!(
                "unknown partition {s:?} (iid | realistic | dir(a) | class(n))"
            )))
        }
    }

    pub fn name(&self) -> String {
        match self {
            Self::Iid => "iid".into(),
            Self::Realistic => "realistic".into(),
            Self::Dirichlet(a) => format!("dir({a})"),
            Self::ByClass(n) => format!("class({n})"),
        }
    }
}

/// Client allocation strategy for distributed training (paper §VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocation {
    /// Greedy Allocation with Adaptive Profiling (Algorithm 1).
    GreedyAda,
    /// Random round-robin (paper's "random allocation" baseline).
    Random,
    /// Slowest-together (paper's "slowest allocation" baseline).
    Slowest,
}

impl Allocation {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "greedyada" | "greedy" => Ok(Self::GreedyAda),
            "random" => Ok(Self::Random),
            "slowest" => Ok(Self::Slowest),
            other => Err(Error::Config(format!("unknown allocation {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::GreedyAda => "greedyada",
            Self::Random => "random",
            Self::Slowest => "slowest",
        }
    }
}

/// SimNet round engine (see [`crate::simnet`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Deadline-bounded synchronous rounds with over-selection.
    Sync,
    /// FedBuff-style async aggregation every `async_buffer` arrivals.
    Async,
}

impl SimMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sync" | "synchronous" => Ok(Self::Sync),
            "async" | "asynchronous" | "fedbuff" => Ok(Self::Async),
            other => Err(Error::Config(format!("unknown sim mode {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Sync => "sync",
            Self::Async => "async",
        }
    }
}

/// Discrete-event simulator knobs (see [`crate::simnet`]). All fields
/// have working defaults so `Config::default()` simulates out of the box.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Round engine: sync (deadline + over-selection) or async (FedBuff).
    pub mode: SimMode,
    /// Registered availability model spec: "always-on" | "diurnal(duty)"
    /// | "flaky(mean_on_ms,mean_off_ms)" | any registered name.
    pub availability: String,
    /// Registered cost model: "mobile-wan" | "ideal" | "datacenter" |
    /// any registered name.
    pub cost_model: String,
    /// Per-selection probability that a client abandons the round.
    pub dropout: f64,
    /// Sync: aggregate whatever has arrived at this virtual deadline.
    pub deadline_ms: f64,
    /// Sync over-selection factor c ≥ 1: select ⌈K·c⌉ clients, aggregate
    /// the first K reporters, drop the rest.
    pub over_select: f64,
    /// Async: aggregate every B arrivals (0 ⇒ clients_per_round).
    pub async_buffer: usize,
    /// Async: concurrent trainers (0 ⇒ 2 × clients_per_round).
    pub async_concurrency: usize,
    /// Async staleness discount exponent: weight = (1+staleness)^-α.
    pub staleness_alpha: f64,
    /// Model update size in bytes (0 ⇒ cost model default).
    pub model_bytes: usize,
    /// Fastest-tier local-training time in ms (0 ⇒ cost model default).
    pub base_compute_ms: f64,
    /// Train real models through the Engine instead of the surrogate
    /// curves (small cohorts only; needs AOT artifacts).
    pub real_training: bool,
    /// Edge→cloud backhaul bandwidth in bytes/ms for hierarchical
    /// topologies (0 ⇒ cost model default). Flat runs never read it.
    pub edge_bandwidth: f64,
    /// Registered adversary model spec corrupting Byzantine clients'
    /// updates: "sign-flip" | "scaled-noise(factor)" | "zero-update" |
    /// any registered name (active only when `adversary_frac > 0`).
    pub adversary: String,
    /// Fraction of the population behaving Byzantine, in [0, 1).
    /// 0 disables the adversary plane entirely (no RNG draws, trace
    /// digests match pre-adversary baselines bit-for-bit).
    pub adversary_frac: f64,
    /// Cloud ingest rate in bytes/ms for hierarchical fan-in: the cloud
    /// deserializes each round's edge partials at this rate before the
    /// reduction lands (0 ⇒ cost model default, infinite on every
    /// built-in, so flat and pre-existing hierarchical digests are
    /// untouched until a finite rate is configured).
    pub cloud_ingest_bytes_per_ms: f64,
    /// Registered elastic-membership (churn) model spec applied between
    /// rounds: "none" | "grow(n)" | "shrink(n)" | "flux(j,l)" | any
    /// registered name. "none" burns zero RNG and leaves every
    /// pre-existing trace digest bit-identical.
    pub churn: String,
    /// Round engine family: "server" (sync/async/hierarchical, the
    /// default) or "gossip" (serverless P2P rounds over a `gossip(k)` /
    /// `ring` peer-graph topology; `bytes_to_cloud` stays 0). "server"
    /// leaves every pre-existing trace digest bit-identical.
    pub engine: String,
    /// Gossip rounds to run when `engine = "gossip"` (0 ⇒ `Config.rounds`).
    pub gossip_rounds: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            mode: SimMode::Sync,
            availability: "always-on".into(),
            cost_model: "mobile-wan".into(),
            dropout: 0.0,
            deadline_ms: 60_000.0,
            over_select: 1.3,
            async_buffer: 0,
            async_concurrency: 0,
            staleness_alpha: 0.5,
            model_bytes: 0,
            base_compute_ms: 0.0,
            real_training: false,
            edge_bandwidth: 0.0,
            adversary: "sign-flip".into(),
            adversary_frac: 0.0,
            cloud_ingest_bytes_per_ms: 0.0,
            churn: "none".into(),
            engine: "server".into(),
            gossip_rounds: 0,
        }
    }
}

impl SimConfig {
    /// Apply a JSON object of overrides (the `"sim"` sub-object).
    fn apply_json(&mut self, v: &Json) -> Result<()> {
        if let Some(s) = v.get("mode").as_str() {
            self.mode = SimMode::parse(s)?;
        }
        if let Some(s) = v.get("availability").as_str() {
            self.availability = s.to_string();
        }
        if let Some(s) = v.get("cost_model").as_str() {
            self.cost_model = s.to_string();
        }
        if let Some(x) = v.get("dropout").as_f64() {
            self.dropout = x;
        }
        if let Some(x) = v.get("deadline_ms").as_f64() {
            self.deadline_ms = x;
        }
        if let Some(x) = v.get("over_select").as_f64() {
            self.over_select = x;
        }
        if let Some(n) = v.get("async_buffer").as_usize() {
            self.async_buffer = n;
        }
        if let Some(n) = v.get("async_concurrency").as_usize() {
            self.async_concurrency = n;
        }
        if let Some(x) = v.get("staleness_alpha").as_f64() {
            self.staleness_alpha = x;
        }
        if let Some(n) = v.get("model_bytes").as_usize() {
            self.model_bytes = n;
        }
        if let Some(x) = v.get("base_compute_ms").as_f64() {
            self.base_compute_ms = x;
        }
        if let Some(b) = v.get("real_training").as_bool() {
            self.real_training = b;
        }
        if let Some(x) = v.get("edge_bandwidth").as_f64() {
            self.edge_bandwidth = x;
        }
        if let Some(s) = v.get("adversary").as_str() {
            self.adversary = s.to_string();
        }
        if let Some(x) = v.get("adversary_frac").as_f64() {
            self.adversary_frac = x;
        }
        if let Some(x) = v.get("cloud_ingest_bytes_per_ms").as_f64() {
            self.cloud_ingest_bytes_per_ms = x;
        }
        if let Some(s) = v.get("churn").as_str() {
            self.churn = s.to_string();
        }
        if let Some(s) = v.get("engine").as_str() {
            self.engine = s.to_string();
        }
        if let Some(n) = v.get("gossip_rounds").as_usize() {
            self.gossip_rounds = n;
        }
        Ok(())
    }

    fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(Error::Config("sim.dropout must be in [0,1)".into()));
        }
        if !(self.deadline_ms > 0.0) {
            return Err(Error::Config("sim.deadline_ms must be > 0".into()));
        }
        if self.over_select < 1.0 {
            return Err(Error::Config("sim.over_select must be ≥ 1".into()));
        }
        if self.staleness_alpha < 0.0 {
            return Err(Error::Config("sim.staleness_alpha must be ≥ 0".into()));
        }
        if self.availability.trim().is_empty() || self.cost_model.trim().is_empty()
        {
            return Err(Error::Config(
                "sim.availability / sim.cost_model must be non-empty".into(),
            ));
        }
        if !(self.edge_bandwidth >= 0.0) {
            return Err(Error::Config(
                "sim.edge_bandwidth must be ≥ 0 (0 = cost model default)"
                    .into(),
            ));
        }
        if !(0.0..1.0).contains(&self.adversary_frac) {
            return Err(Error::Config(
                "sim.adversary_frac must be in [0,1)".into(),
            ));
        }
        if self.adversary.trim().is_empty() {
            return Err(Error::Config("sim.adversary must be non-empty".into()));
        }
        if !(self.cloud_ingest_bytes_per_ms >= 0.0) {
            return Err(Error::Config(
                "sim.cloud_ingest_bytes_per_ms must be ≥ 0 (0 = cost \
                 model default)"
                    .into(),
            ));
        }
        if self.churn.trim().is_empty() {
            return Err(Error::Config(
                "sim.churn must name a registered churn model (\"none\" \
                 disables elastic membership)"
                    .into(),
            ));
        }
        if self.engine != "server" && self.engine != "gossip" {
            return Err(Error::Config(format!(
                "sim.engine must be \"server\" or \"gossip\", got {:?}",
                self.engine
            )));
        }
        Ok(())
    }
}

/// Full platform configuration. Defaults mirror the paper's Appendix B-A.
#[derive(Debug, Clone)]
pub struct Config {
    /// Algorithm name resolved through the component registry at `init`
    /// ("fedavg" | "fedprox" | "stc" | "fedreid" | any registered name).
    /// This is what makes every built-in application a 3-line program:
    /// selecting FedProx is `cfg.algorithm = "fedprox".into()`.
    pub algorithm: String,
    /// Dataset to simulate.
    pub dataset: DatasetKind,
    /// Optional registered data-source name; overrides `dataset` when a
    /// custom [`crate::data::registry::DataSource`] was registered under
    /// this name in the component registry. Built-in names ("femnist",
    /// "shakespeare", "cifar10") also re-pair `dataset` — and therefore
    /// the "auto" model — with the source actually served.
    pub data_source: Option<String>,
    /// Model artifact name ("mlp" | "cnn" | "charcnn"), or "auto" to
    /// pair with the dataset (Table III pairing).
    pub model: String,
    /// Directory containing AOT artifacts.
    pub artifacts_dir: PathBuf,
    /// Total number of simulated clients (0 ⇒ dataset's natural count).
    pub num_clients: usize,
    /// Clients selected per round (paper: C).
    pub clients_per_round: usize,
    /// Training rounds (paper: R).
    pub rounds: usize,
    /// Local epochs per round (paper: E = 10).
    pub local_epochs: usize,
    /// Minibatch size must match the AOT batch (paper: B = 64; ours 32).
    pub batch_size: usize,
    /// SGD learning rate (0.01 images / 0.8 shakespeare in the paper).
    pub lr: f64,
    /// Statistical heterogeneity partition.
    pub partition: Partition,
    /// Simulate unbalanced client sizes (log-normal / Dirichlet sizes).
    pub unbalanced: bool,
    /// Simulate system heterogeneity (device speed-ratio waits).
    pub system_heterogeneity: bool,
    /// Simulated parallel devices ("GPUs"); 1 ⇒ standalone training.
    pub num_devices: usize,
    /// Allocation strategy when `num_devices > 1`.
    pub allocation: Allocation,
    /// GreedyAda default client time `t` in ms (Algorithm 1 input).
    pub default_client_time_ms: f64,
    /// GreedyAda update momentum `m` (Algorithm 1 input).
    pub profile_momentum: f64,
    /// Wait-time scale for system-heterogeneity sleeps (1.0 = real time;
    /// tests/benches use ≤ 0.01 to compress simulated waits).
    pub time_scale: f64,
    /// Use a virtual clock (no real sleeps) for heterogeneity waits.
    pub virtual_clock: bool,
    /// Fraction of each client's samples used for training (Fig 7b/c).
    pub data_amount: f64,
    /// FedProx proximal coefficient μ (used by the fedprox algorithm).
    pub fedprox_mu: f64,
    /// STC kept-coordinate fraction (used by the stc algorithm).
    pub stc_sparsity: f64,
    /// Base RNG seed: equal seeds reproduce experiments bit-for-bit.
    pub seed: u64,
    /// Where the tracking manager persists metrics (None ⇒ memory only).
    pub tracking_dir: Option<PathBuf>,
    /// Evaluate the global model on the test split every `n` rounds.
    pub eval_every: usize,
    /// Total samples cap for quick experiments (0 = dataset natural size).
    pub max_samples: usize,
    /// Size of the IID test split the server evaluates on.
    pub test_samples: usize,
    /// Cohort size at/above which the streaming aggregator reduces dense
    /// updates chunk-parallel across threads (0 ⇒ parallel whenever the
    /// parameter vector is large enough).
    pub agg_parallel_threshold: usize,
    /// Worker threads for the chunk-parallel reduce (0 ⇒ all cores,
    /// capped at 8). Auto mode only engages for very large parameter
    /// vectors (the per-add thread spawn must amortize); an explicit
    /// value opts smaller vectors in.
    pub agg_threads: usize,
    /// Registered aggregator overriding the server flow's default
    /// reduction ("mean" | "trimmed_mean" | "median" | "norm_clip" | any
    /// registered name). `None` keeps each flow's own choice. This is
    /// the pure-config path to Byzantine robustness: `cfg.agg =
    /// Some("trimmed_mean".into())` hardens any algorithm.
    pub agg: Option<String>,
    /// Per-end trim fraction for the "trimmed_mean" aggregator, in
    /// [0, 0.5): ⌊frac·cohort⌋ lowest and highest values are dropped per
    /// coordinate. Tolerates that many Byzantine updates.
    pub agg_trim_frac: f64,
    /// L2 delta-norm threshold for the "norm_clip" aggregator: updates
    /// farther than this from the global model are rescaled onto the
    /// threshold sphere before aggregation. 0 ⇒ *adaptive* clipping:
    /// the aggregator tracks a running quantile of observed update
    /// norms (DP-FedAvg style) so the threshold needs no tuning.
    pub agg_clip_norm: f64,
    /// Run the rank-based robust aggregators ("trimmed_mean", "median")
    /// on mergeable streaming quantile sketches instead of buffering the
    /// decoded cohort: O(threads·P + sketch) memory instead of
    /// O(cohort·P) (see [`crate::aggregate::sketch`]). Off by default —
    /// the exact buffered path stays the equivalence oracle, and small
    /// cohorts (≤ the sketch's per-coordinate capacity) are bit-identical
    /// either way.
    pub agg_sketch: bool,
    /// Federation topology spec resolved through the component registry:
    /// "flat" | "edges(n)" | "clusters(file)" | any registered name.
    /// Anything non-flat interposes an edge aggregator tier between the
    /// clients and the cloud (see [`crate::hierarchy`]).
    pub topology: String,
    /// Registered aggregator for the *edge* tier of a hierarchical
    /// topology. `None` falls back to `agg` (then the flow default), so
    /// `edge_agg = Some("median")` with `agg = Some("trimmed_mean")`
    /// selects per-tier robustness purely from config. Flat runs ignore
    /// it.
    pub edge_agg: Option<String>,
    /// Registered update codec compressing every client upload
    /// ("identity" | "top_k(frac)" | "top_k_f16(frac)" |
    /// "top_k_i8(frac)" | any registered name, see [`crate::codec`]).
    /// When set, the codec stage replaces the algorithm's own compress
    /// stage and SimNet charges encoded bytes per uplink. `None` keeps
    /// each algorithm's flow (and all trace digests) untouched.
    pub codec: Option<String>,
    /// Client-side error feedback for lossy codecs: each client keeps
    /// the residual its codec dropped (coordinates cut by top-k,
    /// quantization error) and adds it back into the next round's delta
    /// before encoding, so compression error accumulates toward zero
    /// instead of being lost. Off by default and digest-neutral when
    /// off; ignored by lossless codecs ("identity").
    pub codec_error_feedback: bool,
    /// Remote coordinator ingest engine: "reactor" (nonblocking poll
    /// loop multiplexing every client connection on a fixed worker pool
    /// with bounded backpressure, see [`crate::comm::reactor`]) or
    /// "threads" (the legacy thread-per-connection baseline).
    pub ingest: String,
    /// Enable the telemetry plane (spans + latency histograms, see
    /// [`crate::obs`]) even without an output file. Implied by
    /// `trace_out` / `metrics_out`. Off by default: disabled runs pay a
    /// single branch per probe and keep trace digests bit-identical.
    pub telemetry: bool,
    /// Stream spans as Chrome trace-event JSONL to this path (loadable
    /// in Perfetto / `chrome://tracing`). Implies `telemetry`.
    pub trace_out: Option<PathBuf>,
    /// Fraction of *sampled* spans actually emitted, in (0, 1]. Applies
    /// only to high-frequency per-entity spans (per-client ingest,
    /// per-edge reduces) routed through
    /// [`crate::obs::Telemetry::span_sampled`]; round-level spans,
    /// counters and histograms are always recorded. The keep/drop
    /// decision hashes the entity id (FNV-1a) — no RNG stream is
    /// touched, so sampled runs keep bit-identical trace digests.
    pub trace_sample: f64,
    /// Write the final counter/histogram snapshot as JSON to this path
    /// at the end of the run. Implies `telemetry`.
    pub metrics_out: Option<PathBuf>,
    /// Write a crash-safe round checkpoint every N aggregation
    /// boundaries (0 = off, the default). Requires `checkpoint_dir`.
    /// Checkpoint writing draws no RNG and pushes no events, so trace
    /// digests are bit-identical with checkpointing on or off.
    pub checkpoint_every: usize,
    /// Directory receiving `ckpt_round_{n}.bin` files (see
    /// [`crate::runtime::checkpoint`]). Created on first write.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume a simulation from this checkpoint file instead of round 0.
    /// The resumed run reproduces the uninterrupted run's trace digest
    /// bit-for-bit; a tampered or truncated file is an integrity error.
    pub resume_from: Option<PathBuf>,
    /// Retain only the newest N checkpoint files, pruning older
    /// `ckpt_round_*.bin` after each successful save (0 = keep all, the
    /// default). The most recent checkpoint is never deleted.
    pub checkpoint_keep: usize,
    /// Chaos plane: registered fault specs injected into the run, e.g.
    /// `kill_server_at_round(10)`, `partition_edge(2)`,
    /// `drop_frames(0.05)`, `corrupt_checkpoint`. Empty (the default)
    /// burns zero RNG and leaves every trace digest untouched.
    pub chaos: Vec<String>,
    /// Discrete-event simulator knobs (the `simulate` subcommand and
    /// [`crate::simnet`] jobs read these; training runs ignore them).
    pub sim: SimConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            algorithm: "fedavg".into(),
            dataset: DatasetKind::Femnist,
            data_source: None,
            model: "auto".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            num_clients: 0,
            clients_per_round: 10,
            rounds: 10,
            local_epochs: 10,
            batch_size: 32,
            lr: 0.01,
            partition: Partition::Realistic,
            unbalanced: false,
            system_heterogeneity: false,
            num_devices: 1,
            allocation: Allocation::GreedyAda,
            default_client_time_ms: 100.0,
            profile_momentum: 0.5,
            time_scale: 1.0,
            virtual_clock: false,
            data_amount: 1.0,
            fedprox_mu: 0.01,
            stc_sparsity: 0.01,
            seed: 42,
            tracking_dir: None,
            eval_every: 1,
            max_samples: 0,
            test_samples: 512,
            agg_parallel_threshold: 64,
            agg_threads: 0,
            agg: None,
            agg_trim_frac: 0.1,
            agg_clip_norm: 10.0,
            agg_sketch: false,
            topology: "flat".into(),
            edge_agg: None,
            codec: None,
            codec_error_feedback: false,
            ingest: "reactor".into(),
            telemetry: false,
            trace_out: None,
            trace_sample: 1.0,
            metrics_out: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume_from: None,
            checkpoint_keep: 0,
            chaos: Vec::new(),
            sim: SimConfig::default(),
        }
    }
}

impl Config {
    /// The effective model name ("auto" resolves to the dataset default).
    pub fn resolved_model(&self) -> String {
        if self.model == "auto" {
            self.dataset.default_model().to_string()
        } else {
            self.model.clone()
        }
    }

    /// True when any telemetry output (or the bare switch) is on.
    /// Probes compile to a single branch when this is false.
    pub fn telemetry_enabled(&self) -> bool {
        self.telemetry || self.trace_out.is_some() || self.metrics_out.is_some()
    }

    /// Paper-style quick constructor: dataset plus defaults.
    pub fn for_dataset(dataset: DatasetKind) -> Config {
        let mut c = Config { dataset, ..Config::default() };
        c.model = dataset.default_model().to_string();
        if dataset == DatasetKind::Shakespeare {
            c.lr = 0.8;
        }
        c
    }

    /// Load overrides from a JSON file on top of defaults.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Apply a JSON object of overrides on top of defaults.
    pub fn from_json(v: &Json) -> Result<Config> {
        let mut c = Config::default();
        if let Some(s) = v.get("algorithm").as_str() {
            c.algorithm = s.to_string();
        }
        if let Some(s) = v.get("data_source").as_str() {
            c.data_source = Some(s.to_string());
        }
        if let Some(s) = v.get("dataset").as_str() {
            c.dataset = DatasetKind::parse(s)?;
            c.model = c.dataset.default_model().to_string();
            if c.dataset == DatasetKind::Shakespeare {
                c.lr = 0.8;
            }
        }
        if let Some(s) = v.get("model").as_str() {
            c.model = s.to_string();
        }
        if let Some(s) = v.get("artifacts_dir").as_str() {
            c.artifacts_dir = PathBuf::from(s);
        }
        if let Some(n) = v.get("num_clients").as_usize() {
            c.num_clients = n;
        }
        if let Some(n) = v.get("clients_per_round").as_usize() {
            c.clients_per_round = n;
        }
        if let Some(n) = v.get("rounds").as_usize() {
            c.rounds = n;
        }
        if let Some(n) = v.get("local_epochs").as_usize() {
            c.local_epochs = n;
        }
        if let Some(n) = v.get("batch_size").as_usize() {
            c.batch_size = n;
        }
        if let Some(x) = v.get("lr").as_f64() {
            c.lr = x;
        }
        if let Some(s) = v.get("partition").as_str() {
            // Resolve through the component registry so custom registered
            // partition schemes are selectable from JSON config too.
            c.partition = crate::registry::parse_partition(s)?;
        }
        if let Some(b) = v.get("unbalanced").as_bool() {
            c.unbalanced = b;
        }
        if let Some(b) = v.get("system_heterogeneity").as_bool() {
            c.system_heterogeneity = b;
        }
        if let Some(n) = v.get("num_devices").as_usize() {
            c.num_devices = n;
        }
        if let Some(s) = v.get("allocation").as_str() {
            c.allocation = Allocation::parse(s)?;
        }
        if let Some(x) = v.get("default_client_time_ms").as_f64() {
            c.default_client_time_ms = x;
        }
        if let Some(x) = v.get("profile_momentum").as_f64() {
            c.profile_momentum = x;
        }
        if let Some(x) = v.get("time_scale").as_f64() {
            c.time_scale = x;
        }
        if let Some(b) = v.get("virtual_clock").as_bool() {
            c.virtual_clock = b;
        }
        if let Some(x) = v.get("data_amount").as_f64() {
            c.data_amount = x;
        }
        if let Some(x) = v.get("fedprox_mu").as_f64() {
            c.fedprox_mu = x;
        }
        if let Some(x) = v.get("stc_sparsity").as_f64() {
            c.stc_sparsity = x;
        }
        if let Some(n) = v.get("seed").as_usize() {
            c.seed = n as u64;
        }
        if let Some(s) = v.get("tracking_dir").as_str() {
            c.tracking_dir = Some(PathBuf::from(s));
        }
        if let Some(n) = v.get("eval_every").as_usize() {
            c.eval_every = n;
        }
        if let Some(n) = v.get("max_samples").as_usize() {
            c.max_samples = n;
        }
        if let Some(n) = v.get("test_samples").as_usize() {
            c.test_samples = n;
        }
        if let Some(n) = v.get("agg_parallel_threshold").as_usize() {
            c.agg_parallel_threshold = n;
        }
        if let Some(n) = v.get("agg_threads").as_usize() {
            c.agg_threads = n;
        }
        if let Some(s) = v.get("agg").as_str() {
            c.agg = Some(s.to_string());
        }
        if let Some(x) = v.get("agg_trim_frac").as_f64() {
            c.agg_trim_frac = x;
        }
        if let Some(x) = v.get("agg_clip_norm").as_f64() {
            c.agg_clip_norm = x;
        }
        if let Some(b) = v.get("agg_sketch").as_bool() {
            c.agg_sketch = b;
        }
        if let Some(s) = v.get("topology").as_str() {
            c.topology = s.to_string();
        }
        if let Some(s) = v.get("edge_agg").as_str() {
            c.edge_agg = Some(s.to_string());
        }
        if let Some(s) = v.get("codec").as_str() {
            c.codec = Some(s.to_string());
        }
        if let Some(b) = v.get("codec_error_feedback").as_bool() {
            c.codec_error_feedback = b;
        }
        if let Some(s) = v.get("ingest").as_str() {
            c.ingest = s.to_string();
        }
        if let Some(b) = v.get("telemetry").as_bool() {
            c.telemetry = b;
        }
        if let Some(s) = v.get("trace_out").as_str() {
            c.trace_out = Some(PathBuf::from(s));
        }
        if let Some(x) = v.get("trace_sample").as_f64() {
            c.trace_sample = x;
        }
        if let Some(s) = v.get("metrics_out").as_str() {
            c.metrics_out = Some(PathBuf::from(s));
        }
        if let Some(n) = v.get("checkpoint_every").as_usize() {
            c.checkpoint_every = n;
        }
        if let Some(s) = v.get("checkpoint_dir").as_str() {
            c.checkpoint_dir = Some(PathBuf::from(s));
        }
        if let Some(s) = v.get("resume_from").as_str() {
            c.resume_from = Some(PathBuf::from(s));
        }
        if let Some(n) = v.get("checkpoint_keep").as_usize() {
            c.checkpoint_keep = n;
        }
        if let Some(arr) = v.get("chaos").as_arr() {
            c.chaos = Vec::with_capacity(arr.len());
            for item in arr {
                match item.as_str() {
                    Some(s) => c.chaos.push(s.to_string()),
                    None => {
                        return Err(Error::Config(
                            "chaos must be an array of fault spec strings"
                                .into(),
                        ))
                    }
                }
            }
        }
        let sim = v.get("sim");
        if sim.as_obj().is_some() {
            c.sim.apply_json(sim)?;
        }
        c.validate()?;
        Ok(c)
    }

    /// Enforce cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.clients_per_round == 0 {
            return Err(Error::Config("clients_per_round must be > 0".into()));
        }
        if self.num_clients > 0 && self.clients_per_round > self.num_clients {
            return Err(Error::Config(format!(
                "clients_per_round ({}) > num_clients ({})",
                self.clients_per_round, self.num_clients
            )));
        }
        if self.num_devices == 0 {
            return Err(Error::Config("num_devices must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.profile_momentum) {
            return Err(Error::Config("profile_momentum must be in [0,1]".into()));
        }
        if !(self.data_amount > 0.0 && self.data_amount <= 1.0) {
            return Err(Error::Config("data_amount must be in (0,1]".into()));
        }
        if self.lr <= 0.0 {
            return Err(Error::Config("lr must be > 0".into()));
        }
        if self.local_epochs == 0 || self.rounds == 0 {
            return Err(Error::Config("rounds/local_epochs must be > 0".into()));
        }
        if matches!(self.partition, Partition::ByClass(0)) {
            return Err(Error::Config("class(n) needs n ≥ 1".into()));
        }
        if matches!(self.partition, Partition::Dirichlet(a) if a <= 0.0) {
            return Err(Error::Config("dir(a) needs a > 0".into()));
        }
        if self.algorithm.trim().is_empty() {
            return Err(Error::Config("algorithm must be non-empty".into()));
        }
        if !(self.stc_sparsity > 0.0 && self.stc_sparsity <= 1.0) {
            return Err(Error::Config("stc_sparsity must be in (0,1]".into()));
        }
        if self.fedprox_mu < 0.0 {
            return Err(Error::Config("fedprox_mu must be ≥ 0".into()));
        }
        if let Some(agg) = &self.agg {
            if agg.trim().is_empty() {
                return Err(Error::Config(
                    "agg must name a registered aggregator (or be absent)"
                        .into(),
                ));
            }
        }
        if !(0.0..0.5).contains(&self.agg_trim_frac) {
            return Err(Error::Config(
                "agg_trim_frac must be in [0, 0.5)".into(),
            ));
        }
        if !(self.agg_clip_norm >= 0.0 && self.agg_clip_norm.is_finite()) {
            return Err(Error::Config(
                "agg_clip_norm must be finite and ≥ 0 (0 = adaptive)".into(),
            ));
        }
        if self.topology.trim().is_empty() {
            return Err(Error::Config(
                "topology must name a registered topology (e.g. \"flat\", \
                 \"edges(16)\")"
                    .into(),
            ));
        }
        if let Some(edge_agg) = &self.edge_agg {
            if edge_agg.trim().is_empty() {
                return Err(Error::Config(
                    "edge_agg must name a registered aggregator (or be \
                     absent)"
                        .into(),
                ));
            }
        }
        if let Some(codec) = &self.codec {
            if codec.trim().is_empty() {
                return Err(Error::Config(
                    "codec must name a registered codec (or be absent)"
                        .into(),
                ));
            }
        }
        match self.ingest.as_str() {
            "reactor" | "threads" => {}
            other => {
                return Err(Error::Config(format!(
                    "ingest must be \"reactor\" or \"threads\", got {other:?}"
                )));
            }
        }
        if !(self.trace_sample > 0.0 && self.trace_sample <= 1.0) {
            return Err(Error::Config(
                "trace_sample must be in (0, 1]".into(),
            ));
        }
        if let (Some(trace), Some(metrics)) =
            (&self.trace_out, &self.metrics_out)
        {
            if trace == metrics {
                return Err(Error::Config(
                    "trace_out and metrics_out must be different paths"
                        .into(),
                ));
            }
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_none() {
            return Err(Error::Config(
                "checkpoint_every > 0 requires checkpoint_dir".into(),
            ));
        }
        for spec in &self.chaos {
            if spec.trim().is_empty() {
                return Err(Error::Config(
                    "chaos fault specs must be non-empty".into(),
                ));
            }
        }
        self.sim.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn dataset_constructor_pairs_model_and_lr() {
        let c = Config::for_dataset(DatasetKind::Shakespeare);
        assert_eq!(c.model, "charcnn");
        assert_eq!(c.lr, 0.8);
        let c = Config::for_dataset(DatasetKind::Cifar10);
        assert_eq!(c.model, "cnn");
        assert_eq!(c.lr, 0.01);
    }

    #[test]
    fn partition_parsing() {
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(
            Partition::parse("dir(0.5)").unwrap(),
            Partition::Dirichlet(0.5)
        );
        assert_eq!(Partition::parse("class(3)").unwrap(), Partition::ByClass(3));
        assert!(Partition::parse("zipf").is_err());
        assert_eq!(Partition::Dirichlet(0.5).name(), "dir(0.5)");
    }

    #[test]
    fn json_overrides_apply() {
        let j = Json::parse(
            r#"{"dataset": "cifar10", "rounds": 3, "partition": "class(2)",
                "num_devices": 4, "allocation": "random", "lr": 0.1}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.dataset, DatasetKind::Cifar10);
        assert_eq!(c.model, "cnn");
        assert_eq!(c.rounds, 3);
        assert_eq!(c.partition, Partition::ByClass(2));
        assert_eq!(c.allocation, Allocation::Random);
        assert_eq!(c.lr, 0.1);
    }

    #[test]
    fn algorithm_fields_parse_from_json() {
        let j = Json::parse(
            r#"{"algorithm": "fedprox", "fedprox_mu": 0.1,
                "stc_sparsity": 0.05, "data_source": "my-data"}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.algorithm, "fedprox");
        assert_eq!(c.fedprox_mu, 0.1);
        assert_eq!(c.stc_sparsity, 0.05);
        assert_eq!(c.data_source.as_deref(), Some("my-data"));
    }

    #[test]
    fn aggregation_knobs_parse_from_json_with_defaults() {
        let c = Config::default();
        assert_eq!(c.agg_parallel_threshold, 64);
        assert_eq!(c.agg_threads, 0);
        let j = Json::parse(
            r#"{"agg_parallel_threshold": 128, "agg_threads": 4}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.agg_parallel_threshold, 128);
        assert_eq!(c.agg_threads, 4);
    }

    #[test]
    fn robust_aggregation_knobs_parse_and_default() {
        let c = Config::default();
        assert!(c.agg.is_none());
        assert_eq!(c.agg_trim_frac, 0.1);
        assert_eq!(c.agg_clip_norm, 10.0);
        assert_eq!(c.sim.adversary, "sign-flip");
        assert_eq!(c.sim.adversary_frac, 0.0);
        let j = Json::parse(
            r#"{"agg": "trimmed_mean", "agg_trim_frac": 0.3,
                "agg_clip_norm": 2.5,
                "sim": {"adversary": "scaled-noise(20)",
                        "adversary_frac": 0.25}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.agg.as_deref(), Some("trimmed_mean"));
        assert_eq!(c.agg_trim_frac, 0.3);
        assert_eq!(c.agg_clip_norm, 2.5);
        assert_eq!(c.sim.adversary, "scaled-noise(20)");
        assert_eq!(c.sim.adversary_frac, 0.25);
    }

    #[test]
    fn hierarchy_knobs_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.topology, "flat");
        assert!(c.edge_agg.is_none());
        assert_eq!(c.sim.edge_bandwidth, 0.0);
        let j = Json::parse(
            r#"{"topology": "edges(16)", "edge_agg": "median",
                "agg": "trimmed_mean",
                "sim": {"edge_bandwidth": 125000}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.topology, "edges(16)");
        assert_eq!(c.edge_agg.as_deref(), Some("median"));
        assert_eq!(c.agg.as_deref(), Some("trimmed_mean"));
        assert_eq!(c.sim.edge_bandwidth, 125_000.0);
    }

    #[test]
    fn codec_knobs_parse_and_default() {
        let c = Config::default();
        assert!(c.codec.is_none());
        assert_eq!(c.sim.cloud_ingest_bytes_per_ms, 0.0);
        let j = Json::parse(
            r#"{"codec": "top_k_i8(0.05)",
                "sim": {"cloud_ingest_bytes_per_ms": 500000}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.codec.as_deref(), Some("top_k_i8(0.05)"));
        assert_eq!(c.sim.cloud_ingest_bytes_per_ms, 500_000.0);
    }

    #[test]
    fn telemetry_knobs_parse_and_default() {
        let c = Config::default();
        assert!(!c.telemetry);
        assert!(c.trace_out.is_none());
        assert!(c.metrics_out.is_none());
        assert!(!c.telemetry_enabled());
        let j = Json::parse(
            r#"{"telemetry": true, "trace_out": "trace.jsonl",
                "metrics_out": "metrics.json"}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert!(c.telemetry);
        assert_eq!(c.trace_out.as_deref(), Some(Path::new("trace.jsonl")));
        assert_eq!(c.metrics_out.as_deref(), Some(Path::new("metrics.json")));
        assert!(c.telemetry_enabled());
        // Either output path alone implies the switch.
        let j = Json::parse(r#"{"trace_out": "t.jsonl"}"#).unwrap();
        assert!(Config::from_json(&j).unwrap().telemetry_enabled());
        let j = Json::parse(r#"{"metrics_out": "m.json"}"#).unwrap();
        assert!(Config::from_json(&j).unwrap().telemetry_enabled());
    }

    #[test]
    fn checkpoint_chaos_churn_knobs_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.checkpoint_every, 0);
        assert!(c.checkpoint_dir.is_none());
        assert!(c.resume_from.is_none());
        assert!(c.chaos.is_empty());
        assert_eq!(c.sim.churn, "none");
        let j = Json::parse(
            r#"{"checkpoint_every": 3, "checkpoint_dir": "ckpts",
                "resume_from": "ckpts/ckpt_round_6.bin",
                "chaos": ["kill_server_at_round(10)", "drop_frames(0.05)"],
                "sim": {"churn": "flux(2,1)"}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.checkpoint_every, 3);
        assert_eq!(c.checkpoint_dir.as_deref(), Some(Path::new("ckpts")));
        assert_eq!(
            c.resume_from.as_deref(),
            Some(Path::new("ckpts/ckpt_round_6.bin"))
        );
        assert_eq!(
            c.chaos,
            vec!["kill_server_at_round(10)", "drop_frames(0.05)"]
        );
        assert_eq!(c.sim.churn, "flux(2,1)");
    }

    #[test]
    fn gossip_and_retention_knobs_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.sim.engine, "server");
        assert_eq!(c.sim.gossip_rounds, 0);
        assert_eq!(c.checkpoint_keep, 0, "0 keeps every checkpoint");
        let j = Json::parse(
            r#"{"topology": "gossip(8)", "checkpoint_keep": 3,
                "sim": {"engine": "gossip", "gossip_rounds": 25}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.sim.engine, "gossip");
        assert_eq!(c.sim.gossip_rounds, 25);
        assert_eq!(c.topology, "gossip(8)");
        assert_eq!(c.checkpoint_keep, 3);
    }

    #[test]
    fn ingest_and_sketch_knobs_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.ingest, "reactor");
        assert!(!c.agg_sketch);
        assert!(!c.codec_error_feedback);
        assert_eq!(c.trace_sample, 1.0);
        let j = Json::parse(
            r#"{"ingest": "threads", "agg_sketch": true,
                "codec_error_feedback": true, "trace_sample": 0.01}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.ingest, "threads");
        assert!(c.agg_sketch);
        assert!(c.codec_error_feedback);
        assert_eq!(c.trace_sample, 0.01);
    }

    #[test]
    fn zero_clip_norm_selects_adaptive_clipping() {
        let j = Json::parse(r#"{"agg": "norm_clip", "agg_clip_norm": 0}"#)
            .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.agg_clip_norm, 0.0, "0 is the adaptive sentinel");
    }

    #[test]
    fn invalid_configs_rejected() {
        let cases = [
            r#"{"clients_per_round": 0}"#,
            r#"{"num_devices": 0}"#,
            r#"{"data_amount": 0}"#,
            r#"{"data_amount": 1.5}"#,
            r#"{"lr": -1}"#,
            r#"{"partition": "class(0)"}"#,
            r#"{"num_clients": 5, "clients_per_round": 10}"#,
            r#"{"profile_momentum": 2}"#,
            r#"{"algorithm": " "}"#,
            r#"{"stc_sparsity": 0}"#,
            r#"{"stc_sparsity": 1.5}"#,
            r#"{"fedprox_mu": -0.5}"#,
            r#"{"sim": {"dropout": 1.0}}"#,
            r#"{"sim": {"deadline_ms": 0}}"#,
            r#"{"sim": {"over_select": 0.5}}"#,
            r#"{"sim": {"staleness_alpha": -1}}"#,
            r#"{"sim": {"mode": "eventually"}}"#,
            r#"{"agg": " "}"#,
            r#"{"agg_trim_frac": 0.5}"#,
            r#"{"agg_trim_frac": -0.1}"#,
            r#"{"agg_clip_norm": -1}"#,
            r#"{"topology": " "}"#,
            r#"{"edge_agg": " "}"#,
            r#"{"sim": {"edge_bandwidth": -5}}"#,
            r#"{"sim": {"adversary_frac": 1.0}}"#,
            r#"{"sim": {"adversary_frac": -0.2}}"#,
            r#"{"sim": {"adversary": " "}}"#,
            r#"{"codec": " "}"#,
            r#"{"sim": {"cloud_ingest_bytes_per_ms": -1}}"#,
            r#"{"trace_out": "same.json", "metrics_out": "same.json"}"#,
            r#"{"ingest": "epoll"}"#,
            r#"{"trace_sample": 0}"#,
            r#"{"trace_sample": 1.5}"#,
            r#"{"checkpoint_every": 3}"#,
            r#"{"chaos": [" "]}"#,
            r#"{"chaos": [42]}"#,
            r#"{"sim": {"churn": " "}}"#,
            r#"{"sim": {"engine": "telepathy"}}"#,
            r#"{"sim": {"engine": " "}}"#,
        ];
        for src in cases {
            let j = Json::parse(src).unwrap();
            assert!(Config::from_json(&j).is_err(), "{src}");
        }
    }

    #[test]
    fn sim_fields_parse_from_json() {
        let j = Json::parse(
            r#"{"rounds": 5, "sim": {"mode": "async", "availability": "diurnal(0.4)",
                "cost_model": "ideal", "dropout": 0.2, "deadline_ms": 30000,
                "over_select": 1.5, "async_buffer": 16, "async_concurrency": 64,
                "staleness_alpha": 0.7, "model_bytes": 4000000,
                "base_compute_ms": 2500}}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.sim.mode, SimMode::Async);
        assert_eq!(c.sim.availability, "diurnal(0.4)");
        assert_eq!(c.sim.cost_model, "ideal");
        assert_eq!(c.sim.dropout, 0.2);
        assert_eq!(c.sim.deadline_ms, 30_000.0);
        assert_eq!(c.sim.over_select, 1.5);
        assert_eq!(c.sim.async_buffer, 16);
        assert_eq!(c.sim.async_concurrency, 64);
        assert_eq!(c.sim.staleness_alpha, 0.7);
        assert_eq!(c.sim.model_bytes, 4_000_000);
        assert_eq!(c.sim.base_compute_ms, 2_500.0);
        assert!(!c.sim.real_training);
        // Absent "sim" keeps working defaults.
        let c2 = Config::from_json(&Json::parse(r#"{"rounds": 2}"#).unwrap()).unwrap();
        assert_eq!(c2.sim.mode, SimMode::Sync);
        assert_eq!(c2.sim.over_select, 1.3);
    }
}
