//! Distribution manager (paper §VI): client → device allocation.
//!
//! The allocation problem is a multiprocessor-scheduling variant: given M
//! devices and per-client training times, partition the round's cohort so
//! the makespan (slowest device) is minimized — Eq. (1) of the paper.
//!
//! Strategies:
//! * [`greedy_ada::GreedyAda`] — the paper's Algorithm 1 (LPT greedy +
//!   adaptive profiling of unknown client times);
//! * [`baselines::RandomAlloc`] — random ≈K/M chunks (paper baseline);
//! * [`baselines::SlowestAlloc`] — slowest clients packed together
//!   (paper baseline, the pathological case).

pub mod baselines;
pub mod greedy_ada;

pub use baselines::{RandomAlloc, SlowestAlloc};
pub use greedy_ada::GreedyAda;

use crate::config::Allocation;
use crate::util::rng::Rng;

/// One allocation decision: `groups[d]` = client ids on device `d`.
pub type Groups = Vec<Vec<usize>>;

/// A client → device allocation strategy.
///
/// `allocate` receives the round's cohort; `observe` feeds back measured
/// per-client round times after the round (adaptive profiling).
pub trait Strategy: Send {
    fn name(&self) -> &'static str;

    /// Partition `clients` over `m` devices.
    fn allocate(&mut self, clients: &[usize], m: usize, rng: &mut Rng) -> Groups;

    /// Feed back measured times (client id, round_ms).
    fn observe(&mut self, _measured: &[(usize, f64)]) {}

    /// Predicted time for a client (tracking/diagnostics; default unknown).
    fn predicted_ms(&self, _client: usize) -> Option<f64> {
        None
    }

    /// Serialized profiling state: sorted `(client, ms)` pairs plus the
    /// current default time. This is what a round checkpoint persists so
    /// a resumed run allocates identically; stateless strategies return
    /// an empty profile.
    fn snapshot_profile(&self) -> (Vec<(usize, f64)>, f64) {
        (Vec::new(), 0.0)
    }

    /// Restore state captured by [`Strategy::snapshot_profile`].
    fn restore_profile(&mut self, _profiled: &[(usize, f64)], _default_ms: f64) {}
}

/// Construct the configured strategy.
pub fn make_strategy(
    alloc: Allocation,
    default_time_ms: f64,
    momentum: f64,
) -> Box<dyn Strategy> {
    match alloc {
        Allocation::GreedyAda => {
            Box::new(GreedyAda::new(default_time_ms, momentum))
        }
        Allocation::Random => Box::new(RandomAlloc),
        Allocation::Slowest => Box::new(SlowestAlloc::new(default_time_ms)),
    }
}

/// Makespan of an allocation under known times (simulation/benches).
pub fn makespan(groups: &Groups, time_of: impl Fn(usize) -> f64) -> f64 {
    groups
        .iter()
        .map(|g| g.iter().map(|&c| time_of(c)).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Check an allocation covers exactly the given cohort.
pub fn is_partition(groups: &Groups, clients: &[usize]) -> bool {
    let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
    seen.sort_unstable();
    let mut want = clients.to_vec();
    want.sort_unstable();
    seen == want
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn makespan_of_known_groups() {
        let groups = vec![vec![0, 1], vec![2]];
        let times = [3.0, 4.0, 5.0];
        assert_eq!(makespan(&groups, |c| times[c]), 7.0);
    }

    #[test]
    fn partition_checker() {
        assert!(is_partition(&vec![vec![3, 1], vec![2]], &[1, 2, 3]));
        assert!(!is_partition(&vec![vec![1], vec![1]], &[1, 2]));
        assert!(!is_partition(&vec![vec![1]], &[1, 2]));
    }

    #[test]
    fn factory_builds_all() {
        for a in [Allocation::GreedyAda, Allocation::Random, Allocation::Slowest] {
            let s = make_strategy(a, 100.0, 0.5);
            assert_eq!(s.name(), a.name());
        }
    }

    #[test]
    fn profile_snapshot_round_trips_across_strategies() {
        for a in [Allocation::GreedyAda, Allocation::Slowest] {
            let mut s = make_strategy(a, 100.0, 0.5);
            s.observe(&[(3, 40.0), (9, 80.0)]);
            let (pairs, default_ms) = s.snapshot_profile();
            // Restore into a strategy built with a *different* default:
            // the profile must fully determine allocation behavior.
            let mut t = make_strategy(a, 1.0, 0.5);
            t.restore_profile(&pairs, default_ms);
            let cohort: Vec<usize> = (0..12).collect();
            assert_eq!(
                s.allocate(&cohort, 3, &mut Rng::new(2)),
                t.allocate(&cohort, 3, &mut Rng::new(2))
            );
            assert_eq!(t.predicted_ms(3), s.predicted_ms(3));
        }
        // Random is stateless: empty profile, restore is a no-op.
        let s = make_strategy(Allocation::Random, 1.0, 0.5);
        assert!(s.snapshot_profile().0.is_empty());
    }
}
