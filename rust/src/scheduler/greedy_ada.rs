//! Greedy Allocation with Adaptive Profiling — the paper's Algorithm 1.
//!
//! LPT greedy: sort the cohort by (estimated) training time descending and
//! assign each client to the device with the smallest accumulated load —
//! the classic Longest-Processing-Time heuristic, ≤ (4/3 − 1/(3M))·OPT
//! (Graham 1969). Unknown client times start at the configurable default
//! `t`; after each round, measured times mark clients as *profiled* and
//! `t` is updated by the momentum rule of Algorithm 1 lines 26–27:
//! `t ← m·avg(measured) + (1−m)·t`.

use std::collections::HashMap;

use super::{Groups, Strategy};
use crate::util::rng::Rng;

/// Algorithm 1 state.
pub struct GreedyAda {
    /// Measured per-client times (c.time for profiled clients).
    profiled: HashMap<usize, f64>,
    /// Default time `t` for unprofiled clients.
    default_ms: f64,
    /// Update momentum `m` ∈ [0,1]; m=1 ⇒ trust measurements only.
    momentum: f64,
}

impl GreedyAda {
    pub fn new(default_ms: f64, momentum: f64) -> GreedyAda {
        GreedyAda {
            profiled: HashMap::new(),
            default_ms: default_ms.max(1e-9),
            momentum: momentum.clamp(0.0, 1.0),
        }
    }

    /// Estimated time for a client (Algorithm 1 lines 7–9).
    pub fn estimate_ms(&self, client: usize) -> f64 {
        *self.profiled.get(&client).unwrap_or(&self.default_ms)
    }

    /// Number of clients profiled so far.
    pub fn profiled_count(&self) -> usize {
        self.profiled.len()
    }

    /// Current default time `t`.
    pub fn default_ms(&self) -> f64 {
        self.default_ms
    }
}

impl Strategy for GreedyAda {
    fn name(&self) -> &'static str {
        "greedyada"
    }

    fn allocate(&mut self, clients: &[usize], m: usize, _rng: &mut Rng) -> Groups {
        assert!(m > 0);
        // Sort by estimated time, descending (Algorithm 1 line 3).
        let mut order: Vec<usize> = clients.to_vec();
        order.sort_by(|&a, &b| {
            self.estimate_ms(b)
                .partial_cmp(&self.estimate_ms(a))
                .unwrap()
                .then(a.cmp(&b)) // deterministic tie-break
        });
        // Greedy min-load assignment (lines 10–12). M is small (≤ 64);
        // a linear argmin beats a heap at this size.
        let mut groups: Groups = vec![Vec::new(); m];
        let mut load = vec![0.0f64; m];
        for c in order {
            let dev = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            load[dev] += self.estimate_ms(c);
            groups[dev].push(c);
        }
        groups
    }

    /// ADAPTIVE_PROFILING (Algorithm 1 lines 16–29).
    fn observe(&mut self, measured: &[(usize, f64)]) {
        if measured.is_empty() {
            return;
        }
        for &(c, t) in measured {
            self.profiled.insert(c, t);
        }
        let avg = measured.iter().map(|&(_, t)| t).sum::<f64>()
            / measured.len() as f64;
        self.default_ms = avg * self.momentum + self.default_ms * (1.0 - self.momentum);
    }

    fn predicted_ms(&self, client: usize) -> Option<f64> {
        Some(self.estimate_ms(client))
    }

    fn snapshot_profile(&self) -> (Vec<(usize, f64)>, f64) {
        let mut pairs: Vec<(usize, f64)> =
            self.profiled.iter().map(|(&c, &t)| (c, t)).collect();
        pairs.sort_unstable_by_key(|&(c, _)| c);
        (pairs, self.default_ms)
    }

    fn restore_profile(&mut self, profiled: &[(usize, f64)], default_ms: f64) {
        self.profiled = profiled.iter().copied().collect();
        self.default_ms = default_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{is_partition, makespan};
    use crate::util::prop;

    fn rng() -> Rng {
        Rng::new(17)
    }

    #[test]
    fn lpt_with_known_times_is_good() {
        // Classic LPT example: times {7,6,5,4,3,2,2} on 3 machines.
        let times = [7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 2.0];
        let mut g = GreedyAda::new(1.0, 1.0);
        g.observe(
            &times
                .iter()
                .enumerate()
                .map(|(i, &t)| (i, t))
                .collect::<Vec<_>>(),
        );
        let groups = g.allocate(&[0, 1, 2, 3, 4, 5, 6], 3, &mut rng());
        assert!(is_partition(&groups, &[0, 1, 2, 3, 4, 5, 6]));
        let span = makespan(&groups, |c| times[c]);
        // total = 29, OPT = 10 (e.g. {7,3},{6,4},{5,2,2}); LPT gives ≤ 4/3·OPT.
        assert!(span <= 10.0 * 4.0 / 3.0 + 1e-9, "span={span}");
    }

    #[test]
    fn unprofiled_clients_use_default_then_adapt() {
        let mut g = GreedyAda::new(100.0, 0.5);
        assert_eq!(g.estimate_ms(3), 100.0);
        g.observe(&[(3, 40.0), (4, 60.0)]);
        assert_eq!(g.estimate_ms(3), 40.0);
        assert_eq!(g.profiled_count(), 2);
        // t ← 0.5·avg(50) + 0.5·100 = 75.
        assert!((g.default_ms() - 75.0).abs() < 1e-9);
        // m = 1 trusts measurements fully.
        let mut g1 = GreedyAda::new(100.0, 1.0);
        g1.observe(&[(0, 10.0)]);
        assert_eq!(g1.default_ms(), 10.0);
    }

    #[test]
    fn allocation_is_deterministic() {
        let mut a = GreedyAda::new(50.0, 0.5);
        let mut b = GreedyAda::new(50.0, 0.5);
        let cohort: Vec<usize> = (0..20).collect();
        assert_eq!(
            a.allocate(&cohort, 4, &mut rng()),
            b.allocate(&cohort, 4, &mut rng())
        );
    }

    /// Brute-force optimal makespan for tiny instances.
    fn opt_makespan(times: &[f64], m: usize) -> f64 {
        fn rec(i: usize, times: &[f64], load: &mut Vec<f64>, best: &mut f64) {
            if i == times.len() {
                let span = load.iter().cloned().fold(0.0, f64::max);
                *best = best.min(span);
                return;
            }
            for d in 0..load.len() {
                load[d] += times[i];
                if load[d] < *best {
                    rec(i + 1, times, load, best);
                }
                load[d] -= times[i];
                if load[d] == 0.0 {
                    break; // symmetry cut
                }
            }
        }
        let mut best = f64::MAX;
        rec(0, times, &mut vec![0.0; m], &mut best);
        best
    }

    #[test]
    fn prop_lpt_within_graham_bound_of_opt() {
        prop::check("lpt-graham-bound", 123, 60, |rng| {
            let n = 2 + rng.below(8) as usize;
            let m = 1 + rng.below(3) as usize;
            let times: Vec<f64> =
                (0..n).map(|_| 1.0 + rng.uniform() * 99.0).collect();
            let mut g = GreedyAda::new(1.0, 1.0);
            g.observe(
                &times.iter().enumerate().map(|(i, &t)| (i, t)).collect::<Vec<_>>(),
            );
            let cohort: Vec<usize> = (0..n).collect();
            let groups = g.allocate(&cohort, m, rng);
            crate::prop_assert!(
                crate::scheduler::is_partition(&groups, &cohort),
                "not a partition"
            );
            let span = makespan(&groups, |c| times[c]);
            let opt = opt_makespan(&times, m);
            let bound = (4.0 / 3.0 - 1.0 / (3.0 * m as f64)) * opt + 1e-6;
            crate::prop_assert!(
                span <= bound,
                "LPT {span} exceeds Graham bound {bound} (opt {opt}, m {m})"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_every_device_used_when_enough_clients() {
        prop::check("all-devices-used", 5, 40, |rng| {
            let m = 1 + rng.below(8) as usize;
            let n = m + rng.below(40) as usize;
            let mut g = GreedyAda::new(10.0, 0.5);
            let cohort: Vec<usize> = (0..n).collect();
            let groups = g.allocate(&cohort, m, rng);
            crop_empty(&groups, m, n)
        });

        fn crop_empty(groups: &Groups, m: usize, n: usize) -> Result<(), String> {
            let empty = groups.iter().filter(|g| g.is_empty()).count();
            if n >= m && empty > 0 {
                return Err(format!("{empty} idle devices with {n} clients"));
            }
            Ok(())
        }
    }
}
