//! The paper's allocation baselines (Fig 5): random and slowest-together.

use std::collections::HashMap;

use super::{Groups, Strategy};
use crate::util::rng::Rng;

/// Random allocation: shuffle, then deal ≈K/M clients per device.
pub struct RandomAlloc;

impl Strategy for RandomAlloc {
    fn name(&self) -> &'static str {
        "random"
    }

    fn allocate(&mut self, clients: &[usize], m: usize, rng: &mut Rng) -> Groups {
        assert!(m > 0);
        let mut order = clients.to_vec();
        rng.shuffle(&mut order);
        chunk_contiguous(&order, m)
    }
}

/// Slowest allocation: sort by (measured) time descending and pack
/// contiguous chunks — co-locating the stragglers on one device, the
/// paper's pathological baseline.
pub struct SlowestAlloc {
    times: HashMap<usize, f64>,
    default_ms: f64,
}

impl SlowestAlloc {
    pub fn new(default_ms: f64) -> SlowestAlloc {
        SlowestAlloc { times: HashMap::new(), default_ms }
    }

    fn time(&self, c: usize) -> f64 {
        *self.times.get(&c).unwrap_or(&self.default_ms)
    }
}

impl Strategy for SlowestAlloc {
    fn name(&self) -> &'static str {
        "slowest"
    }

    fn allocate(&mut self, clients: &[usize], m: usize, _rng: &mut Rng) -> Groups {
        assert!(m > 0);
        let mut order = clients.to_vec();
        order.sort_by(|&a, &b| {
            self.time(b).partial_cmp(&self.time(a)).unwrap().then(a.cmp(&b))
        });
        chunk_contiguous(&order, m)
    }

    fn observe(&mut self, measured: &[(usize, f64)]) {
        for &(c, t) in measured {
            self.times.insert(c, t);
        }
    }

    fn predicted_ms(&self, client: usize) -> Option<f64> {
        Some(self.time(client))
    }

    fn snapshot_profile(&self) -> (Vec<(usize, f64)>, f64) {
        let mut pairs: Vec<(usize, f64)> =
            self.times.iter().map(|(&c, &t)| (c, t)).collect();
        pairs.sort_unstable_by_key(|&(c, _)| c);
        (pairs, self.default_ms)
    }

    fn restore_profile(&mut self, profiled: &[(usize, f64)], default_ms: f64) {
        self.times = profiled.iter().copied().collect();
        self.default_ms = default_ms;
    }
}

/// Deal ≈len/M contiguous chunks (the paper's "around 20/M clients").
fn chunk_contiguous(order: &[usize], m: usize) -> Groups {
    let mut groups: Groups = vec![Vec::new(); m];
    if order.is_empty() {
        return groups;
    }
    let base = order.len() / m;
    let extra = order.len() % m;
    let mut it = order.iter();
    for (d, group) in groups.iter_mut().enumerate() {
        let take = base + usize::from(d < extra);
        group.extend(it.by_ref().take(take).copied());
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{is_partition, makespan};
    use crate::util::prop;

    #[test]
    fn random_is_partition_with_even_chunks() {
        let mut s = RandomAlloc;
        let cohort: Vec<usize> = (0..20).collect();
        let groups = s.allocate(&cohort, 4, &mut Rng::new(3));
        assert!(is_partition(&groups, &cohort));
        assert!(groups.iter().all(|g| g.len() == 5));
    }

    #[test]
    fn slowest_packs_stragglers_together() {
        let mut s = SlowestAlloc::new(10.0);
        // Clients 0..3 are very slow.
        s.observe(&[(0, 100.0), (1, 95.0), (2, 90.0), (3, 85.0)]);
        let cohort: Vec<usize> = (0..8).collect();
        let groups = s.allocate(&cohort, 2, &mut Rng::new(1));
        assert!(is_partition(&groups, &cohort));
        // First chunk holds exactly the four slow clients.
        let mut first = groups[0].clone();
        first.sort_unstable();
        assert_eq!(first, vec![0, 1, 2, 3]);
        // And its makespan dominates.
        let t = |c: usize| s.time(c);
        assert!(groups[0].iter().map(|&c| t(c)).sum::<f64>()
            > groups[1].iter().map(|&c| t(c)).sum::<f64>());
    }

    #[test]
    fn prop_baselines_always_partition() {
        prop::check("baselines-partition", 31, 50, |rng| {
            let n = rng.below(50) as usize;
            let m = 1 + rng.below(8) as usize;
            let cohort: Vec<usize> = (0..n).map(|i| i * 3).collect();
            let g1 = RandomAlloc.allocate(&cohort, m, rng);
            let mut sa = SlowestAlloc::new(5.0);
            let g2 = sa.allocate(&cohort, m, rng);
            crate::prop_assert!(is_partition(&g1, &cohort), "random not partition");
            crate::prop_assert!(is_partition(&g2, &cohort), "slowest not partition");
            // Chunk sizes differ by at most 1.
            for g in [&g1, &g2] {
                let sizes: Vec<usize> = g.iter().map(Vec::len).collect();
                let min = sizes.iter().min().unwrap();
                let max = sizes.iter().max().unwrap();
                crate::prop_assert!(max - min <= 1, "uneven chunks {sizes:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_beats_slowest_on_heterogeneous_times() {
        // The Fig 5 ordering at makespan level: greedy ≤ random ≤ slowest
        // on a heavy-tailed time distribution (averaged over seeds).
        let mut rng = Rng::new(77);
        let mut sums = [0.0f64; 3];
        for trial in 0..30 {
            let n = 20;
            let times: Vec<f64> = (0..n)
                .map(|_| 50.0 * rng.log_normal(0.0, 1.0))
                .collect();
            let cohort: Vec<usize> = (0..n).collect();
            let measured: Vec<(usize, f64)> =
                times.iter().enumerate().map(|(i, &t)| (i, t)).collect();

            let mut g = crate::scheduler::GreedyAda::new(50.0, 1.0);
            g.observe(&measured);
            let mut r = RandomAlloc;
            let mut s = SlowestAlloc::new(50.0);
            s.observe(&measured);

            let mut rr = Rng::new(1000 + trial);
            sums[0] += makespan(&g.allocate(&cohort, 4, &mut rr), |c| times[c]);
            sums[1] += makespan(&r.allocate(&cohort, 4, &mut rr), |c| times[c]);
            sums[2] += makespan(&s.allocate(&cohort, 4, &mut rr), |c| times[c]);
        }
        assert!(sums[0] < sums[1], "greedy {} !< random {}", sums[0], sums[1]);
        assert!(sums[1] < sums[2], "random {} !< slowest {}", sums[1], sums[2]);
    }
}
