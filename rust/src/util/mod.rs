//! Shared substrates: deterministic RNG, JSON, CLI args, clocks, binary
//! codecs, and the in-tree property-testing harness.
//!
//! These exist because the offline registry ships none of rand / serde /
//! clap / proptest — see DESIGN.md "Substitutions" #7.

pub mod args;
pub mod bench;
pub mod bytes;
pub mod clock;
pub mod json;
pub mod prop;
pub mod rng;
