//! Minimal JSON parser/serializer (the offline registry ships no serde).
//!
//! Supports the full JSON data model; used by the config system, the
//! artifact metadata loader and the tracking store. Numbers are kept as
//! f64 (all easyfl metadata fits losslessly: counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------ typed accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Typed lookup helpers that produce config-grade errors.
    pub fn req_str(&self, key: &str) -> Result<String> {
        self.get(key)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| Error::Json(format!("missing string field {key:?}")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| Error::Json(format!("missing integer field {key:?}")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| Error::Json(format!("missing number field {key:?}")))
    }
}

/// Convenience builder: `obj([("a", Json::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(fields: I) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not emitted by us).
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("e").as_bool(), Some(true));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = obj([
            ("name", Json::Str("easyfl".into())),
            ("n", Json::Num(42.0)),
        ]);
        let pretty = v.to_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn parses_real_artifact_meta() {
        // Shape of python/compile/aot.py output.
        let src = r#"{
          "model": "mlp", "param_count": 241854, "batch": 32,
          "input_shape": [784], "input_dtype": "f32", "classes": 62,
          "layout": [["w1", [784, 256]], ["b1", [256]]],
          "files": {"train": "mlp_train.hlo.txt"}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_usize("param_count").unwrap(), 241854);
        assert_eq!(v.req_str("input_dtype").unwrap(), "f32");
        assert_eq!(
            v.get("layout").as_arr().unwrap()[0].as_arr().unwrap()[0]
                .as_str(),
            Some("w1")
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
        let s = Json::Str("tab\there".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("tab\there"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
