//! Real vs virtual time.
//!
//! Heterogeneity simulation injects waits proportional to device speed
//! ratios (paper §V-A). Small runs sleep for real (scaled); large sweeps —
//! Fig 7's 64-GPU grid — run on a virtual clock so the *shape* of the
//! result is exact without tying up wall-clock. Everything that waits goes
//! through this trait so the two modes are interchangeable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A source of "now" plus the ability to wait.
pub trait Clock: Send + Sync {
    /// Milliseconds since the clock's epoch.
    fn now_ms(&self) -> f64;
    /// Block the calling worker for `ms` simulated milliseconds.
    fn wait_ms(&self, ms: f64);
    /// True when waits consume wall-clock time.
    fn is_real(&self) -> bool;
}

/// Wall-clock backed; waits sleep, optionally scaled down.
pub struct RealClock {
    epoch: Instant,
    /// Multiplier applied to waits: 0.01 ⇒ simulated second = 10 real ms.
    time_scale: f64,
}

impl RealClock {
    pub fn new(time_scale: f64) -> Self {
        RealClock { epoch: Instant::now(), time_scale }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new(1.0)
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1000.0
    }

    fn wait_ms(&self, ms: f64) {
        if ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(
                ms * self.time_scale / 1000.0,
            ));
        }
    }

    fn is_real(&self) -> bool {
        true
    }
}

/// Logical time in integer microseconds; waits advance a shared counter.
///
/// Per-worker logical timelines are modeled by the scheduler itself (each
/// simulated device accumulates its own makespan); the shared counter
/// provides a monotone global ordering for tracking timestamps.
#[derive(Default)]
pub struct VirtualClock {
    now_us: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Jump the clock to an absolute time. Drivers that own their own
    /// timeline (SimNet's event queue) sync the shared clock to event
    /// time before emitting telemetry, so spans carry virtual
    /// timestamps.
    pub fn set_ms(&self, ms: f64) {
        self.now_us.store((ms * 1000.0) as u64, Ordering::Relaxed);
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> f64 {
        self.now_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    fn wait_ms(&self, ms: f64) {
        if ms > 0.0 {
            self.now_us
                .fetch_add((ms * 1000.0) as u64, Ordering::Relaxed);
        }
    }

    fn is_real(&self) -> bool {
        false
    }
}

/// Simple monotonic stopwatch for measuring real elapsed time.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_advances() {
        let c = RealClock::new(1.0);
        let t0 = c.now_ms();
        std::thread::sleep(Duration::from_millis(5));
        assert!(c.now_ms() > t0);
        assert!(c.is_real());
    }

    #[test]
    fn real_clock_scales_waits() {
        let c = RealClock::new(0.01);
        let sw = Stopwatch::start();
        c.wait_ms(200.0); // scaled → 2ms real
        assert!(sw.elapsed_ms() < 100.0);
    }

    #[test]
    fn virtual_clock_accumulates_without_sleeping() {
        let c = VirtualClock::new();
        let sw = Stopwatch::start();
        c.wait_ms(1_000_000.0);
        assert!(sw.elapsed_ms() < 50.0);
        assert!((c.now_ms() - 1_000_000.0).abs() < 1.0);
        assert!(!c.is_real());
    }

    #[test]
    fn virtual_clock_jumps_to_absolute_time() {
        let c = VirtualClock::new();
        c.set_ms(123.5);
        assert!((c.now_ms() - 123.5).abs() < 1e-9);
        c.set_ms(50.0); // backwards jumps are allowed (new sim timeline)
        assert!((c.now_ms() - 50.0).abs() < 1e-9);
    }
}
