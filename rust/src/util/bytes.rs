//! Little-endian binary encode/decode helpers for the wire protocol and
//! artifact files (no `serde`/`bincode` in the offline registry).

use crate::error::{Error, Result};

/// Append-only binary writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// f32 slice with length prefix; bulk memcpy on LE targets.
    pub fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        self.raw_f32s(vs);
    }

    /// f32 slice without length prefix.
    pub fn raw_f32s(&mut self, vs: &[f32]) {
        if cfg!(target_endian = "little") {
            // SAFETY: f32 and [u8; 4] are layout-compatible; LE matches wire.
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    vs.as_ptr() as *const u8,
                    vs.len() * 4,
                )
            };
            self.buf.extend_from_slice(bytes);
        } else {
            for v in vs {
                self.buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-style binary reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            Error::Comm("length overflow".into())
        })?;
        if end > self.buf.len() {
            return Err(Error::Comm(format!(
                "truncated frame: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Comm("invalid utf-8 string".into()))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        self.raw_f32s(n)
    }

    pub fn raw_f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            Error::Comm("f32 length overflow".into())
        })?)?;
        let mut out = Vec::with_capacity(n);
        if cfg!(target_endian = "little") {
            // SAFETY: reading n f32s from 4n bytes; alignment handled by copy.
            unsafe {
                out.set_len(n);
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
        } else {
            for chunk in bytes.chunks_exact(4) {
                out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
        }
        Ok(out)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Read a whole little-endian f32 file (artifact init params).
pub fn read_f32_file(path: &std::path::Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(Error::Artifact(format!(
            "{}: length {} not a multiple of 4",
            path.display(),
            bytes.len()
        )));
    }
    let mut reader = Reader::new(&bytes);
    reader.raw_f32s(bytes.len() / 4)
}

/// Read a whole little-endian i32 file (golden labels).
pub fn read_i32_file(path: &std::path::Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % 4 != 0 {
        return Err(Error::Artifact(format!(
            "{}: length {} not a multiple of 4",
            path.display(),
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("hello — utf8 ✓");
        w.f32s(&[1.0, 2.0, 3.0]);
        w.bytes(&[9, 8, 7]);
        let buf = w.finish();

        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "hello — utf8 ✓");
        assert_eq!(r.f32s().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(r.bytes().unwrap(), &[9, 8, 7]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_reads_error() {
        let mut w = Writer::new();
        w.u32(100); // claims 100 f32s
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn big_f32_roundtrip() {
        let vs: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        let mut w = Writer::with_capacity(vs.len() * 4 + 4);
        w.f32s(&vs);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32s().unwrap(), vs);
    }
}
