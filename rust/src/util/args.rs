//! Tiny CLI argument parser (no `clap` in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands, and generates usage text from the declared options.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declarative CLI option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` against the declared options.
    pub fn parse(argv: &[String], opts: &[Opt]) -> Result<Args> {
        let mut out = Args::default();
        for opt in opts {
            if let Some(d) = opt.default {
                out.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = opts.iter().find(|o| o.name == key).ok_or_else(|| {
                    Error::Config(format!("unknown option --{key}"))
                })?;
                if opt.is_flag {
                    out.flags.push(key);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    Error::Config(format!(
                                        "--{key} requires a value"
                                    ))
                                })?
                        }
                    };
                    out.values.insert(key, value);
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let raw = self.get(name).ok_or_else(|| {
            Error::Config(format!("missing --{name}"))
        })?;
        raw.parse()
            .map_err(|_| Error::Config(format!("--{name}: not an integer: {raw}")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let raw = self.get(name).ok_or_else(|| {
            Error::Config(format!("missing --{name}"))
        })?;
        raw.parse()
            .map_err(|_| Error::Config(format!("--{name}: not a number: {raw}")))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Render usage text for a command.
pub fn usage(cmd: &str, about: &str, opts: &[Opt]) -> String {
    let mut out = format!("{about}\n\nUSAGE: easyfl {cmd} [options]\n\nOPTIONS:\n");
    for o in opts {
        let default = o
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        out.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, default));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Vec<Opt> {
        vec![
            Opt { name: "rounds", help: "rounds", default: Some("10"), is_flag: false },
            Opt { name: "model", help: "model", default: None, is_flag: false },
            Opt { name: "verbose", help: "verbose", default: None, is_flag: true },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(
            &sv(&["--rounds", "5", "--model=mlp", "--verbose", "pos1"]),
            &opts(),
        )
        .unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), 5);
        assert_eq!(a.get("model"), Some("mlp"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &opts()).unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), 10);
        assert_eq!(a.get("model"), None);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&sv(&["--nope"]), &opts()).is_err());
        assert!(Args::parse(&sv(&["--model"]), &opts()).is_err());
        assert!(Args::parse(&sv(&["--rounds", "abc"]), &opts())
            .unwrap()
            .get_usize("rounds")
            .is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("run", "Run training", &opts());
        assert!(u.contains("--rounds"));
        assert!(u.contains("default: 10"));
    }
}
